"""AOT compile path: lower the L2 matcher to HLO **text** artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the ``xla`` crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text
parser on the Rust side (``HloModuleProto::from_text_file``) reassigns ids
and round-trips cleanly.  See /opt/xla-example/README.md.

Artifacts written (per batch-size variant B):

    matcher_b{B}.hlo.txt        full two-matcher model (4 outputs)
    title_matcher_b{B}.hlo.txt  title-only first-pass model (1 output)
    manifest.json               shapes/dtypes/constants for the Rust loader

The Rust runtime (`rust/src/runtime/artifact.rs`) reads ``manifest.json`` to
discover variants and validate shapes at load time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import TITLE_LEN, BITMAP_WORDS

# Batch-size variants compiled by default.  The L3 batcher picks the
# smallest variant that fits a pair block, padding the tail.
DEFAULT_BATCH_SIZES = (64, 256, 1024)

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_matcher(batch: int) -> str:
    """Lower the full matcher for one batch-size variant."""
    t = jax.ShapeDtypeStruct((batch, TITLE_LEN), jnp.int32)
    v = jax.ShapeDtypeStruct((batch,), jnp.int32)
    g = jax.ShapeDtypeStruct((batch, BITMAP_WORDS), jnp.int32)
    lowered = jax.jit(model.matcher).lower(t, t, v, v, g, g)
    return to_hlo_text(lowered)


def lower_title_matcher(batch: int) -> str:
    """Lower the title-only first-pass matcher."""
    t = jax.ShapeDtypeStruct((batch, TITLE_LEN), jnp.int32)
    v = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(model.title_matcher).lower(t, t, v, v)
    return to_hlo_text(lowered)


def build_manifest(batch_sizes) -> dict:
    """Manifest consumed by rust/src/runtime/artifact.rs."""
    return {
        "version": MANIFEST_VERSION,
        "title_len": TITLE_LEN,
        "bitmap_words": BITMAP_WORDS,
        "w_title": model.W_TITLE,
        "w_abstract": model.W_ABSTRACT,
        "threshold": model.THRESHOLD,
        "variants": [
            {
                "batch": b,
                "matcher": f"matcher_b{b}.hlo.txt",
                "title_matcher": f"title_matcher_b{b}.hlo.txt",
                "outputs": ["score", "sim_title", "sim_abstract", "skipped"],
            }
            for b in batch_sizes
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory to write artifacts into")
    ap.add_argument("--out", default=None,
                    help="(compat) single-file output; writes the b256 "
                         "matcher there in addition to --out-dir")
    ap.add_argument("--batch-sizes", default=",".join(
        str(b) for b in DEFAULT_BATCH_SIZES))
    args = ap.parse_args()

    batch_sizes = [int(s) for s in args.batch_sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)

    for b in batch_sizes:
        text = lower_matcher(b)
        path = os.path.join(args.out_dir, f"matcher_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

        text = lower_title_matcher(b)
        path = os.path.join(args.out_dir, f"title_matcher_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = build_manifest(batch_sizes)
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")

    if args.out:
        # Back-compat with the scaffold Makefile's single-artifact target.
        with open(args.out, "w") as f:
            f.write(lower_matcher(256))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
