"""Layer-2 JAX matcher model — the paper's matching strategy (§5.1).

The paper scores each candidate pair with two matchers and combines them::

    score = 0.5 * edit_distance_sim(title_a, title_b)
          + 0.5 * trigram_sim(abstract_a, abstract_b)
    match = score >= 0.75

plus an internal optimization: "skipping the execution of the second matcher
if the similarity after the execution of the first matcher was too low for
reaching the combined similarity threshold."

This module is the build-time-only JAX graph that calls the Layer-1 Pallas
kernels and is AOT-lowered by ``aot.py`` to HLO text; the Rust coordinator
(Layer 3) loads and executes the compiled artifact on the request path —
Python is never invoked at runtime.

Short-circuit semantics on a vector machine: evaluating a data-dependent
branch per lane would serialize the batch, so the AOT model computes both
similarities for every lane and additionally reports, per lane, whether the
paper's optimization *would have* skipped matcher 2 (``skipped``).  Match
decisions are bit-identical to the short-circuiting Rust native matcher
because a skipped pair is by construction a non-match.  The skipped-fraction
is used by the L3 scheduler to decide between the native (short-circuit
wins when most pairs are early-exits) and XLA (batch wins when not)
matchers — see ``rust/src/er/matcher.rs``.
"""

import jax.numpy as jnp

from .kernels import levenshtein_similarity, trigram_dice

# Matching-strategy constants (paper §5.1).  Mirrored in
# rust/src/er/strategy.rs — keep in sync.
W_TITLE = 0.5
W_ABSTRACT = 0.5
THRESHOLD = 0.75


def matcher(ta, tb, la, lb, ga, gb):
    """Score a batch of candidate entity pairs.

    Args:
        ta, tb: ``int32[B, L]`` zero-padded title character codes.
        la, lb: ``int32[B]`` true title lengths.
        ga, gb: ``int32[B, W]`` packed abstract trigram bitmaps.

    Returns:
        Tuple of four ``float32[B]`` arrays:
        ``(score, sim_title, sim_abstract, skipped)`` where ``skipped`` is
        1.0 for lanes the paper's short-circuit optimization would not have
        run matcher 2 on (useful for L3 scheduling + accounting), else 0.0.
    """
    sim_t = levenshtein_similarity(ta, tb, la, lb)
    sim_g = trigram_dice(ga, gb)
    score = W_TITLE * sim_t + W_ABSTRACT * sim_g
    # Even a perfect matcher-2 similarity cannot lift these lanes over the
    # threshold: the short-circuit predicate of §5.1.
    skipped = (W_TITLE * sim_t + W_ABSTRACT * 1.0) < THRESHOLD
    return (
        score.astype(jnp.float32),
        sim_t.astype(jnp.float32),
        sim_g.astype(jnp.float32),
        skipped.astype(jnp.float32),
    )


def title_matcher(ta, tb, la, lb):
    """Title-only variant (first pass of a short-circuiting two-phase run).

    Lets Layer 3 run the paper's optimization *across* artifacts: score all
    pairs with the cheap matcher first, then re-run only the surviving lanes
    through :func:`matcher`.  Benchmarked as ablation A1.
    """
    sim_t = levenshtein_similarity(ta, tb, la, lb)
    return (sim_t.astype(jnp.float32),)
