"""Layer-1 Pallas kernels for the batched pair-similarity matcher.

The matching strategy of the paper (edit distance on the title, trigram
similarity on the abstract, weighted average, threshold 0.75) is the compute
hot-spot of the whole entity-resolution workflow: Sorted Neighborhood
produces ``(n - w/2) * (w - 1)`` candidate pairs and every one of them is
scored.  These kernels score a *batch* of pairs at once so the Layer-3 Rust
coordinator can amortize the PJRT dispatch overhead.

Kernels
-------
``levenshtein``  batched edit-distance similarity over fixed-length,
                 zero-padded integer code sequences (titles).
``trigram``      batched Dice similarity over packed trigram bitmaps
                 (abstracts), using ``lax.population_count``.

Both are written with ``pl.pallas_call(..., interpret=True)``: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so interpret mode is the
correctness path; TPU performance is estimated analytically in DESIGN.md §7.
``ref.py`` holds the pure-``jnp`` oracles the kernels are tested against.
"""

from .levenshtein import levenshtein_similarity, TITLE_LEN
from .trigram import trigram_dice, BITMAP_WORDS, BITMAP_BITS
from . import ref

__all__ = [
    "levenshtein_similarity",
    "trigram_dice",
    "ref",
    "TITLE_LEN",
    "BITMAP_WORDS",
    "BITMAP_BITS",
]
