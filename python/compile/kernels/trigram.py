"""Batched trigram Dice similarity over packed bitmaps as a Pallas kernel.

The paper's second matcher is "TriGram on abstract".  Exact trigram-set Dice
requires variable-length set intersection — hostile to a vector machine.  We
instead hash every character trigram of the (normalized) abstract into a
fixed ``BITMAP_BITS``-bit Bloom-style bitmap **once**, Rust-side, at map
time (``rust/src/runtime/encode.rs``), and compute

    dice(A, B) = 2 * popcount(A & B) / (popcount(A) + popcount(B))

over ``int32[B, W]`` packed words with ``lax.population_count``.  This is a
pure elementwise + row-reduction kernel: one VMEM tile of ``(B_tile, W)``
words per operand, VPU-bound, no MXU.  With 2048 bits the collision-induced
Dice error for typical abstracts (~400 distinct trigrams) is < 2% — measured
in ``rust/tests/`` against the exact set computation, and irrelevant for the
reproduction since *both* the native and XLA matchers use the same bitmaps.

Empty-vs-empty abstracts are defined as similarity 1.0 (identical), matching
the reference oracle and the Rust native matcher.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Bitmap geometry.  Must match rust/src/runtime/encode.rs.
BITMAP_BITS = 2048
BITMAP_WORDS = BITMAP_BITS // 32  # 64 int32 words

DEFAULT_BLOCK_B = 256


def _trigram_kernel(a_ref, b_ref, out_ref):
    """Kernel body: Dice over one batch tile of packed bitmaps."""
    a = a_ref[...]
    b = b_ref[...]
    inter = jax.lax.population_count(a & b).sum(axis=1)
    ca = jax.lax.population_count(a).sum(axis=1)
    cb = jax.lax.population_count(b).sum(axis=1)
    denom = (ca + cb).astype(jnp.float32)
    dice = 2.0 * inter.astype(jnp.float32) / jnp.maximum(denom, 1.0)
    out_ref[...] = jnp.where(denom == 0.0, 1.0, dice)


@functools.partial(jax.jit, static_argnames=("block_b",))
def trigram_dice(a, b, *, block_b: int = DEFAULT_BLOCK_B):
    """Batched Dice similarity of packed trigram bitmaps.

    Args:
        a, b: ``int32[B, W]`` packed bitmaps (W = :data:`BITMAP_WORDS`).
        block_b: batch tile size per grid step.

    Returns:
        ``float32[B]`` Dice coefficients in ``[0, 1]``.
    """
    bsz, w = a.shape
    if bsz % block_b != 0:
        block_b = bsz
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _trigram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=True,
    )(a, b)
