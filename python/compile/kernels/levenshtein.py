"""Batched Levenshtein (edit-distance) similarity as a Pallas kernel.

Problem shape
-------------
Titles are encoded Rust-side (see ``rust/src/runtime/encode.rs``) as
``int32[B, L]`` arrays of small character codes, zero-padded to ``L``
(= :data:`TITLE_LEN`), plus true lengths ``int32[B]``.  The kernel returns
``float32[B]`` similarities::

    sim = 1 - dist(a[:la], b[:lb]) / max(la, lb, 1)

Vectorization strategy (the Hardware-Adaptation story)
------------------------------------------------------
The classic Wagner–Fischer DP is sequential in both dimensions.  The row
recurrence is

    d[i][j] = min( d[i-1][j-1] + sub,      # substitution
                   d[i-1][j]   + 1,        # deletion
                   d[i][j-1]   + 1 )       # insertion

The first two terms depend only on the previous row (elementwise over j).
The insertion term is a running minimum that unrolls to the *min-plus*
identity

    d[i][j] = j + min_{k <= j} ( f[k] - k ),
    f[0]    = d[i][0] = i,
    f[k]    = min(d[i-1][k-1] + sub_k, d[i-1][k] + 1)   for k >= 1,

so each DP row is two vectorized passes: an elementwise min and one
``lax.cummin`` prefix scan.  The whole distance is ``L`` such rows, each of
``O(B * L)`` vector work — ideal for a wide VPU.  On a real TPU one tile of
``(B_tile, L+1)`` int32 rows lives in VMEM (3 rows * B_tile * (L+1) * 4 B;
for B_tile=256, L=64 that is ~200 KiB, well under the ~16 MiB VMEM budget),
and the grid walks the batch dimension.  There is no MXU work — the kernel
is VPU/scan bound, which is also what the roofline estimate in DESIGN.md
assumes.

Answer extraction: the DP must be read at ``(la, lb)``, not ``(L, L)``.
After finishing row ``i`` we capture ``row[lb]`` for the lanes with
``la == i`` (a batched gather via ``take_along_axis``), so padding never
influences the result.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed title length (characters) used across all artifacts.  Must match
# rust/src/runtime/encode.rs::TITLE_LEN.
TITLE_LEN = 64

# Default number of batch lanes processed per Pallas grid step.  Chosen so
# one tile's DP state fits comfortably in VMEM (see module docstring).
DEFAULT_BLOCK_B = 256


def _levenshtein_kernel(a_ref, b_ref, la_ref, lb_ref, out_ref):
    """Pallas kernel body: one batch tile, full DP.

    Refs:
        a_ref:  int32[Bt, L]   left title codes (0-padded)
        b_ref:  int32[Bt, L]   right title codes (0-padded)
        la_ref: int32[Bt]      true length of a (0..L)
        lb_ref: int32[Bt]      true length of b (0..L)
        out_ref: float32[Bt]   similarity in [0, 1]
    """
    a = a_ref[...]
    b = b_ref[...]
    la = la_ref[...]
    lb = lb_ref[...]

    bt, l = a.shape
    js = jnp.arange(l + 1, dtype=jnp.int32)  # [L+1]

    # prev[b, j] = distance(a[:0], b[:j]) = j
    prev = jnp.broadcast_to(js, (bt, l + 1)).astype(jnp.int32)
    lb_col = lb[:, None]  # [Bt, 1]

    # ans starts as row 0 gathered at lb (covers la == 0).
    ans0 = jnp.take_along_axis(prev, lb_col, axis=1)[:, 0]

    def row_step(i, carry):
        prev, ans = carry
        # sub cost for row i: a[i-1] vs b[j-1], j = 1..L
        ai = jax.lax.dynamic_slice_in_dim(a, i - 1, 1, axis=1)  # [Bt, 1]
        sub_cost = (ai != b).astype(jnp.int32)  # [Bt, L]
        # f[k] for k = 1..L: min(diagonal, above)
        diag = prev[:, :-1] + sub_cost
        above = prev[:, 1:] + 1
        e = jnp.minimum(diag, above)  # [Bt, L]
        # f[0] = d[i][0] = i
        f0 = jnp.full((bt, 1), i, dtype=jnp.int32)
        f = jnp.concatenate([f0, e], axis=1)  # [Bt, L+1]
        # row[j] = j + cummin_{k<=j}(f[k] - k)
        g = f - js[None, :]
        row = js[None, :] + jax.lax.cummin(g, axis=1)
        # capture answer for lanes whose a-length is exactly i
        picked = jnp.take_along_axis(row, lb_col, axis=1)[:, 0]
        ans = jnp.where(la == i, picked, ans)
        return row, ans

    _, ans = jax.lax.fori_loop(1, l + 1, row_step, (prev, ans0))

    denom = jnp.maximum(jnp.maximum(la, lb), 1).astype(jnp.float32)
    sim = 1.0 - ans.astype(jnp.float32) / denom
    # Two empty strings are identical.
    sim = jnp.where(jnp.maximum(la, lb) == 0, 1.0, sim)
    out_ref[...] = sim


@functools.partial(jax.jit, static_argnames=("block_b",))
def levenshtein_similarity(a, b, la, lb, *, block_b: int = DEFAULT_BLOCK_B):
    """Batched edit-distance similarity.

    Args:
        a, b:   ``int32[B, L]`` zero-padded character codes.
        la, lb: ``int32[B]`` true lengths, each in ``[0, L]``.
        block_b: batch tile size per grid step; ``B`` must be divisible by
            it (the Rust side always pads batches to the artifact size).

    Returns:
        ``float32[B]`` similarities in ``[0, 1]``.
    """
    bsz, l = a.shape
    if bsz % block_b != 0:
        block_b = bsz  # degenerate: single tile
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _levenshtein_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b, la, lb)
