"""Pure-``jnp`` (and pure-Python) correctness oracles for the L1 kernels.

These are the ground truth the Pallas kernels are validated against in
``python/tests/``.  Two tiers:

* ``*_jnp``   — vectorized jnp implementations with *independent* structure
  (no cummin trick, no pallas): used for allclose sweeps over shapes.
* ``levenshtein_py`` — the textbook O(L^2) scalar DP: used to validate the
  jnp oracle itself on small cases, closing the loop.
"""

import jax
import jax.numpy as jnp


def levenshtein_py(a, b) -> int:
    """Textbook Wagner–Fischer edit distance on Python sequences."""
    la, lb = len(a), len(b)
    prev = list(range(lb + 1))
    for i in range(1, la + 1):
        cur = [i] + [0] * lb
        for j in range(1, lb + 1):
            cost = 0 if a[i - 1] == b[j - 1] else 1
            cur[j] = min(prev[j - 1] + cost, prev[j] + 1, cur[j - 1] + 1)
        prev = cur
    return prev[lb]


def levenshtein_sim_py(a, b) -> float:
    """Similarity form of :func:`levenshtein_py` (matches kernel contract)."""
    m = max(len(a), len(b))
    if m == 0:
        return 1.0
    return 1.0 - levenshtein_py(a, b) / m


def levenshtein_similarity_jnp(a, b, la, lb):
    """Vectorized oracle: per-lane full DP using a scan over rows.

    Deliberately written *without* the min-plus cummin trick the kernel
    uses: the insertion term is resolved with an inner ``fori_loop``, i.e. a
    genuinely sequential scan, so a bug in the kernel's scan identity cannot
    be mirrored here.
    """
    bsz, l = a.shape
    js = jnp.arange(l + 1, dtype=jnp.int32)
    prev = jnp.broadcast_to(js, (bsz, l + 1)).astype(jnp.int32)
    lb_col = lb[:, None]
    ans0 = jnp.take_along_axis(prev, lb_col, axis=1)[:, 0]

    def row(i, carry):
        prev, ans = carry
        ai = jax.lax.dynamic_slice_in_dim(a, i - 1, 1, axis=1)
        sub_cost = (ai != b).astype(jnp.int32)
        diag = prev[:, :-1] + sub_cost
        above = prev[:, 1:] + 1
        e = jnp.minimum(diag, above)  # candidates for j=1..L

        def inner(j, cur):
            # cur[:, j] = min(e[:, j-1], cur[:, j-1] + 1)
            left = jax.lax.dynamic_slice_in_dim(cur, j - 1, 1, axis=1)[:, 0]
            ej = jax.lax.dynamic_slice_in_dim(e, j - 1, 1, axis=1)[:, 0]
            val = jnp.minimum(ej, left + 1)
            return jax.lax.dynamic_update_slice_in_dim(
                cur, val[:, None], j, axis=1
            )

        cur0 = jnp.concatenate(
            [jnp.full((bsz, 1), i, dtype=jnp.int32),
             jnp.zeros((bsz, l), dtype=jnp.int32)],
            axis=1,
        )
        cur = jax.lax.fori_loop(1, l + 1, inner, cur0)
        picked = jnp.take_along_axis(cur, lb_col, axis=1)[:, 0]
        ans = jnp.where(la == i, picked, ans)
        return cur, ans

    _, ans = jax.lax.fori_loop(1, l + 1, row, (prev, ans0))
    denom = jnp.maximum(jnp.maximum(la, lb), 1).astype(jnp.float32)
    sim = 1.0 - ans.astype(jnp.float32) / denom
    return jnp.where(jnp.maximum(la, lb) == 0, 1.0, sim)


def trigram_dice_jnp(a, b):
    """Vectorized oracle for the bitmap Dice kernel.

    Counts bits via an arithmetic popcount (bit-slicing), not
    ``lax.population_count``, for implementation independence.
    """

    def popcount32(x):
        x = x - ((x >> 1) & jnp.uint32(0x55555555))
        x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
        x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
        return ((x * jnp.uint32(0x01010101)) >> 24) & jnp.uint32(0x3F)

    ax = a.astype(jnp.uint32)
    bx = b.astype(jnp.uint32)
    inter = popcount32(ax & bx).astype(jnp.int32).sum(axis=1)
    ca = popcount32(ax).astype(jnp.int32).sum(axis=1)
    cb = popcount32(bx).astype(jnp.int32).sum(axis=1)
    denom = (ca + cb).astype(jnp.float32)
    dice = 2.0 * inter.astype(jnp.float32) / jnp.maximum(denom, 1.0)
    return jnp.where(denom == 0.0, 1.0, dice)


def matcher_ref(ta, tb, la, lb, ga, gb, *, w_title=0.5, w_abstract=0.5,
                threshold=0.75):
    """Full-matcher oracle mirroring ``model.matcher`` semantics."""
    sim_t = levenshtein_similarity_jnp(ta, tb, la, lb)
    sim_g = trigram_dice_jnp(ga, gb)
    score = w_title * sim_t + w_abstract * sim_g
    # Short-circuit accounting: pairs where matcher 1 alone already rules
    # out reaching the threshold even with a perfect matcher-2 score.
    skipped = (w_title * sim_t + w_abstract * 1.0) < threshold
    return score, sim_t, sim_g, skipped.astype(jnp.float32)
