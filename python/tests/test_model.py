"""L2 matcher model: semantics, shapes, and oracle agreement."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import encode, model
from compile.kernels import ref


def encode_pairs(pairs):
    """[(title_a, abs_a, title_b, abs_b)] → model input arrays."""
    ta, la, tb, lb, ga, gb = [], [], [], [], [], []
    for t1, a1, t2, a2 in pairs:
        c, n = encode.encode_title(t1)
        ta.append(c)
        la.append(n)
        c, n = encode.encode_title(t2)
        tb.append(c)
        lb.append(n)
        ga.append(encode.words_as_i32(encode.encode_bitmap(a1)))
        gb.append(encode.words_as_i32(encode.encode_bitmap(a2)))
    return (jnp.array(ta, jnp.int32), jnp.array(tb, jnp.int32),
            jnp.array(la, jnp.int32), jnp.array(lb, jnp.int32),
            jnp.array(ga, jnp.int32), jnp.array(gb, jnp.int32))


PAIRS = [
    # near-duplicate: same paper, minor title typo, same abstract
    ("the merge purge problem for large databases",
     "we present a method for merging large databases efficiently",
     "the merge purge problem for large database",
     "we present a method for merging large databases efficiently"),
    # clear non-match
    ("parallel sorted neighborhood blocking",
     "cloud infrastructures enable parallel entity resolution",
     "quantum chromodynamics on the lattice",
     "we simulate gauge fields with monte carlo methods"),
    # identical
    ("data cleaning problems and current approaches",
     "data quality problems appear in single and multiple sources",
     "data cleaning problems and current approaches",
     "data quality problems appear in single and multiple sources"),
    # same title, different abstract
    ("a survey of entity resolution",
     "this survey covers blocking techniques in depth",
     "a survey of entity resolution",
     "completely different text about unrelated things here"),
]


def test_matcher_outputs_shapes_and_ranges():
    args = encode_pairs(PAIRS)
    score, sim_t, sim_g, skipped = model.matcher(*args)
    for arr in (score, sim_t, sim_g, skipped):
        assert arr.shape == (len(PAIRS),)
        assert arr.dtype == jnp.float32
    s = np.asarray(score)
    assert ((s >= -1e-6) & (s <= 1 + 1e-6)).all()


def test_matcher_decisions():
    args = encode_pairs(PAIRS)
    score, sim_t, sim_g, skipped = (np.asarray(x) for x in
                                    model.matcher(*args))
    # identical pair scores 1.0 and matches
    assert score[2] == pytest.approx(1.0, abs=1e-6)
    # near-duplicate matches
    assert score[0] >= model.THRESHOLD
    # clear non-match fails and is short-circuit-skippable
    assert score[1] < model.THRESHOLD
    assert skipped[1] == 1.0
    # identical pair is never skipped
    assert skipped[2] == 0.0


def test_matcher_agrees_with_oracle():
    args = encode_pairs(PAIRS)
    got = tuple(np.asarray(x) for x in model.matcher(*args))
    want = tuple(np.asarray(x) for x in ref.matcher_ref(*args))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-6)


def test_skipped_pairs_are_nonmatches():
    """The short-circuit predicate must never skip a would-be match."""
    args = encode_pairs(PAIRS)
    score, _, _, skipped = (np.asarray(x) for x in model.matcher(*args))
    assert not ((skipped == 1.0) & (score >= model.THRESHOLD)).any()


def test_title_matcher_is_prefix_of_full():
    args = encode_pairs(PAIRS)
    (sim_t_only,) = model.title_matcher(args[0], args[1], args[2], args[3])
    _, sim_t_full, _, _ = model.matcher(*args)
    np.testing.assert_allclose(np.asarray(sim_t_only),
                               np.asarray(sim_t_full), atol=1e-6)
