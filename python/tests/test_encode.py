"""Encoding spec tests (the executable contract with encode.rs)."""

import pytest
from hypothesis import given, settings, strategies as st

from compile import encode


def test_char_codes():
    assert encode.char_code("a") == 1
    assert encode.char_code("z") == 26
    assert encode.char_code("A") == 1  # case folded
    assert encode.char_code("0") == 27
    assert encode.char_code("9") == 36
    assert encode.char_code(" ") == 37
    assert encode.char_code("!") == 38
    assert encode.char_code("ü") == 38


def test_encode_title_pads_and_truncates():
    codes, n = encode.encode_title("ab")
    assert n == 2
    assert codes[:2] == [1, 2]
    assert codes[2:] == [0] * (encode.TITLE_LEN - 2)
    long = "x" * 100
    codes, n = encode.encode_title(long)
    assert n == encode.TITLE_LEN
    assert len(codes) == encode.TITLE_LEN


def test_fnv1a64_known_vectors():
    # Published FNV-1a 64 test vectors
    assert encode.fnv1a64(b"") == 0xCBF29CE484222325
    assert encode.fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    assert encode.fnv1a64(b"foobar") == 0x85944171F73967E8


def test_normalize_text():
    assert encode.normalize_text("Hello,   World!!") == "hello world"
    assert encode.normalize_text("  a--b  ") == "a b"
    assert encode.normalize_text("...") == ""
    assert encode.normalize_text("Tab\tand\nnewline") == "tab and newline"


def test_trigrams():
    assert encode.trigrams("abcd") == ["abc", "bcd"]
    assert encode.trigrams("ab") == ["ab"]
    assert encode.trigrams("") == []
    assert encode.trigrams("A  B") == ["a b"]


def test_bitmap_determinism_and_popcount():
    w1 = encode.encode_bitmap("some abstract text")
    w2 = encode.encode_bitmap("some abstract text")
    assert w1 == w2
    bits = sum(bin(w & 0xFFFFFFFF).count("1") for w in w1)
    grams = set(encode.trigrams("some abstract text"))
    assert 0 < bits <= len(grams)


def test_words_as_i32_roundtrip():
    words = [0, 1, 0x7FFFFFFF, 0x80000000, 0xFFFFFFFF]
    as_i32 = encode.words_as_i32(words)
    assert as_i32 == [0, 1, 0x7FFFFFFF, -(1 << 31), -1]
    back = [w & 0xFFFFFFFF for w in as_i32]
    assert back == words


@settings(max_examples=50, deadline=None)
@given(st.text(min_size=0, max_size=200))
def test_encode_never_crashes_and_is_stable(s):
    codes, n = encode.encode_title(s)
    assert len(codes) == encode.TITLE_LEN
    assert 0 <= n <= encode.TITLE_LEN
    assert all(0 <= c <= 38 for c in codes)
    assert encode.encode_bitmap(s) == encode.encode_bitmap(s)


def test_golden_generation(tmp_path):
    path = tmp_path / "golden.json"
    encode.gen_golden(str(path))
    import json
    data = json.loads(path.read_text())
    assert data["title_len"] == encode.TITLE_LEN
    assert len(data["cases"]) == len(encode.GOLDEN_STRINGS)
    empty = data["cases"][0]
    assert empty["fnv1a64_hex"] == "cbf29ce484222325"
