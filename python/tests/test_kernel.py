"""Kernel-vs-reference correctness: the CORE L1 signal.

Three-tier validation chain:
  scalar python DP  ⟷  jnp oracle (ref.py)  ⟷  Pallas kernel

plus hypothesis sweeps over shapes, lengths, and alphabets.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    levenshtein_similarity,
    trigram_dice,
    ref,
    TITLE_LEN,
    BITMAP_WORDS,
)
from compile import encode


def enc_batch(strings_a, strings_b):
    """Encode two lists of strings into kernel input arrays."""
    assert len(strings_a) == len(strings_b)
    ta, la, tb, lb = [], [], [], []
    for a, b in zip(strings_a, strings_b):
        ca, na = encode.encode_title(a)
        cb, nb = encode.encode_title(b)
        ta.append(ca)
        la.append(na)
        tb.append(cb)
        lb.append(nb)
    return (
        jnp.array(ta, jnp.int32),
        jnp.array(tb, jnp.int32),
        jnp.array(la, jnp.int32),
        jnp.array(lb, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Tier 1: jnp oracle vs textbook scalar DP
# ---------------------------------------------------------------------------

KNOWN_DISTANCES = [
    ("", "", 0),
    ("a", "", 1),
    ("", "abc", 3),
    ("kitten", "sitting", 3),
    ("flaw", "lawn", 2),
    ("intention", "execution", 5),
    ("abc", "abc", 0),
    ("abc", "acb", 2),
    ("sorted neighborhood", "sorted neighbourhood", 1),
]


@pytest.mark.parametrize("a,b,d", KNOWN_DISTANCES)
def test_scalar_dp_known_distances(a, b, d):
    assert ref.levenshtein_py(a, b) == d


@pytest.mark.parametrize("a,b,d", KNOWN_DISTANCES)
def test_jnp_oracle_matches_scalar(a, b, d):
    ta, tb, la, lb = enc_batch([a], [b])
    sim = np.asarray(ref.levenshtein_similarity_jnp(ta, tb, la, lb))[0]
    m = max(len(a), len(b))
    expect = 1.0 if m == 0 else 1.0 - d / m
    assert sim == pytest.approx(expect, abs=1e-6)


# ---------------------------------------------------------------------------
# Tier 2: Pallas kernel vs jnp oracle
# ---------------------------------------------------------------------------

def test_kernel_matches_oracle_fixed_batch():
    strings = [a for a, _, _ in KNOWN_DISTANCES]
    others = [b for _, b, _ in KNOWN_DISTANCES]
    # pad batch to 16 with self-pairs
    while len(strings) < 16:
        strings.append("padding title xyz")
        others.append("padding title xyz")
    ta, tb, la, lb = enc_batch(strings, others)
    got = np.asarray(levenshtein_similarity(ta, tb, la, lb, block_b=8))
    want = np.asarray(ref.levenshtein_similarity_jnp(ta, tb, la, lb))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_kernel_self_similarity_is_one():
    strings = ["alpha beta", "x", "", "some very long title " * 3]
    ta, tb, la, lb = enc_batch(strings, strings)
    got = np.asarray(levenshtein_similarity(ta, tb, la, lb, block_b=4))
    np.testing.assert_allclose(got, np.ones(4), atol=1e-6)


text_strategy = st.text(
    alphabet=st.sampled_from("abcdefgh 0123!?"), min_size=0, max_size=TITLE_LEN
)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(text_strategy, text_strategy),
                min_size=1, max_size=12))
def test_kernel_hypothesis_sweep(pairs):
    sa = [p[0] for p in pairs]
    sb = [p[1] for p in pairs]
    ta, tb, la, lb = enc_batch(sa, sb)
    got = np.asarray(levenshtein_similarity(ta, tb, la, lb, block_b=len(sa)))
    # compare against the scalar DP on the *encoded* sequences (encoding is
    # lossy: case folding + 'other' buckets), not the raw strings
    for i, (a, b) in enumerate(zip(sa, sb)):
        ca = [encode.char_code(c) for c in a[:TITLE_LEN]]
        cb = [encode.char_code(c) for c in b[:TITLE_LEN]]
        m = max(len(ca), len(cb))
        want = 1.0 if m == 0 else 1.0 - ref.levenshtein_py(ca, cb) / m
        assert got[i] == pytest.approx(want, abs=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=33),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_random_codes_any_batch(bsz, seed):
    """Shape sweep with raw random code arrays (no string path)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 39, size=(bsz, TITLE_LEN)).astype(np.int32)
    b = rng.integers(1, 39, size=(bsz, TITLE_LEN)).astype(np.int32)
    la = rng.integers(0, TITLE_LEN + 1, size=bsz).astype(np.int32)
    lb = rng.integers(0, TITLE_LEN + 1, size=bsz).astype(np.int32)
    got = np.asarray(levenshtein_similarity(
        jnp.array(a), jnp.array(b), jnp.array(la), jnp.array(lb),
        block_b=bsz))
    want = np.asarray(ref.levenshtein_similarity_jnp(
        jnp.array(a), jnp.array(b), jnp.array(la), jnp.array(lb)))
    np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# Trigram kernel
# ---------------------------------------------------------------------------

def bitmaps(strings):
    return jnp.array(
        [encode.words_as_i32(encode.encode_bitmap(s)) for s in strings],
        jnp.int32,
    )


def test_trigram_identical_is_one():
    s = ["the quick brown fox jumps over the lazy dog", "a b c", ""]
    a = bitmaps(s)
    got = np.asarray(trigram_dice(a, a, block_b=3))
    np.testing.assert_allclose(got, np.ones(3), atol=1e-6)


def test_trigram_disjoint_is_zero():
    a = bitmaps(["aaaa aaaa aaaa"])
    b = bitmaps(["zzzz zzzz zzzz"])
    got = np.asarray(trigram_dice(a, b, block_b=1))
    assert got[0] == pytest.approx(0.0, abs=1e-6)


def test_trigram_kernel_matches_oracle():
    sa = ["data cleaning problems", "entity resolution survey",
          "mapreduce simplified data processing", ""]
    sb = ["data cleaning approaches", "entity matching survey",
          "hadoop distributed file system", "x"]
    a, b = bitmaps(sa), bitmaps(sb)
    got = np.asarray(trigram_dice(a, b, block_b=4))
    want = np.asarray(ref.trigram_dice_jnp(a, b))
    np.testing.assert_allclose(got, want, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=17),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_trigram_hypothesis_random_bitmaps(bsz, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-2**31, 2**31, size=(bsz, BITMAP_WORDS),
                     dtype=np.int64).astype(np.int32)
    b = rng.integers(-2**31, 2**31, size=(bsz, BITMAP_WORDS),
                     dtype=np.int64).astype(np.int32)
    got = np.asarray(trigram_dice(jnp.array(a), jnp.array(b), block_b=bsz))
    want = np.asarray(ref.trigram_dice_jnp(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_trigram_dice_against_exact_sets():
    """Bitmap Dice approximates exact trigram-set Dice closely."""
    sa = "efficient parallel set similarity joins using mapreduce"
    sb = "efficient parallel set similarity joins with mapreduce"
    ga, gb = set(encode.trigrams(sa)), set(encode.trigrams(sb))
    exact = 2 * len(ga & gb) / (len(ga) + len(gb))
    a, b = bitmaps([sa]), bitmaps([sb])
    got = float(np.asarray(trigram_dice(a, b, block_b=1))[0])
    assert got == pytest.approx(exact, abs=0.02)
