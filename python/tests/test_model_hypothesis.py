"""Hypothesis sweep of the full L2 matcher model against the oracle.

Random raw tensor inputs (not just string-derived ones): arbitrary code
arrays, lengths and bitmaps — the model must agree with ``matcher_ref``
on every output, and its invariants (score decomposition, skip predicate
soundness) must hold for all inputs.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, TITLE_LEN, BITMAP_WORDS


def random_inputs(rng, bsz):
    ta = rng.integers(0, 39, size=(bsz, TITLE_LEN)).astype(np.int32)
    tb = rng.integers(0, 39, size=(bsz, TITLE_LEN)).astype(np.int32)
    la = rng.integers(0, TITLE_LEN + 1, size=bsz).astype(np.int32)
    lb = rng.integers(0, TITLE_LEN + 1, size=bsz).astype(np.int32)
    ga = rng.integers(-2**31, 2**31, size=(bsz, BITMAP_WORDS),
                      dtype=np.int64).astype(np.int32)
    gb = rng.integers(-2**31, 2**31, size=(bsz, BITMAP_WORDS),
                      dtype=np.int64).astype(np.int32)
    return tuple(jnp.array(x) for x in (ta, tb, la, lb, ga, gb))


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_model_matches_oracle_on_random_tensors(bsz, seed):
    args = random_inputs(np.random.default_rng(seed), bsz)
    got = tuple(np.asarray(x) for x in model.matcher(*args))
    want = tuple(np.asarray(x) for x in ref.matcher_ref(*args))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_model_invariants(bsz, seed):
    args = random_inputs(np.random.default_rng(seed), bsz)
    score, sim_t, sim_g, skipped = (np.asarray(x) for x in
                                    model.matcher(*args))
    # score decomposition
    np.testing.assert_allclose(
        score, model.W_TITLE * sim_t + model.W_ABSTRACT * sim_g, atol=1e-6)
    # similarity ranges
    for arr in (sim_t, sim_g):
        assert (arr >= -1e-6).all() and (arr <= 1 + 1e-6).all()
    # skip predicate soundness: a skipped pair can never be a match
    assert not ((skipped == 1.0) & (score >= model.THRESHOLD)).any()
    # skip predicate definition
    expect_skip = (model.W_TITLE * sim_t + model.W_ABSTRACT) < model.THRESHOLD
    np.testing.assert_array_equal(skipped == 1.0, expect_skip)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_model_symmetry(seed):
    """matcher(a, b) == matcher(b, a) on every output."""
    ta, tb, la, lb, ga, gb = random_inputs(np.random.default_rng(seed), 6)
    fwd = tuple(np.asarray(x) for x in model.matcher(ta, tb, la, lb, ga, gb))
    rev = tuple(np.asarray(x) for x in model.matcher(tb, ta, lb, la, gb, ga))
    for f, r in zip(fwd, rev):
        np.testing.assert_allclose(f, r, atol=1e-6)
