"""AOT lowering smoke tests: HLO text is produced and structurally sound."""

import json

import pytest

from compile import aot


@pytest.fixture(scope="module")
def hlo_b64():
    return aot.lower_matcher(64)


def test_lowering_produces_hlo_text(hlo_b64):
    assert "HloModule" in hlo_b64
    assert "ENTRY" in hlo_b64
    # 6 parameters: ta, tb, la, lb, ga, gb
    assert hlo_b64.count("parameter(") >= 6


def test_lowering_batch_shape_in_entry(hlo_b64):
    # title operands show up with the requested batch size
    assert "s32[64,64]" in hlo_b64
    # the root is a tuple of four f32[64] outputs (return_tuple=True)
    assert "f32[64]" in hlo_b64


def test_title_matcher_lowering():
    text = aot.lower_title_matcher(64)
    assert "HloModule" in text
    assert "s32[64,64]" in text


def test_manifest_contents(tmp_path):
    m = aot.build_manifest([64, 256])
    assert m["title_len"] == 64
    assert m["bitmap_words"] == 64
    assert m["threshold"] == 0.75
    assert [v["batch"] for v in m["variants"]] == [64, 256]
    # round-trips as json
    s = json.dumps(m)
    assert json.loads(s) == m


def test_no_custom_calls_in_hlo(hlo_b64):
    """interpret=True must lower pallas to plain HLO (no Mosaic)."""
    assert "custom-call" not in hlo_b64 or "mosaic" not in hlo_b64.lower()
