//! Multi-pass Sorted Neighborhood (§4's robustness extension): run RepSN
//! twice with different blocking keys and union the results — dirty title
//! prefixes no longer doom recall.
//!
//! The passes are independent MapReduce jobs; `multipass::run` submits
//! them all to one shared `JobScheduler` (`workers` map/reduce slots), so
//! their task waves interleave instead of running job-at-a-time.
//!
//! ```bash
//! cargo run --release --example multipass_dedup -- --n 10000
//! ```

use std::sync::Arc;

use snmr::data::corpus::{generate, CorpusConfig};
use snmr::data::noise::NoiseConfig;
use snmr::er::blockkey::{BlockingKey, TitlePrefixKey, TitleSuffixKey};
use snmr::er::quality::Quality;
use snmr::er::strategy::MatchStrategyConfig;
use snmr::sn::multipass;
use snmr::sn::partition::RangePartition;
use snmr::sn::types::{SnConfig, SnMode};
use snmr::util::cli::{flag, Args};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[flag("n", "corpus size (default 10000)")], false)
        .map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 10_000).map_err(anyhow::Error::msg)?;

    // extra-dirty corpus: more first-word typos → prefix key suffers
    let corpus = generate(&CorpusConfig {
        n_entities: n,
        dup_fraction: 0.15,
        noise: NoiseConfig {
            title_edits: 3.0,
            ..Default::default()
        },
        seed: 0xD1127,
        ..Default::default()
    });
    let truth = corpus.truth_pairs();
    println!(
        "corpus: {} entities, {} truth pairs (dirty titles)",
        corpus.entities.len(),
        truth.len()
    );

    let prefix = TitlePrefixKey::new(2);
    let base = SnConfig {
        window: 10,
        num_map_tasks: 8,
        workers: 2,
        partitioner: Arc::new(RangePartition::balanced(
            &corpus.entities,
            |e| prefix.key(e),
            10,
        )),
        blocking_key: Arc::new(TitlePrefixKey::new(2)),
        mode: SnMode::Matching(MatchStrategyConfig::default()),
        sort_buffer_records: None,
        balance: Default::default(),
        spill: None,
        push: false,
        faults: None,
        max_task_retries: None,
        trace: None,
        memory: None,
    };
    let keys: Vec<Arc<dyn BlockingKey>> = vec![
        Arc::new(TitlePrefixKey::new(2)),
        Arc::new(TitleSuffixKey),
    ];
    let res = multipass::run(&corpus.entities, &base, &keys)?;

    for (i, (pass, newly)) in res.per_pass.iter().zip(&res.new_per_pass).enumerate() {
        let predicted: Vec<_> = pass.matches.iter().map(|m| m.pair).collect();
        let q = Quality::evaluate(&predicted, &truth);
        println!(
            "pass {} ({}): {} matches ({} new)  P {:.3}  R {:.3}",
            i + 1,
            keys[i].name(),
            pass.matches.len(),
            newly,
            q.precision(),
            q.recall()
        );
    }
    let predicted: Vec<_> = res.union.matches.iter().map(|m| m.pair).collect();
    let q = Quality::evaluate(&predicted, &truth);
    println!(
        "union: {} matches  P {:.3}  R {:.3}  F1 {:.3}",
        predicted.len(),
        q.precision(),
        q.recall(),
        q.f1()
    );
    println!("\nExpected: union recall > each single pass (multi-pass SN, §4).");
    Ok(())
}
