//! Data-skew study (§5.3): reproduce Table 1 and the shape of
//! Figures 9/10 at example scale.
//!
//! Builds the paper's partition-function ladder (Manual, Even10, Even8,
//! Even8_40 … Even8_85), measures the Gini coefficient of the resulting
//! partition sizes, runs RepSN (w = 100, m = r-slots = 8) and reports both
//! measured single-core runtimes and simulated 8-core cluster times.
//!
//! The Manual partitioner's key histogram is computed as a MapReduce job
//! with a map-side combiner (`sn::balance::key_histogram_job`) — the
//! analysis job the paper's "manually defined" partitioning implies,
//! exercising the combiner on real SN data.
//!
//! With `--speculative`, every ladder configuration is additionally
//! re-submitted to one shared `JobScheduler` with speculative execution
//! enabled: all jobs run concurrently on 4 map/reduce slots, outputs are
//! checked identical to the serial runs, and the straggler-cloning
//! counters are reported next to simulated slow-node makespans.
//!
//! With `--balance blocksplit|pairrange`, a Zipf *block-key*-skewed copy
//! of the corpus (giant blocks no key-range partitioner can split) is run
//! through unbalanced RepSN and the chosen `sn::loadbalance` strategy:
//! outputs are asserted identical and the max-reduce-task pair counts are
//! reported side by side — the load-balancing smoke test CI runs.
//!
//! With `--sort-buffer N`, every ladder configuration is additionally
//! re-run **disk-backed**: sealed map-side runs spill through the codec
//! layer into DEFLATE-compressed run files under a temp spill dir, the
//! pair digests are asserted identical to the in-memory runs, and the
//! compressed-vs-raw shuffle ratio is reported — the spill smoke test CI
//! runs.
//!
//! With `--faults`, every ladder configuration is re-run on a 4-slot
//! `JobScheduler` with an **injected task panic** (`FaultPlan::seeded`
//! kills the first attempt of one deterministically drawn task per job)
//! and a retry budget of 2.  Rows alternate between the barrier and the
//! push shuffle so both recovery paths are exercised; pair digests are
//! asserted identical to the clean serial runs, and `TASK_RETRIES` must
//! be positive across the ladder — the fault smoke test CI runs.
//!
//! With `--push`, every ladder configuration is re-run on a 4-slot
//! `JobScheduler` with the **push-based shuffle**: reduce tasks start on
//! their first runs instead of after the map wave.  Pair digests are
//! asserted identical to the serial barrier runs, and
//! `reduce_first_start_secs` must strictly precede the last map-task
//! completion (`overlap_secs > 0`) on every ladder row — the push smoke
//! test CI runs.
//!
//! With `--executors N`, every ladder configuration is re-run on the
//! **message-passing control plane** (`DistScheduler`): a scheduler
//! event loop drives N channel-transport executors and reduce tasks
//! fetch map runs by `(executor, run id)` location from the shuffle
//! registry.  The flag composes with `--push` (location-addressed push
//! shuffle) and `--faults` (seeded task panics + retry budget); one
//! mid-ladder row additionally **kills an executor** after its first
//! completed map task and must finish via loss resubmission.  Pair
//! digests are asserted identical to the serial runs and no task may
//! exhaust its retry budget — the dist smoke test CI runs.
//!
//! With `--trace DIR`, every ladder row records the full task-event
//! stream (`mapreduce::trace`): per row, the raw events land in
//! `DIR/<row>.trace.jsonl`, the reconstructed per-slot timeline in
//! `DIR/<row>.timeline.json`, the rendered Gantt in `DIR/<row>.gantt.txt`,
//! and a simulated-vs-measured drift report in `DIR/<row>.drift.json` —
//! the trace smoke test CI runs.  The last row's Gantt and drift table are
//! printed.
//!
//! With `--metrics DIR`, every ladder row is re-run on a 4-slot push
//! scheduler with the live `HealthSampler` attached
//! (`metrics::registry`): the snapshot ring lands in
//! `DIR/<row>.snapshots.jsonl`, the last row's rendered dashboard in
//! `DIR/dashboard.txt`.  Pair digests are asserted identical to the
//! serial runs, and the sampler must have caught nonzero slot occupancy
//! and mailbox depth on every row — the metrics smoke test CI runs.
//!
//! ```bash
//! cargo run --release --example skew_study -- --n 20000
//! cargo run --release --example skew_study -- --n 2000 --window 20 --trace /tmp/skew-traces
//! cargo run --release --example skew_study -- --n 2000 --window 20 --metrics /tmp/skew-metrics
//! cargo run --release --example skew_study -- --n 2000 --window 20 --speculative
//! cargo run --release --example skew_study -- --n 2000 --window 20 --balance blocksplit
//! cargo run --release --example skew_study -- --n 2000 --window 20 --sort-buffer 64
//! cargo run --release --example skew_study -- --n 2000 --window 20 --push
//! cargo run --release --example skew_study -- --n 2000 --window 20 --faults
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use snmr::data::corpus::{generate, CorpusConfig};
use snmr::data::skew::{skew_to_last_partition, zipf_skew_block_keys};
use snmr::er::blockkey::{BlockingKey, TitlePrefixKey};
use snmr::mapreduce::counters::names;
use snmr::mapreduce::scheduler::{
    DistConfig, DistScheduler, Exec, JobScheduler, KillPlan, PushMode, SchedulerConfig,
};
use snmr::mapreduce::sim::{
    drift_report, simulate_job, simulate_job_chain, simulate_job_overlap, ClusterSpec,
};
use snmr::mapreduce::{FaultPlan, MemoryPool, TempSpillDir, TraceSpec};
use snmr::metrics::registry::MetricsSpec;
use snmr::metrics::report::{write_report, Table};
use snmr::metrics::timeline::JobTimeline;
use snmr::sn::balance::{balanced_from_histogram, key_histogram_job, pair_balanced_min_size};
use snmr::sn::loadbalance::{counter_names as balance_counters, reduce_pair_skew, BalanceStrategy};
use snmr::sn::partition::{gini, partition_sizes, EvenPartition, PartitionFn};
use snmr::sn::repsn;
use snmr::sn::types::{SnConfig, SnMode, SnResult, SnSpill};
use snmr::util::cli::{flag, switch, Args};
use snmr::util::json::Json;

/// Order-independent digest of a result's pair set (length + FNV-1a over
/// the sorted pair ids) — lets us verify scheduler runs produce identical
/// output without keeping every serial pair set in memory.
fn pair_digest(res: &SnResult) -> (usize, u64) {
    let pairs = res.pair_set();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for p in &pairs {
        for part in [p.a, p.b] {
            for b in part.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        }
    }
    (pairs.len(), h)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(
        &[
            flag("n", "corpus size (default 20000)"),
            flag("window", "SN window (default 100)"),
            switch(
                "speculative",
                "re-run the ladder concurrently on a shared scheduler with speculation",
            ),
            switch(
                "push",
                "re-run the ladder on a 4-slot scheduler with the push-based shuffle",
            ),
            switch(
                "faults",
                "re-run the ladder under injected task panics with retries enabled",
            ),
            flag(
                "executors",
                "re-run the ladder on the message-passing control plane with this many \
                 executors (composes with --push/--faults; one row kills an executor)",
            ),
            flag(
                "balance",
                "also run the load-balancing study with this strategy (blocksplit|pairrange)",
            ),
            flag(
                "sort-buffer",
                "also re-run the ladder disk-backed + compressed with this sort budget",
            ),
            flag(
                "trace",
                "record task-event traces: per ladder row, write <row>.trace.jsonl, \
                 <row>.timeline.json, <row>.gantt.txt and <row>.drift.json into this directory",
            ),
            flag(
                "metrics",
                "re-run the ladder on a 4-slot push scheduler with the health sampler \
                 attached: write <row>.snapshots.jsonl and dashboard.txt into this directory",
            ),
            flag(
                "pool-bytes",
                "re-run the ladder with every job accounting against one shared memory \
                 pool of this many bytes (composes with --push/--executors)",
            ),
        ],
        false,
    )
    .map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 20_000).map_err(anyhow::Error::msg)?;
    let window = args.get_usize("window", 100).map_err(anyhow::Error::msg)?;
    let speculative = args.get_bool("speculative");
    let push = args.get_bool("push");
    let faults = args.get_bool("faults");
    let executors = match args.get("executors") {
        None => None,
        Some(_) => Some(args.get_usize("executors", 4).map_err(anyhow::Error::msg)?.max(2)),
    };
    let sort_buffer = match args.get("sort-buffer") {
        None => None,
        Some(_) => Some(args.get_usize("sort-buffer", 64).map_err(anyhow::Error::msg)?),
    };
    let trace_dir = args.get("trace").map(std::path::PathBuf::from);
    if let Some(dir) = &trace_dir {
        std::fs::create_dir_all(dir)?;
    }
    let metrics_dir = args.get("metrics").map(std::path::PathBuf::from);
    if let Some(dir) = &metrics_dir {
        std::fs::create_dir_all(dir)?;
    }
    let pool_bytes = match args.get("pool-bytes") {
        None => None,
        Some(_) => Some(args.get_usize("pool-bytes", 1 << 20).map_err(anyhow::Error::msg)?.max(1)),
    };
    let balance = match args.get("balance") {
        None => None,
        Some(s) => Some(
            BalanceStrategy::parse(s)
                .filter(|b| *b != BalanceStrategy::None)
                .ok_or_else(|| anyhow::anyhow!("--balance must be blocksplit or pairrange"))?,
        ),
    };

    let corpus = generate(&CorpusConfig {
        n_entities: n,
        seed: 0x5EED5,
        ..Default::default()
    });
    let bk = TitlePrefixKey::new(2);
    let bk_dyn: Arc<dyn BlockingKey> = Arc::new(TitlePrefixKey::new(2));

    // Manual partitioner from the combiner-powered key-histogram job
    // (instead of a driver-side sort of all keys)
    let (hist, hist_counters) = key_histogram_job(&corpus.entities, &bk_dyn, 8, 2);
    let manual = balanced_from_histogram(&hist, 10);
    println!(
        "key-histogram job: {} distinct keys; combiner {} -> {} records \
         (shuffle {} bytes)\n",
        hist.len(),
        hist_counters.get(names::COMBINE_INPUT_RECORDS),
        hist_counters.get(names::COMBINE_OUTPUT_RECORDS),
        hist_counters.get(names::SHUFFLE_BYTES),
    );

    // partition-function ladder (paper Table 1)
    let mut configs: Vec<(String, Arc<dyn PartitionFn>, Vec<snmr::er::Entity>)> = vec![
        ("Manual".into(), Arc::new(manual), corpus.entities.clone()),
        (
            "Even10".into(),
            Arc::new(EvenPartition::ascii(10)),
            corpus.entities.clone(),
        ),
        (
            "Even8".into(),
            Arc::new(EvenPartition::ascii(8)),
            corpus.entities.clone(),
        ),
    ];
    for pct in [40, 55, 70, 85] {
        let p = EvenPartition::ascii(8);
        let mut entities = corpus.entities.clone();
        skew_to_last_partition(&mut entities, &bk, &p, pct as f64 / 100.0, 0xBAD5EED);
        configs.push((format!("Even8_{pct}"), Arc::new(p), entities));
    }

    let sn_cfg = |p: &Arc<dyn PartitionFn>| SnConfig {
        window,
        num_map_tasks: 8,
        workers: 1, // clean per-task timings for the simulator
        partitioner: Arc::clone(p),
        blocking_key: Arc::new(TitlePrefixKey::new(2)),
        mode: SnMode::Blocking,
        sort_buffer_records: None,
        balance: Default::default(),
        spill: None,
        push: false,
        faults: None,
        max_task_retries: None,
        trace: None,
        memory: None,
    };

    let mut table = Table::new(
        "Table 1 + Fig 9/10: skew ladder, RepSN blocking (w, m=8, slots=8)",
        &["p", "gini", "comparisons", "wall_1core_s", "sim_8core_s"],
    );
    let mut digests = Vec::new();
    let mut serial_profiles = Vec::new();
    let last_row = configs.len() - 1;
    for (row, (name, p, entities)) in configs.iter().enumerate() {
        let sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), p.as_ref());
        let g = gini(&sizes);
        let mut cfg = sn_cfg(p);
        // one fresh sink per row, so each JSONL artifact is self-contained
        let spec = trace_dir.as_ref().map(|_| TraceSpec::new());
        cfg.trace = spec.clone();
        let t0 = Instant::now();
        let res = repsn::run(entities, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let (_, sim8) = simulate_job_chain(&res.profiles, &ClusterSpec::paper_like(8));
        table.row(vec![
            name.clone(),
            format!("{g:.2}"),
            res.counters.get("sn.window_comparisons").to_string(),
            format!("{wall:.2}"),
            format!("{sim8:.1}"),
        ]);
        if let (Some(dir), Some(spec)) = (&trace_dir, &spec) {
            let records = spec.drain();
            std::fs::write(
                dir.join(format!("{name}.trace.jsonl")),
                TraceSpec::to_jsonl(&records),
            )?;
            let timelines: Vec<JobTimeline> = JobTimeline::jobs(&records)
                .iter()
                .map(|j| JobTimeline::from_records(j, &records))
                .collect();
            let tl_json = Json::obj(vec![
                ("row", Json::str(name.as_str())),
                (
                    "jobs",
                    Json::Arr(timelines.iter().map(JobTimeline::to_json).collect()),
                ),
            ]);
            std::fs::write(dir.join(format!("{name}.timeline.json")), tl_json.to_string())?;
            let gantt: String = timelines.iter().map(|t| t.render_gantt(72)).collect();
            std::fs::write(dir.join(format!("{name}.gantt.txt")), &gantt)?;
            // drift: measured workers=1 stats vs the same profile simulated
            // on a matching 1-slot cluster — cost-model error, not
            // parallelism mismatch
            let drift = drift_report(
                &res.stats[0],
                res.profiles[0].map_output_bytes,
                &ClusterSpec::paper_like(1),
            );
            std::fs::write(dir.join(format!("{name}.drift.json")), drift.to_json())?;
            if row == last_row {
                println!("--- {name}: reconstructed timeline ---");
                print!("{gantt}");
                print!("{}", drift.render());
                println!("trace artifacts for all rows in {}\n", dir.display());
            }
        }
        digests.push(pair_digest(&res));
        serial_profiles.push(res.profiles.clone());
    }
    println!("{}", table.render());
    let path = write_report(
        "skew_study",
        &Json::obj(vec![("n", Json::num(n as f64)), ("rows", table.to_json())]),
    )?;
    println!("report written to {}", path.display());
    println!(
        "\nExpected shape (paper §5.3): Manual fastest; runtime grows with\n\
         gini; Even8_85 ≈ 3× Manual on the simulated 8-core cluster."
    );

    if speculative {
        // every ladder job in flight on one shared scheduler shaped like a
        // small simulated cluster (2 nodes × 2 slots), straggler cloning on
        println!("\n--- concurrent re-run: shared JobScheduler, speculation on ---");
        let cluster = ClusterSpec::paper_like(4).with_speculation(true);
        let sched = JobScheduler::new(SchedulerConfig::from_cluster(&cluster));
        let t0 = Instant::now();
        let pending: Vec<_> = configs
            .iter()
            .map(|(_, p, entities)| repsn::submit(entities, &sn_cfg(p), &sched))
            .collect();
        let results: Vec<SnResult> = pending
            .into_iter()
            .map(|h| h.join())
            .collect::<anyhow::Result<_>>()?;
        let wall = t0.elapsed().as_secs_f64();
        let mut t2 = Table::new(
            &format!(
                "Concurrent ladder ({} shared map slots, speculative)",
                sched.map_slots()
            ),
            &["p", "identical", "spec_launched", "spec_won", "sim8_slow_node_s"],
        );
        let slow_spec = ClusterSpec::paper_like(8)
            .with_slow_nodes(1, 3.0)
            .with_speculation(true);
        for (((name, _, _), res), (digest, profiles)) in configs
            .iter()
            .zip(&results)
            .zip(digests.iter().zip(&serial_profiles))
        {
            let identical = pair_digest(res) == *digest;
            assert!(identical, "{name}: concurrent output diverged from serial");
            // simulate from the *serial* workers=1 profiles — the
            // concurrent run's task timings include slot contention and
            // would mislead the simulator
            let (_, sim_slow) = simulate_job_chain(profiles, &slow_spec);
            t2.row(vec![
                name.clone(),
                identical.to_string(),
                res.counters.get(names::SPECULATIVE_LAUNCHED).to_string(),
                res.counters.get(names::SPECULATIVE_WON).to_string(),
                format!("{sim_slow:.1}"),
            ]);
        }
        println!("{}", t2.render());
        println!(
            "all {} jobs concurrently in {wall:.2}s wall; outputs identical to serial.",
            configs.len()
        );
    }

    if push {
        // Push-based shuffle re-run: every ladder configuration on a
        // 4-slot scheduler with run-granular reduce scheduling.  Output
        // digests must match the serial barrier runs exactly, and the
        // first reduce task must start strictly before the map wave ends.
        println!("\n--- push-based shuffle re-run: 4-slot scheduler, run-granular flow ---");
        let sched = JobScheduler::new(SchedulerConfig::slots(4).with_push(PushMode::Push));
        let spec8 = ClusterSpec::paper_like(8);
        let mut t5 = Table::new(
            "Push ladder (4 shared slots): reduce starts on first runs",
            &[
                "p",
                "identical",
                "first_reduce_s",
                "map_done_s",
                "overlap_s",
                "pushed_runs",
                "sim8_push/barrier",
            ],
        );
        for (((name, p, entities), digest), profiles) in
            configs.iter().zip(&digests).zip(&serial_profiles)
        {
            // many map tasks → many map waves on 4 slots, so the first
            // committed run precedes the wave end by a wide margin (the
            // pair *set* is invariant to the map task count)
            let mut cfg = sn_cfg(p);
            cfg.num_map_tasks = 32;
            // wall-clock overlap is scheduling-sensitive on loaded CI
            // runners: allow a couple of retries before calling it a
            // regression
            let mut res = repsn::run_on(entities, &cfg, Exec::Scheduler(&sched))?;
            for _retry in 0..2 {
                if res.stats[0].overlap_secs > 0.0 {
                    break;
                }
                res = repsn::run_on(entities, &cfg, Exec::Scheduler(&sched))?;
            }
            let identical = pair_digest(&res) == *digest;
            assert!(identical, "{name}: push output diverged from the barrier run");
            let stats = &res.stats[0];
            assert!(
                stats.overlap_secs > 0.0,
                "{name}: push run showed no map/reduce overlap \
                 (first reduce {:.4}s, map done {:.4}s)",
                stats.reduce_first_start_secs,
                stats.map_wave_done_secs
            );
            // simulated 8-core makespans from the serial workers=1
            // profiles: the overlap mode must never exceed the barrier
            let barrier_sim: f64 = profiles
                .iter()
                .map(|pr| simulate_job(pr, &spec8).total())
                .sum();
            let push_sim: f64 = profiles
                .iter()
                .map(|pr| simulate_job_overlap(pr, &spec8).total())
                .sum();
            assert!(
                push_sim <= barrier_sim + 1e-9,
                "{name}: simulated push makespan {push_sim:.2}s exceeds barrier {barrier_sim:.2}s"
            );
            t5.row(vec![
                name.clone(),
                identical.to_string(),
                format!("{:.4}", stats.reduce_first_start_secs),
                format!("{:.4}", stats.map_wave_done_secs),
                format!("{:.4}", stats.overlap_secs),
                res.counters.get(names::PUSHED_RUNS).to_string(),
                format!("{:.3}", push_sim / barrier_sim.max(1e-12)),
            ]);
        }
        println!("{}", t5.render());
        println!(
            "all ladder runs pushed: outputs identical to the barrier digests,\n\
             every first reduce start preceded its map wave's completion."
        );
    }

    if let Some(dir) = &metrics_dir {
        // Live-telemetry re-run: every ladder configuration on a 4-slot
        // push scheduler with the health sampler attached.  The sampler
        // must catch nonzero slot occupancy and mailbox depth on every
        // row; pair digests must match the serial runs; per-row snapshot
        // rings land as JSONL plus the last row's rendered dashboard —
        // the metrics smoke test CI runs.
        println!("\n--- live telemetry re-run: 4-slot push scheduler, health sampler on ---");
        let mut t7 = Table::new(
            "Metrics ladder (4 shared slots, push shuffle, 500µs sampler)",
            &["p", "identical", "snapshots", "peak_running", "peak_mailbox_runs", "dead_letters"],
        );
        let mut last_dashboard = String::new();
        for ((name, p, entities), digest) in configs.iter().zip(&digests) {
            let mut cfg = sn_cfg(p);
            // many map waves on 4 slots keep the slots and mailboxes busy
            // long enough for the sampler to observe them
            cfg.num_map_tasks = 32;
            // sampler timing is scheduling-sensitive on loaded CI runners:
            // allow a few fresh attempts before calling it a regression
            let mut attempt = 0;
            let (spec, res) = loop {
                let spec = MetricsSpec::new()
                    .with_cadence(Duration::from_micros(500))
                    .with_ring_capacity(65_536);
                let sched = JobScheduler::new(
                    SchedulerConfig::slots(4)
                        .with_push(PushMode::Push)
                        .with_metrics(spec.clone()),
                );
                let res = repsn::run_on(entities, &cfg, Exec::Scheduler(&sched))?;
                // one final explicit sample so every JSONL ends quiescent
                sched.sample_metrics_now();
                let snaps = spec.snapshots();
                let busy = snaps.iter().any(|s| s.map_running + s.reduce_running > 0);
                let fed = snaps.iter().any(|s| s.mailbox_runs > 0 || s.staged_bytes > 0);
                if (busy && fed) || attempt >= 3 {
                    break (spec, res);
                }
                attempt += 1;
            };
            let identical = pair_digest(&res) == *digest;
            assert!(identical, "{name}: metrics re-run output diverged from serial");
            let snaps = spec.snapshots();
            assert!(
                snaps.iter().any(|s| s.map_running + s.reduce_running > 0),
                "{name}: sampler never observed an occupied slot"
            );
            assert!(
                snaps.iter().any(|s| s.mailbox_runs > 0 || s.staged_bytes > 0),
                "{name}: sampler never observed mailbox depth"
            );
            std::fs::write(
                dir.join(format!("{name}.snapshots.jsonl")),
                spec.snapshots_jsonl(),
            )?;
            last_dashboard = spec.render_dashboard();
            t7.row(vec![
                name.clone(),
                identical.to_string(),
                snaps.len().to_string(),
                snaps.iter().map(|s| s.tasks_running).max().unwrap_or(0).to_string(),
                snaps.iter().map(|s| s.mailbox_runs).max().unwrap_or(0).to_string(),
                snaps.last().map(|s| s.dead_letters).unwrap_or(0).to_string(),
            ]);
        }
        std::fs::write(dir.join("dashboard.txt"), &last_dashboard)?;
        println!("{}", t7.render());
        print!("{last_dashboard}");
        println!(
            "all ladder runs sampled live: outputs identical to serial,\n\
             snapshot artifacts in {}",
            dir.display()
        );
    }

    if faults {
        // Fault-injection re-run: one deterministic task panic per ladder
        // job, recovered by the scheduler's bounded retry.  Rows alternate
        // between the barrier and the push shuffle so both recovery paths
        // (wave resubmission vs staged-attempt retraction + re-pull) are
        // exercised; output digests must match the clean serial runs.
        println!("\n--- fault-injection re-run: 4-slot scheduler, injected panics + retry ---");
        let barrier_sched = JobScheduler::new(SchedulerConfig::slots(4));
        let push_sched = JobScheduler::new(SchedulerConfig::slots(4).with_push(PushMode::Push));
        let mut t6 = Table::new(
            "Fault ladder (4 shared slots): seeded panic, retry budget 2",
            &["p", "mode", "identical", "task_retries", "tasks_failed"],
        );
        let mut total_retries = 0u64;
        for (i, ((name, p, entities), digest)) in
            configs.iter().zip(&digests).enumerate()
        {
            let mut cfg = sn_cfg(p);
            cfg.faults = Some(FaultPlan::seeded(
                i as u64,
                cfg.num_map_tasks,
                p.num_partitions(),
            ));
            cfg.max_task_retries = Some(2);
            let (mode, sched) = if i % 2 == 0 {
                ("barrier", &barrier_sched)
            } else {
                ("push", &push_sched)
            };
            let res = repsn::run_on(entities, &cfg, Exec::Scheduler(sched))?;
            let identical = pair_digest(&res) == *digest;
            assert!(identical, "{name}: faulted output diverged from the clean run");
            let retries = res.counters.get(names::TASK_RETRIES);
            let failed = res.counters.get(names::TASKS_FAILED);
            assert_eq!(failed, 0, "{name}: a task exhausted its retry budget");
            total_retries += retries;
            t6.row(vec![
                name.clone(),
                mode.into(),
                identical.to_string(),
                retries.to_string(),
                failed.to_string(),
            ]);
        }
        assert!(total_retries > 0, "no injected fault actually fired");
        println!("{}", t6.render());
        println!(
            "all ladder runs recovered {total_retries} injected panic(s) via retry;\n\
             outputs identical to the clean serial digests."
        );
    }

    if let Some(n_exec) = executors {
        // Distributed re-run: every ladder configuration on the
        // message-passing control plane — a scheduler event loop driving
        // n_exec channel-transport executors, reduce tasks fetching map
        // runs by location from the shuffle registry.  Composes with
        // --push (location-addressed push shuffle) and --faults (seeded
        // panics + retry).  One mid-ladder row kills executor 1 after its
        // first completed map task; the job must finish via loss
        // resubmission with the same digest — the dist smoke test CI runs.
        println!(
            "\n--- distributed re-run: {n_exec}-executor control plane \
             (push={push}, faults={faults}) ---"
        );
        let kill_row = configs.len() / 2;
        let mut t8 = Table::new(
            &format!("Dist ladder ({n_exec} executors, location-addressed shuffle)"),
            &[
                "p",
                "identical",
                "executors_lost",
                "task_retries",
                "remote_fetches",
                "tasks_failed",
            ],
        );
        let mut total_retries = 0u64;
        let mut total_lost = 0u64;
        let mut total_failed = 0u64;
        // retries on rows without a kill can only come from injected panics
        let mut fault_retries = 0u64;
        for (i, ((name, p, entities), digest)) in configs.iter().zip(&digests).enumerate() {
            let mut cfg = sn_cfg(p);
            cfg.push = push;
            if faults {
                cfg.faults = Some(FaultPlan::seeded(
                    i as u64,
                    cfg.num_map_tasks,
                    p.num_partitions(),
                ));
                cfg.max_task_retries = Some(2);
            }
            let mut dist_cfg = DistConfig::executors(n_exec).with_retries(2);
            if push {
                dist_cfg = dist_cfg.with_push(PushMode::Push);
            }
            if i == kill_row {
                // enough map tasks that the doomed executor completes one
                // (and registers runs that will be lost) before dying
                cfg.num_map_tasks = cfg.num_map_tasks.max(2 * n_exec);
                dist_cfg = dist_cfg.with_kill(KillPlan {
                    executor: 1,
                    after_map_tasks: 1,
                });
            }
            let dist = DistScheduler::new(dist_cfg);
            let res = repsn::run_on(entities, &cfg, Exec::Dist(&dist))?;
            let identical = pair_digest(&res) == *digest;
            assert!(identical, "{name}: distributed output diverged from serial");
            let lost = res.counters.get(names::EXECUTORS_LOST);
            let retries = res.counters.get(names::TASK_RETRIES);
            let failed = res.counters.get(names::TASKS_FAILED);
            assert_eq!(failed, 0, "{name}: a task exhausted its retry budget");
            if i == kill_row {
                assert!(lost >= 1, "{name}: the kill plan never fired");
                assert!(retries >= 1, "{name}: loss recovery resubmitted nothing");
            }
            total_retries += retries;
            total_lost += lost;
            total_failed += failed;
            if i != kill_row {
                fault_retries += retries;
            }
            t8.row(vec![
                name.clone(),
                identical.to_string(),
                lost.to_string(),
                retries.to_string(),
                res.counters.get(names::DIST_REMOTE_FETCHES).to_string(),
                failed.to_string(),
            ]);
        }
        if faults {
            assert!(fault_retries > 0, "no injected fault actually fired");
        }
        println!("{}", t8.render());
        println!(
            "dist ladder complete: outputs identical to the serial digests, \
             no runs lost.\n\
             dist ladder: EXECUTORS_LOST={total_lost} TASK_RETRIES={total_retries} \
             TASKS_FAILED={total_failed}"
        );
    }

    if let Some(pb) = pool_bytes {
        // Pooled re-run: every ladder configuration accounting against ONE
        // shared memory pool — map sort buffers seal early under pressure,
        // staged push runs feel backpressure, reduce merges reserve their
        // windows.  Pair digests must match the unpooled runs exactly (the
        // pool may move bytes to disk or stall a push, never change them)
        // and no task may fail.  This is the CI pool-smoke leg.
        let mode = if let Some(nx) = executors {
            format!("{nx} executors{}", if push { ", push" } else { "" })
        } else if push {
            "4-slot push scheduler".into()
        } else {
            "serial".into()
        };
        println!("\n--- pooled re-run: one shared {pb}-byte pool across the ladder ({mode}) ---");
        let pool = MemoryPool::new(pb as u64);
        let mut t9 = Table::new(
            "Pooled ladder (one shared byte budget)",
            &["p", "identical", "denied_grows", "spill_requests", "backpressure_waits", "failed"],
        );
        let (mut total_denied, mut total_spills, mut total_waits) = (0u64, 0u64, 0u64);
        for ((name, p, entities), digest) in configs.iter().zip(&digests) {
            let mut cfg = sn_cfg(p);
            // several map waves per row keep the pool contended throughout
            cfg.num_map_tasks = 32;
            cfg.push = push;
            cfg.memory = Some(pool.clone());
            let res = if let Some(nx) = executors {
                let mut dist_cfg = DistConfig::executors(nx).with_retries(2);
                if push {
                    dist_cfg = dist_cfg.with_push(PushMode::Push);
                }
                let dist = DistScheduler::new(dist_cfg);
                repsn::run_on(entities, &cfg, Exec::Dist(&dist))?
            } else if push {
                let sched =
                    JobScheduler::new(SchedulerConfig::slots(4).with_push(PushMode::Push));
                repsn::run_on(entities, &cfg, Exec::Scheduler(&sched))?
            } else {
                repsn::run(entities, &cfg)?
            };
            let identical = pair_digest(&res) == *digest;
            assert!(identical, "{name}: pooled output diverged from the unpooled run");
            let failed = res.counters.get(names::TASKS_FAILED);
            assert_eq!(failed, 0, "{name}: a pooled task failed");
            let denied = res.counters.get(names::POOL_DENIED_GROWS);
            let spills = res.counters.get(names::POOL_SPILL_REQUESTS);
            let waits = res.counters.get(names::POOL_BACKPRESSURE_WAITS);
            total_denied += denied;
            total_spills += spills;
            total_waits += waits;
            t9.row(vec![
                name.clone(),
                identical.to_string(),
                denied.to_string(),
                spills.to_string(),
                waits.to_string(),
                failed.to_string(),
            ]);
        }
        assert!(pool.peak_bytes() > 0, "the pool never accounted a byte");
        // a budget tight enough to deny grows must also have produced
        // relief — early seals (spill requests) or push backpressure
        if total_denied > 0 {
            assert!(
                total_spills + total_waits > 0,
                "grows were denied but nothing sealed early or waited"
            );
        }
        // overdraft past the budget proves real pressure; with elastic
        // (push/spill) tasks that pressure must have triggered early seals
        if push && pool.peak_bytes() > pb as u64 {
            assert!(
                total_spills > 0,
                "pool peaked {} over the {pb}-byte budget without a single early seal",
                pool.peak_bytes()
            );
        }
        println!("{}", t9.render());
        println!(
            "pooled ladder: outputs identical, no failures; peak accounted {} of {pb} budget; \
             POOL_DENIED_GROWS={total_denied} POOL_SPILL_REQUESTS={total_spills} \
             POOL_BACKPRESSURE_WAITS={total_waits}",
            pool.peak_bytes(),
        );
    }

    if let Some(strategy) = balance {
        // Load-balancing study: a Zipf block-key corpus (a few giant
        // blocks) through unbalanced RepSN vs the chosen two-job pipeline.
        println!("\n--- load balancing: unbalanced RepSN vs {} ---", strategy.name());
        let mut bal_entities = corpus.entities.clone();
        zipf_skew_block_keys(&mut bal_entities, 150, 1.5, 0xB10C);
        let partitioner = pair_balanced_min_size(&bal_entities, &bk, 8, window);
        let r = partitioner.num_partitions();
        let cfg = |strategy: BalanceStrategy| SnConfig {
            window,
            num_map_tasks: 8,
            workers: 2,
            partitioner: Arc::new(partitioner.clone()),
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: strategy,
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        };
        let unbalanced = repsn::run(&bal_entities, &cfg(BalanceStrategy::None))?;
        let (unb_max, unb_total) = reduce_pair_skew(&unbalanced.stats[0]);
        let balanced = repsn::run(&bal_entities, &cfg(strategy))?;
        let identical = pair_digest(&balanced) == pair_digest(&unbalanced);
        assert!(identical, "{}: output diverged from RepSN", strategy.name());
        let max_task = balanced.counters.get(balance_counters::PAIRS_MAX_TASK);
        assert!(
            max_task <= unb_max,
            "{}: max task {max_task} worse than unbalanced {unb_max}",
            strategy.name()
        );
        let mut t3 = Table::new(
            &format!("Reduce-task pair skew (r={r}, w={window})"),
            &["strategy", "pairs_max_task", "pairs_total", "blocks_split", "identical"],
        );
        t3.row(vec![
            "none".into(),
            unb_max.to_string(),
            unb_total.to_string(),
            "-".into(),
            "-".into(),
        ]);
        t3.row(vec![
            strategy.name().into(),
            max_task.to_string(),
            balanced.counters.get(balance_counters::PAIRS_TOTAL).to_string(),
            balanced.counters.get(balance_counters::BLOCKS_SPLIT).to_string(),
            identical.to_string(),
        ]);
        println!("{}", t3.render());
        println!(
            "{}: hottest reduce task {unb_max} → {max_task} pairs ({:.1}× flatter), same output.",
            strategy.name(),
            unb_max as f64 / max_task.max(1) as f64
        );
    }

    if let Some(budget) = sort_buffer {
        // Disk-backed re-run: the whole ladder again with a tiny sort
        // budget and DEFLATE-compressed run files — output digests must
        // match the in-memory runs exactly (the spill smoke test CI runs).
        println!("\n--- disk-backed re-run: sort budget {budget}, DEFLATE run files ---");
        let spill_dir = TempSpillDir::new("skew-study")?;
        let mut t4 = Table::new(
            &format!("Disk-backed ladder (sort buffer {budget} records, compressed)"),
            &["p", "identical", "run_files", "shuffle_raw_b", "shuffle_comp_b", "ratio"],
        );
        for ((name, p, entities), digest) in configs.iter().zip(&digests) {
            let mut cfg = sn_cfg(p);
            cfg.sort_buffer_records = Some(budget);
            cfg.spill = Some(SnSpill::new(spill_dir.path()));
            let res = repsn::run(entities, &cfg)?;
            let identical = pair_digest(&res) == *digest;
            assert!(identical, "{name}: disk-backed output diverged from in-memory");
            let raw = res.counters.get(names::SHUFFLE_BYTES_RAW);
            let comp = res.counters.get(names::SHUFFLE_BYTES);
            assert!(comp < raw, "{name}: compression did not shrink the shuffle");
            t4.row(vec![
                name.clone(),
                identical.to_string(),
                res.counters.get(names::SPILLED_RUNS).to_string(),
                raw.to_string(),
                comp.to_string(),
                format!("{:.2}", comp as f64 / raw.max(1) as f64),
            ]);
        }
        println!("{}", t4.render());
        println!(
            "all ladder runs disk-backed with compressed intermediates:\n\
             outputs identical, SHUFFLE_BYTES < SHUFFLE_BYTES_RAW."
        );
    }
    Ok(())
}
