//! Data-skew study (§5.3): reproduce Table 1 and the shape of
//! Figures 9/10 at example scale.
//!
//! Builds the paper's partition-function ladder (Manual, Even10, Even8,
//! Even8_40 … Even8_85), measures the Gini coefficient of the resulting
//! partition sizes, runs RepSN (w = 100, m = r-slots = 8) and reports both
//! measured single-core runtimes and simulated 8-core cluster times.
//!
//! ```bash
//! cargo run --release --example skew_study -- --n 20000
//! ```

use std::sync::Arc;

use snmr::data::corpus::{generate, CorpusConfig};
use snmr::data::skew::skew_to_last_partition;
use snmr::er::blockkey::{BlockingKey, TitlePrefixKey};
use snmr::mapreduce::sim::{simulate_job_chain, ClusterSpec};
use snmr::metrics::report::{write_report, Table};
use snmr::sn::partition::{gini, partition_sizes, EvenPartition, PartitionFn, RangePartition};
use snmr::sn::repsn;
use snmr::sn::types::{SnConfig, SnMode};
use snmr::util::cli::{flag, Args};
use snmr::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(
        &[
            flag("n", "corpus size (default 20000)"),
            flag("window", "SN window (default 100)"),
        ],
        false,
    )
    .map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 20_000).map_err(anyhow::Error::msg)?;
    let window = args.get_usize("window", 100).map_err(anyhow::Error::msg)?;

    let corpus = generate(&CorpusConfig {
        n_entities: n,
        seed: 0x5EED5,
        ..Default::default()
    });
    let bk = TitlePrefixKey::new(2);

    // partition-function ladder (paper Table 1)
    let mut configs: Vec<(String, Arc<dyn PartitionFn>, Vec<snmr::er::Entity>)> = vec![
        (
            "Manual".into(),
            Arc::new(RangePartition::balanced(&corpus.entities, |e| bk.key(e), 10)),
            corpus.entities.clone(),
        ),
        (
            "Even10".into(),
            Arc::new(EvenPartition::ascii(10)),
            corpus.entities.clone(),
        ),
        (
            "Even8".into(),
            Arc::new(EvenPartition::ascii(8)),
            corpus.entities.clone(),
        ),
    ];
    for pct in [40, 55, 70, 85] {
        let p = EvenPartition::ascii(8);
        let mut entities = corpus.entities.clone();
        skew_to_last_partition(&mut entities, &bk, &p, pct as f64 / 100.0, 0xBAD5EED);
        configs.push((format!("Even8_{pct}"), Arc::new(p), entities));
    }

    let mut table = Table::new(
        "Table 1 + Fig 9/10: skew ladder, RepSN blocking (w, m=8, slots=8)",
        &["p", "gini", "comparisons", "wall_1core_s", "sim_8core_s"],
    );
    for (name, p, entities) in &configs {
        let sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), p.as_ref());
        let g = gini(&sizes);
        let cfg = SnConfig {
            window,
            num_map_tasks: 8,
            workers: 1, // clean per-task timings for the simulator
            partitioner: Arc::clone(p),
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
        };
        let t0 = std::time::Instant::now();
        let res = repsn::run(entities, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let (_, sim8) = simulate_job_chain(&res.profiles, &ClusterSpec::paper_like(8));
        table.row(vec![
            name.clone(),
            format!("{g:.2}"),
            res.counters.get("sn.window_comparisons").to_string(),
            format!("{wall:.2}"),
            format!("{sim8:.1}"),
        ]);
    }
    println!("{}", table.render());
    let path = write_report(
        "skew_study",
        &Json::obj(vec![("n", Json::num(n as f64)), ("rows", table.to_json())]),
    )?;
    println!("report written to {}", path.display());
    println!(
        "\nExpected shape (paper §5.3): Manual fastest; runtime grows with\n\
         gini; Even8_85 ≈ 3× Manual on the simulated 8-core cluster."
    );
    Ok(())
}
