//! End-to-end driver (the EXPERIMENTS.md §E2E run): full pipeline on a
//! real small workload, proving all three layers compose.
//!
//! Pipeline: generate corpus → persist to DFS sequence files → load →
//! RepSN + JobSN with the **AOT-compiled XLA matcher** (PJRT; Layer 2/1)
//! → match quality vs ground truth → cluster-simulated speedups.
//!
//! ```bash
//! make artifacts && cargo run --release --example dedup_publications -- \
//!     --n 50000 --window 10 --matcher xla
//! ```

use std::sync::Arc;

use snmr::data::corpus::{generate, CorpusConfig};
use snmr::er::blockkey::{BlockingKey, TitlePrefixKey};
use snmr::er::matcher::{NativeScorer, PairScorer};
use snmr::er::quality::Quality;
use snmr::er::strategy::MatchStrategyConfig;
use snmr::mapreduce::seqfile;
use snmr::mapreduce::sim::{simulate_job_chain, ClusterSpec};
use snmr::metrics::report::{write_report, Table};
use snmr::runtime::matcher_exec::XlaMatcher;
use snmr::sn::partition::RangePartition;
use snmr::sn::types::{SnConfig, SnMode};
use snmr::sn::{jobsn, repsn};
use snmr::util::cli::{flag, Args};
use snmr::util::humanize;
use snmr::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(
        &[
            flag("n", "corpus size (default 50000)"),
            flag("window", "SN window (default 10)"),
            flag("matcher", "xla | native (default xla, falls back)"),
            flag("maps", "map tasks (default 8)"),
            flag("workers", "worker slots (default 2)"),
        ],
        false,
    )
    .map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 50_000).map_err(anyhow::Error::msg)?;
    let window = args.get_usize("window", 10).map_err(anyhow::Error::msg)?;
    let maps = args.get_usize("maps", 8).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?;

    // ---- 1. generate + persist (DFS sequence-file round trip) -----------
    println!("== generate ({n} entities) ==");
    let corpus = generate(&CorpusConfig {
        n_entities: n,
        dup_fraction: 0.15,
        seed: 0xE2E,
        ..Default::default()
    });
    let records: Vec<_> = corpus.entities.iter().map(|e| e.to_record()).collect();
    let bytes = seqfile::write_records(&records, true)?;
    println!(
        "  {} entities → {} compressed",
        humanize::commas(n as u64),
        humanize::bytes(bytes.len() as u64)
    );
    let loaded = seqfile::read_records(&bytes)?;
    let entities: Vec<_> = loaded
        .iter()
        .map(|(k, v)| snmr::er::Entity::from_record(k, v))
        .collect::<anyhow::Result<_>>()?;
    assert_eq!(entities.len(), n);

    // ---- 2. matcher backend (XLA preferred) ------------------------------
    let scorer: Arc<dyn PairScorer> = match args.get_or("matcher", "xla") {
        "native" => Arc::new(NativeScorer::default()),
        _ => match XlaMatcher::load(&snmr::runtime::artifact::default_dir()) {
            Ok(m) => {
                println!("  matcher: XLA/PJRT (batch {})", m.preferred_batch());
                Arc::new(m)
            }
            Err(e) => {
                println!("  matcher: native (XLA unavailable: {e})");
                Arc::new(NativeScorer::default())
            }
        },
    };

    // ---- 3. run RepSN and JobSN ------------------------------------------
    let key = TitlePrefixKey::new(2);
    let partitioner = Arc::new(RangePartition::balanced(&entities, |e| key.key(e), 10));
    let cfg = SnConfig {
        window,
        num_map_tasks: maps,
        workers,
        partitioner,
        blocking_key: Arc::new(TitlePrefixKey::new(2)),
        mode: SnMode::Matching(MatchStrategyConfig {
            threshold: snmr::er::matcher::THRESHOLD,
            scorer,
        }),
        sort_buffer_records: None,
        balance: Default::default(),
        spill: None,
        push: false,
        faults: None,
        max_task_retries: None,
        trace: None,
        memory: None,
    };
    let truth = corpus.truth_pairs();
    let mut table = Table::new(
        "E2E dedup (matching mode)",
        &["variant", "jobs", "matches", "comparisons", "wall_s", "precision", "recall", "f1"],
    );
    let mut profiles = Vec::new();
    for (name, run) in [
        ("RepSN", repsn::run as fn(&[snmr::er::Entity], &SnConfig) -> anyhow::Result<snmr::sn::SnResult>),
        ("JobSN", jobsn::run as fn(&[snmr::er::Entity], &SnConfig) -> anyhow::Result<snmr::sn::SnResult>),
    ] {
        println!("== {name} ==");
        let t0 = std::time::Instant::now();
        let res = run(&entities, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let predicted: Vec<_> = res.matches.iter().map(|m| m.pair).collect();
        let q = Quality::evaluate(&predicted, &truth);
        table.row(vec![
            name.to_string(),
            res.stats.len().to_string(),
            res.matches.len().to_string(),
            res.counters.get("sn.window_comparisons").to_string(),
            format!("{wall:.2}"),
            format!("{:.3}", q.precision()),
            format!("{:.3}", q.recall()),
            format!("{:.3}", q.f1()),
        ]);
        if name == "RepSN" {
            profiles = res.profiles.clone();
        }
    }
    println!("\n{}", table.render());

    // ---- 4. simulated cluster speedups (Fig 8 methodology) ---------------
    let mut sim = Table::new(
        "RepSN on simulated paper-like clusters",
        &["cores", "time_s", "speedup"],
    );
    let mut t1 = None;
    for cores in [1usize, 2, 4, 8] {
        let (_, total) = simulate_job_chain(&profiles, &ClusterSpec::paper_like(cores));
        let t1v = *t1.get_or_insert(total);
        sim.row(vec![
            cores.to_string(),
            format!("{total:.1}"),
            format!("{:.2}", t1v / total),
        ]);
    }
    println!("{}", sim.render());

    let report = Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("window", Json::num(window as f64)),
        ("results", table.to_json()),
        ("simulated", sim.to_json()),
    ]);
    let path = write_report("e2e_dedup", &report)?;
    println!("report written to {}", path.display());
    Ok(())
}
