//! Quickstart: deduplicate a small synthetic publication corpus with
//! RepSN (the paper's single-job parallel Sorted Neighborhood).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use snmr::data::corpus::{generate, CorpusConfig};
use snmr::er::blockkey::{BlockingKey, TitlePrefixKey};
use snmr::er::quality::Quality;
use snmr::er::strategy::MatchStrategyConfig;
use snmr::sn::partition::RangePartition;
use snmr::sn::types::{SnConfig, SnMode};
use snmr::sn::repsn;

fn main() -> anyhow::Result<()> {
    // 1. A corpus with injected duplicates and known ground truth.
    let corpus = generate(&CorpusConfig {
        n_entities: 5_000,
        dup_fraction: 0.15,
        seed: 42,
        ..Default::default()
    });
    println!(
        "corpus: {} entities, {} true duplicate pairs",
        corpus.entities.len(),
        corpus.truth_pairs().len()
    );

    // 2. The paper's setup: blocking key = lowercased 2-letter title
    //    prefix; a manually balanced range partitioning into 10 blocks.
    let key = TitlePrefixKey::new(2);
    let partitioner = Arc::new(RangePartition::balanced(
        &corpus.entities,
        |e| key.key(e),
        10,
    ));

    // 3. RepSN with full matching (edit distance + trigram, τ = 0.75).
    let cfg = SnConfig {
        window: 10,
        num_map_tasks: 8,
        workers: 2,
        partitioner,
        blocking_key: Arc::new(key),
        mode: SnMode::Matching(MatchStrategyConfig::default()),
        sort_buffer_records: None,
        balance: Default::default(),
        spill: None,
        push: false,
        faults: None,
        max_task_retries: None,
        trace: None,
        memory: None,
    };
    let t0 = std::time::Instant::now();
    let result = repsn::run(&corpus.entities, &cfg)?;
    println!(
        "RepSN: {} matches from {} window comparisons in {:.2?}",
        result.matches.len(),
        result.counters.get("sn.window_comparisons"),
        t0.elapsed()
    );

    // 4. Quality against the injected ground truth.
    let predicted: Vec<_> = result.matches.iter().map(|m| m.pair).collect();
    let q = Quality::evaluate(&predicted, &corpus.truth_pairs());
    println!(
        "precision {:.3}  recall {:.3}  F1 {:.3}",
        q.precision(),
        q.recall(),
        q.f1()
    );
    println!(
        "(replicated entities: {}, max by formula m(r-1)(w-1) = {})",
        result.counters.get("sn.replicated_entities"),
        8 * (10 - 1) * (10 - 1)
    );

    // 5. Cluster the pairwise matches into duplicate groups.
    let clusters = snmr::er::clustering::cluster_matches(&result.matches);
    let largest = clusters.iter().map(|c| c.members.len()).max().unwrap_or(0);
    println!(
        "{} duplicate clusters (largest has {largest} records)",
        clusters.len()
    );
    Ok(())
}
