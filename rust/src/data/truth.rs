//! Ground-truth bookkeeping for injected duplicates.
//!
//! Duplicates form clusters (a base record and its noisy copies); the
//! truth pair set is the union of all within-cluster pairs (transitive
//! closure — if B and C both duplicate A, then (B, C) is also true).

use std::collections::{BTreeMap, BTreeSet};

use crate::er::entity::Pair;

/// Union-find-free cluster registry (clusters are tiny and append-only:
/// a duplicate always links to an existing cluster's base).
#[derive(Debug, Default)]
pub struct TruthSet {
    /// entity id → cluster id (the base entity's id).
    cluster_of: BTreeMap<u64, u64>,
    /// cluster id → member ids (including the base).
    members: BTreeMap<u64, Vec<u64>>,
    links: usize,
}

impl TruthSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `dup` as a duplicate of `base` (or of base's cluster).
    pub fn link(&mut self, base: u64, dup: u64) {
        let cluster = *self.cluster_of.get(&base).unwrap_or(&base);
        self.cluster_of.entry(base).or_insert(cluster);
        self.cluster_of.insert(dup, cluster);
        let m = self.members.entry(cluster).or_insert_with(|| vec![cluster]);
        if !m.contains(&dup) {
            m.push(dup);
        }
        self.links += 1;
    }

    /// Number of explicit duplicate links registered.
    pub fn n_links(&self) -> usize {
        self.links
    }

    /// Size of the cluster containing `id` minus one (extra copies), 0 if
    /// the entity is unclustered.
    pub fn cluster_size(&self, id: u64) -> usize {
        self.cluster_of
            .get(&id)
            .and_then(|c| self.members.get(c))
            .map(|m| m.len().saturating_sub(1))
            .unwrap_or(0)
    }

    /// Iterate `(cluster id, member count)`.
    pub fn cluster_sizes(&self) -> impl Iterator<Item = (u64, usize)> + '_ {
        self.members.iter().map(|(c, m)| (*c, m.len()))
    }

    /// The full truth pair set (within-cluster transitive closure).
    pub fn pairs(&self) -> BTreeSet<Pair> {
        let mut out = BTreeSet::new();
        for members in self.members.values() {
            for i in 0..members.len() {
                for j in (i + 1)..members.len() {
                    out.insert(Pair::new(members[i], members[j]));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transitive_closure() {
        let mut t = TruthSet::new();
        t.link(1, 2);
        t.link(1, 3);
        let pairs = t.pairs();
        assert_eq!(pairs.len(), 3);
        assert!(pairs.contains(&Pair::new(2, 3)));
    }

    #[test]
    fn chained_link_through_duplicate() {
        let mut t = TruthSet::new();
        t.link(1, 2);
        t.link(2, 3); // base is itself a duplicate → same cluster as 1
        let pairs = t.pairs();
        assert_eq!(pairs.len(), 3);
        assert!(pairs.contains(&Pair::new(1, 3)));
    }

    #[test]
    fn cluster_size_counts_extras() {
        let mut t = TruthSet::new();
        assert_eq!(t.cluster_size(7), 0);
        t.link(7, 8);
        assert_eq!(t.cluster_size(7), 1);
        assert_eq!(t.cluster_size(8), 1);
        t.link(7, 9);
        assert_eq!(t.cluster_size(9), 2);
    }

    #[test]
    fn disjoint_clusters_stay_disjoint() {
        let mut t = TruthSet::new();
        t.link(1, 2);
        t.link(10, 11);
        let pairs = t.pairs();
        assert_eq!(pairs.len(), 2);
        assert!(!pairs.contains(&Pair::new(2, 11)));
    }
}
