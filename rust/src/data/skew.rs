//! Blocking-key skew shaping for the §5.3 experiments.
//!
//! Table 1 evaluates Even8 variants where "40%, 55%, 70% and 85%,
//! respectively, of all entities fall in the last partition" — produced by
//! *modifying the blocking keys*.  We do the same: rewrite the first two
//! title characters of randomly chosen entities to a prefix that the
//! Even-8 partition function routes to its last partition.

use crate::er::blockkey::BlockingKey;
use crate::er::entity::Entity;
use crate::sn::partition::PartitionFn;
use crate::util::rng::Rng;

/// Rewrite titles until `fraction` of all entities fall into the *last*
/// partition of `p`.  Returns the number of entities rewritten.
/// Deterministic for a given `(entities, fraction, seed)`.
pub fn skew_to_last_partition(
    entities: &mut [Entity],
    blocking_key: &dyn BlockingKey,
    p: &dyn PartitionFn,
    fraction: f64,
    seed: u64,
) -> usize {
    assert!((0.0..=1.0).contains(&fraction));
    let last = p.num_partitions() - 1;
    let n = entities.len();
    let target = (fraction * n as f64).round() as usize;
    let mut in_last: usize = entities
        .iter()
        .filter(|e| p.partition(&blocking_key.key(e)) == last)
        .count();
    if in_last >= target {
        return 0;
    }
    let mut rng = Rng::new(seed ^ 0x5E3B_00C5);
    // candidate order: deterministic shuffle of indices not in last
    let mut candidates: Vec<usize> = (0..n)
        .filter(|&i| p.partition(&blocking_key.key(&entities[i])) != last)
        .collect();
    rng.shuffle(&mut candidates);
    let mut rewritten = 0;
    for idx in candidates {
        if in_last >= target {
            break;
        }
        let e = &mut entities[idx];
        // prefix that lands deep inside the last partition: "z" + letter
        let c2 = (b'p' + rng.below(11) as u8) as char; // p..z
        let rest: String = e.title.chars().skip(2).collect();
        e.title = format!("z{c2}{rest}");
        debug_assert_eq!(p.partition(&blocking_key.key(e)), last);
        in_last += 1;
        rewritten += 1;
    }
    rewritten
}

/// Rewrite every entity's two-character key prefix by sampling letter
/// ranks from a Zipf(`s`) distribution — heavy-tailed *data* skew, as
/// opposed to the machine skew of
/// [`ClusterSpec::with_slow_nodes`](crate::mapreduce::sim::ClusterSpec::with_slow_nodes).
/// The speculation sweep in `benches/fig9_skew.rs` contrasts the two:
/// speculative execution rescues machine-skew stragglers but cannot beat
/// data-skew ones (a clone re-processes the same oversized partition).
/// Larger `s` ⇒ heavier head ⇒ higher partition-size Gini.
/// Deterministic for a given `(entities, s, seed)`.
pub fn zipf_skew_titles(entities: &mut [Entity], s: f64, seed: u64) {
    assert!(s > 0.0);
    let mut rng = Rng::new(seed ^ 0x21BF_05EE_D21F_0000);
    for e in entities.iter_mut() {
        let c1 = (b'a' + rng.zipf(26, s) as u8) as char;
        let c2 = (b'a' + rng.zipf(26, s) as u8) as char;
        let rest: String = e.title.chars().skip(2).collect();
        e.title = format!("{c1}{c2}{rest}");
    }
}

/// Rewrite every entity's two-character key prefix to one of
/// `distinct_keys` two-letter keys chosen by a **single** Zipf(`s`) rank
/// draw — skewing the *blocking-key* distribution itself, as opposed to
/// [`zipf_skew_titles`]'s independent per-letter draws.  One draw per
/// entity means the head of the distribution is a handful of giant
/// *blocks* (key runs), which no monotone key-range partitioner can
/// split — the reduce-side skew that `sn::loadbalance`'s BlockSplit /
/// PairRange exist for, dialed independently of matcher cost.  Hot keys
/// are scattered over the key space by a fixed unit permutation so a
/// range partitioner cannot dodge them by accident.  Deterministic for a
/// given `(entities, distinct_keys, s, seed)`.
pub fn zipf_skew_block_keys(entities: &mut [Entity], distinct_keys: usize, s: f64, seed: u64) {
    assert!(s > 0.0);
    const SPAN: usize = 26 * 26;
    let k = distinct_keys.clamp(1, SPAN);
    let mut rng = Rng::new(seed ^ 0x0B10_C4B1_0C4B_10C4);
    for e in entities.iter_mut() {
        let rank = rng.zipf(k, s);
        // 131 is coprime to 676, so this is a bijection on the key space
        let slot = (rank * 131) % SPAN;
        let c1 = (b'a' + (slot / 26) as u8) as char;
        let c2 = (b'a' + (slot % 26) as u8) as char;
        let rest: String = e.title.chars().skip(2).collect();
        e.title = format!("{c1}{c2}{rest}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusConfig};
    use crate::er::blockkey::TitlePrefixKey;
    use crate::sn::partition::{gini, partition_sizes, EvenPartition};

    fn fraction_in_last(entities: &[Entity], p: &EvenPartition) -> f64 {
        let bk = TitlePrefixKey::new(2);
        let last = p.num_partitions() - 1;
        entities
            .iter()
            .filter(|e| p.partition(&bk.key(e)) == last)
            .count() as f64
            / entities.len() as f64
    }

    #[test]
    fn hits_target_fractions() {
        let corpus = generate(&CorpusConfig {
            n_entities: 4000,
            ..Default::default()
        });
        let p = EvenPartition::ascii(8);
        let bk = TitlePrefixKey::new(2);
        for target in [0.40, 0.55, 0.70, 0.85] {
            let mut entities = corpus.entities.clone();
            skew_to_last_partition(&mut entities, &bk, &p, target, 42);
            let f = fraction_in_last(&entities, &p);
            assert!(
                (f - target).abs() < 0.01,
                "target {target} reached {f}"
            );
        }
    }

    #[test]
    fn gini_rises_with_skew() {
        let corpus = generate(&CorpusConfig {
            n_entities: 4000,
            ..Default::default()
        });
        let p = EvenPartition::ascii(8);
        let bk = TitlePrefixKey::new(2);
        let mut last_g = -1.0;
        for target in [0.40, 0.55, 0.70, 0.85] {
            let mut entities = corpus.entities.clone();
            skew_to_last_partition(&mut entities, &bk, &p, target, 42);
            let sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), &p);
            let g = gini(&sizes);
            assert!(g > last_g, "gini must increase: {last_g} → {g}");
            last_g = g;
        }
        assert!(last_g > 0.6, "85% skew should give high gini, got {last_g}");
    }

    #[test]
    fn deterministic() {
        let corpus = generate(&CorpusConfig {
            n_entities: 1000,
            ..Default::default()
        });
        let p = EvenPartition::ascii(8);
        let bk = TitlePrefixKey::new(2);
        let mut a = corpus.entities.clone();
        let mut b = corpus.entities.clone();
        skew_to_last_partition(&mut a, &bk, &p, 0.5, 7);
        skew_to_last_partition(&mut b, &bk, &p, 0.5, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn noop_when_already_skewed() {
        let mut entities: Vec<Entity> =
            (0..100).map(|i| Entity::new(i, "zz title", "")).collect();
        let p = EvenPartition::ascii(8);
        let n = skew_to_last_partition(&mut entities, &TitlePrefixKey::new(2), &p, 0.5, 1);
        assert_eq!(n, 0);
    }

    #[test]
    fn zipf_block_keys_concentrate_mass_on_few_blocks() {
        let corpus = generate(&CorpusConfig {
            n_entities: 4000,
            ..Default::default()
        });
        let bk = TitlePrefixKey::new(2);
        let mut a = corpus.entities.clone();
        zipf_skew_block_keys(&mut a, 200, 1.5, 7);
        // block-size histogram: the hottest single key must dominate
        let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
        for e in &a {
            *counts.entry(bk.key(e)).or_insert(0) += 1;
        }
        let hottest = *counts.values().max().unwrap();
        assert!(
            hottest > 4000 / 5,
            "s=1.5 head should hold >20% of entities in ONE block, got {hottest}"
        );
        assert!(counts.len() > 20, "tail must still spread: {}", counts.len());
        // deterministic
        let mut b = corpus.entities.clone();
        zipf_skew_block_keys(&mut b, 200, 1.5, 7);
        assert_eq!(a, b);
        // heavier exponent ⇒ bigger head block
        let mut c = corpus.entities.clone();
        zipf_skew_block_keys(&mut c, 200, 2.0, 7);
        let mut counts2: std::collections::BTreeMap<String, usize> = Default::default();
        for e in &c {
            *counts2.entry(bk.key(e)).or_insert(0) += 1;
        }
        assert!(*counts2.values().max().unwrap() > hottest);
    }

    #[test]
    fn zipf_skew_is_heavy_tailed_and_deterministic() {
        let corpus = generate(&CorpusConfig {
            n_entities: 3000,
            ..Default::default()
        });
        let p = EvenPartition::ascii(8);
        let bk = TitlePrefixKey::new(2);
        let base_sizes = partition_sizes(corpus.entities.iter().map(|e| bk.key(e)), &p);
        let base_g = gini(&base_sizes);
        let mut a = corpus.entities.clone();
        zipf_skew_titles(&mut a, 1.2, 99);
        let sizes = partition_sizes(a.iter().map(|e| bk.key(e)), &p);
        let g = gini(&sizes);
        assert!(
            g > base_g + 0.1,
            "zipf rewrite should raise gini: {base_g} → {g}"
        );
        let mut b = corpus.entities.clone();
        zipf_skew_titles(&mut b, 1.2, 99);
        assert_eq!(a, b, "same seed must give same corpus");
        // heavier exponent ⇒ heavier head
        let mut c = corpus.entities.clone();
        zipf_skew_titles(&mut c, 2.0, 99);
        let g2 = gini(&partition_sizes(c.iter().map(|e| bk.key(e)), &p));
        assert!(g2 > g, "s=2.0 should be more skewed than s=1.2: {g} vs {g2}");
    }
}
