//! Duplicate-injection noise: realistic typos and edits.
//!
//! Duplicates of a base record get: character-level title typos
//! (insert/delete/substitute/transpose), word drops in the abstract, year
//! jitter and occasional venue changes — calibrated so most duplicates
//! stay above the 0.75 match threshold (like real near-duplicate
//! bibliographic records) while a tail becomes genuinely hard.

use crate::er::entity::Entity;
use crate::util::rng::Rng;

/// Noise intensity configuration.
#[derive(Debug, Clone)]
pub struct NoiseConfig {
    /// Expected number of character edits applied to the title.
    pub title_edits: f64,
    /// Probability of dropping each abstract word.
    pub abstract_word_drop: f64,
    /// Probability the year shifts by ±1.
    pub year_jitter: f64,
    /// Fraction of duplicates that get *heavy* corruption (many title
    /// edits + large abstract loss) — the hard tail real bibliographic
    /// data has; these often fall below the match threshold.
    pub hard_fraction: f64,
}

impl Default for NoiseConfig {
    fn default() -> Self {
        Self {
            title_edits: 1.5,
            abstract_word_drop: 0.05,
            year_jitter: 0.2,
            hard_fraction: 0.10,
        }
    }
}

const TYPO_CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz ";

/// Apply one random character edit to `s` (in place semantics via return).
pub fn char_edit(s: &str, rng: &mut Rng) -> String {
    let mut chars: Vec<char> = s.chars().collect();
    if chars.is_empty() {
        return s.to_string();
    }
    match rng.below(4) {
        0 => {
            // substitute
            let i = rng.range(0, chars.len());
            chars[i] = *rng.pick(TYPO_CHARS) as char;
        }
        1 => {
            // insert
            let i = rng.range(0, chars.len() + 1);
            chars.insert(i, *rng.pick(TYPO_CHARS) as char);
        }
        2 => {
            // delete
            let i = rng.range(0, chars.len());
            chars.remove(i);
        }
        _ => {
            // transpose
            if chars.len() >= 2 {
                let i = rng.range(0, chars.len() - 1);
                chars.swap(i, i + 1);
            }
        }
    }
    chars.into_iter().collect()
}

/// Create a noisy duplicate of `base` with a fresh id.
pub fn make_duplicate(base: &Entity, new_id: u64, cfg: &NoiseConfig, rng: &mut Rng) -> Entity {
    let hard = rng.chance(cfg.hard_fraction);
    let mut title = base.title.clone();
    if hard {
        // heavy corruption: 25–45% of the title length in edits
        let n_edits = (title.len() as f64 * (0.25 + 0.2 * rng.f64())) as usize;
        for _ in 0..n_edits.max(4) {
            title = char_edit(&title, rng);
        }
    } else {
        // Poisson-ish: geometric number of edits with the configured mean
        let p_more = cfg.title_edits / (1.0 + cfg.title_edits);
        while rng.chance(p_more) {
            title = char_edit(&title, rng);
        }
    }
    let drop_p = if hard {
        0.4
    } else {
        cfg.abstract_word_drop
    };
    let abstract_text: String = base
        .abstract_text
        .split_whitespace()
        .filter(|_| !rng.chance(drop_p))
        .collect::<Vec<_>>()
        .join(" ");
    let year = if rng.chance(cfg.year_jitter) {
        if rng.chance(0.5) {
            base.year.saturating_add(1)
        } else {
            base.year.saturating_sub(1)
        }
    } else {
        base.year
    };
    Entity {
        id: new_id,
        title,
        abstract_text,
        authors: base.authors.clone(),
        year,
        venue: base.venue.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::matcher::NativeScorer;
    use crate::runtime::encode::encode_entity;

    #[test]
    fn char_edit_changes_or_keeps_length_by_one() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let out = char_edit("hello world", &mut rng);
            let dl = out.len() as i64 - 11;
            assert!(dl.abs() <= 1, "{out}");
        }
    }

    #[test]
    fn duplicates_mostly_match_under_default_noise() {
        let mut rng = Rng::new(7);
        let base = Entity {
            id: 0,
            title: "parallel sorted neighborhood blocking with mapreduce".into(),
            abstract_text: "cloud infrastructures enable the efficient parallel \
                            execution of data intensive tasks such as entity \
                            resolution on large datasets using mapreduce"
                .into(),
            authors: "kolb".into(),
            year: 2010,
            venue: "BTW".into(),
        };
        let scorer = NativeScorer::default();
        let mut matched = 0;
        const N: usize = 200;
        for i in 0..N {
            let dup = make_duplicate(&base, 1000 + i as u64, &NoiseConfig::default(), &mut rng);
            let a = encode_entity(&base.title, &base.abstract_text);
            let b = encode_entity(&dup.title, &dup.abstract_text);
            if scorer.score_pair(&a, &b).score >= 0.75 {
                matched += 1;
            }
        }
        assert!(
            matched > N * 8 / 10,
            "only {matched}/{N} duplicates match — noise too strong"
        );
        assert!(matched < N, "noise too weak: every duplicate trivially matches");
    }

    #[test]
    fn duplicate_keeps_identity_fields() {
        let mut rng = Rng::new(3);
        let base = Entity::new(5, "some base title", "some abstract");
        let dup = make_duplicate(&base, 99, &NoiseConfig::default(), &mut rng);
        assert_eq!(dup.id, 99);
        assert_eq!(dup.authors, base.authors);
    }
}
