//! Seeded synthetic publication-corpus generator.
//!
//! Produces the workload shape the paper's experiments need (DESIGN.md §3
//! documents the substitution for the unavailable CiteSeerX dump):
//!
//! * titles of 3–10 words whose *first* word follows a skewed starter
//!   distribution → the 2-letter blocking-key histogram is realistically
//!   non-uniform ("many publication titles start with 'a'"),
//! * abstracts of 25–70 words over a shared vocabulary (Zipf-sampled) so
//!   trigram similarity is informative,
//! * injected duplicate clusters with typo noise and recorded ground
//!   truth.

use std::collections::BTreeSet;

use crate::data::noise::{make_duplicate, NoiseConfig};
use crate::data::truth::TruthSet;
use crate::data::vocab;
use crate::er::entity::Entity;
use crate::util::rng::Rng;

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    /// Total number of entities (bases + duplicates).
    pub n_entities: usize,
    /// Fraction of entities that are duplicates of an earlier base.
    pub dup_fraction: f64,
    /// Maximum duplicates per cluster.
    pub max_cluster_extra: usize,
    /// Noise applied to duplicates.
    pub noise: NoiseConfig,
    /// PRNG seed — same seed ⇒ identical corpus.
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            n_entities: 10_000,
            dup_fraction: 0.15,
            max_cluster_extra: 3,
            noise: NoiseConfig::default(),
            seed: 0xC15E_5EED,
        }
    }
}

/// A generated corpus: entities plus ground truth.
#[derive(Debug)]
pub struct Corpus {
    pub entities: Vec<Entity>,
    pub truth: TruthSet,
}

impl Corpus {
    /// Truth as a flat pair set (for quality evaluation).
    pub fn truth_pairs(&self) -> BTreeSet<crate::er::entity::Pair> {
        self.truth.pairs()
    }
}

fn make_title(rng: &mut Rng) -> String {
    let starter = vocab::TITLE_STARTERS[rng.zipf(vocab::TITLE_STARTERS.len(), 0.7)];
    let n_words = rng.range(2, 9);
    let mut words = vec![starter.to_string()];
    for _ in 0..n_words {
        words.push(vocab::CONTENT_WORDS[rng.zipf(vocab::CONTENT_WORDS.len(), 1.05)].to_string());
    }
    words.join(" ")
}

fn make_abstract(rng: &mut Rng) -> String {
    let n_words = rng.range(25, 70);
    let mut words = Vec::with_capacity(n_words);
    for _ in 0..n_words {
        words.push(vocab::CONTENT_WORDS[rng.zipf(vocab::CONTENT_WORDS.len(), 1.02)]);
    }
    words.join(" ")
}

fn make_authors(rng: &mut Rng) -> String {
    let n = rng.range(1, 4);
    (0..n)
        .map(|_| {
            format!(
                "{} {}",
                rng.pick(vocab::FIRST_NAMES),
                rng.pick(vocab::LAST_NAMES)
            )
        })
        .collect::<Vec<_>>()
        .join(", ")
}

/// Generate a corpus.
pub fn generate(cfg: &CorpusConfig) -> Corpus {
    let mut rng = Rng::new(cfg.seed);
    let mut entities: Vec<Entity> = Vec::with_capacity(cfg.n_entities);
    let mut truth = TruthSet::new();
    // base records eligible for duplication (index into entities, cluster)
    let mut bases: Vec<usize> = Vec::new();
    let mut next_id = 0u64;
    while entities.len() < cfg.n_entities {
        let duplicate = !bases.is_empty() && rng.chance(cfg.dup_fraction);
        if duplicate {
            let base_idx = *rng.pick(&bases);
            let base = entities[base_idx].clone();
            // limit cluster size
            if truth.cluster_size(base.id) < cfg.max_cluster_extra {
                let dup = make_duplicate(&base, next_id, &cfg.noise, &mut rng);
                truth.link(base.id, dup.id);
                entities.push(dup);
                next_id += 1;
                continue;
            }
        }
        let e = Entity {
            id: next_id,
            title: make_title(&mut rng),
            abstract_text: make_abstract(&mut rng),
            authors: make_authors(&mut rng),
            year: 1985 + rng.below(26) as u16,
            venue: rng.pick(vocab::VENUES).to_string(),
        };
        bases.push(entities.len());
        entities.push(e);
        next_id += 1;
    }
    Corpus { entities, truth }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::{BlockingKey, TitlePrefixKey};
    use crate::sn::partition::{gini, partition_sizes, EvenPartition};

    #[test]
    fn deterministic_per_seed() {
        let cfg = CorpusConfig {
            n_entities: 500,
            ..Default::default()
        };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.entities, b.entities);
        assert_eq!(a.truth_pairs(), b.truth_pairs());
        let c = generate(&CorpusConfig { seed: 1, ..cfg });
        assert_ne!(a.entities, c.entities);
    }

    #[test]
    fn duplicate_fraction_roughly_respected() {
        let cfg = CorpusConfig {
            n_entities: 5000,
            dup_fraction: 0.2,
            ..Default::default()
        };
        let corpus = generate(&cfg);
        let n_dup_links = corpus.truth.n_links();
        assert!(
            (700..1300).contains(&n_dup_links),
            "expected ~1000 duplicate links, got {n_dup_links}"
        );
    }

    #[test]
    fn key_distribution_is_skewed_but_covering() {
        let corpus = generate(&CorpusConfig {
            n_entities: 5000,
            ..Default::default()
        });
        let bk = TitlePrefixKey::new(2);
        let p = EvenPartition::ascii(8);
        let sizes = partition_sizes(
            corpus.entities.iter().map(|e| bk.key(e)),
            &p,
        );
        let g = gini(&sizes);
        // natural skew: clearly nonzero, not degenerate
        assert!(g > 0.15, "corpus keys too uniform: g={g}, sizes={sizes:?}");
        assert!(g < 0.9, "corpus keys degenerate: g={g}, sizes={sizes:?}");
        assert!(sizes.iter().filter(|&&s| s > 0).count() >= 3);
    }

    #[test]
    fn truth_pairs_reference_real_ids() {
        let corpus = generate(&CorpusConfig {
            n_entities: 1000,
            ..Default::default()
        });
        let ids: BTreeSet<u64> = corpus.entities.iter().map(|e| e.id).collect();
        assert_eq!(ids.len(), 1000, "ids must be unique");
        for p in corpus.truth_pairs() {
            assert!(ids.contains(&p.a) && ids.contains(&p.b));
        }
    }

    #[test]
    fn clusters_are_bounded() {
        let cfg = CorpusConfig {
            n_entities: 3000,
            dup_fraction: 0.5,
            max_cluster_extra: 2,
            ..Default::default()
        };
        let corpus = generate(&cfg);
        for (_, size) in corpus.truth.cluster_sizes() {
            assert!(size <= 3, "cluster larger than base+2: {size}");
        }
    }
}
