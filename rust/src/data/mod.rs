//! Synthetic publication corpus (the CiteSeerX substitute).
//!
//! The paper's dataset (1.4 M CiteSeerX records, csx.raw.txt) is no longer
//! available; [`corpus`] generates a seeded corpus with the properties the
//! experiments depend on: realistic title-prefix key distribution (many
//! titles start with "a"/"the"), abstracts with shared vocabulary, and
//! *injected duplicates* ([`noise`]) that give us the ground truth the
//! original evaluation lacked.  [`skew`] reshapes blocking keys to hit the
//! Table-1 skew targets (Even8_40 … Even8_85).

pub mod corpus;
pub mod noise;
pub mod skew;
pub mod truth;
pub mod vocab;
