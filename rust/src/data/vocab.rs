//! Vocabulary for the synthetic publication corpus.
//!
//! Title starter words are weighted so the blocking-key (2-letter title
//! prefix) distribution is realistically skewed — the paper notes "many
//! publication titles start with 'a'" when motivating its manual balanced
//! partitioning.  Content words are CS-flavoured so abstracts share enough
//! trigrams for the matcher to be meaningfully exercised.

/// Common title-starting words (sampled Zipf-style: earlier = likelier).
pub const TITLE_STARTERS: &[&str] = &[
    "a", "the", "an", "on", "towards", "efficient", "parallel",
    "adaptive", "automatic", "analysis", "learning", "distributed",
    "scalable", "fast", "optimal", "robust", "dynamic", "improving",
    "evaluation", "modeling", "mining", "using", "query", "data",
    "incremental", "online", "practical", "secure", "self", "semantic",
    "understanding", "visual", "web", "exploring", "beyond", "revisiting",
    "approximate", "benchmarking", "composable", "declarative", "elastic",
    "federated", "generalized", "hybrid", "interactive", "joint",
    "knowledge", "lightweight", "managing", "novel", "optimizing",
    "privacy", "quantifying", "ranking", "sampling", "transparent",
    "unified", "validating", "workload", "cross", "yet", "zero",
];

/// Content words for titles and abstracts.
pub const CONTENT_WORDS: &[&str] = &[
    "entity", "resolution", "blocking", "matching", "duplicate", "record",
    "linkage", "database", "databases", "cloud", "mapreduce", "hadoop",
    "cluster", "clusters", "index", "indexing", "similarity", "string",
    "distance", "window", "neighborhood", "sorted", "partition",
    "partitioning", "skew", "balancing", "load", "reduce", "map", "join",
    "joins", "query", "queries", "optimization", "processing", "parallel",
    "distributed", "scalable", "performance", "evaluation", "framework",
    "system", "systems", "algorithm", "algorithms", "approach", "method",
    "methods", "technique", "techniques", "model", "models", "learning",
    "classification", "detection", "analysis", "mining", "integration",
    "quality", "cleaning", "schema", "xml", "graph", "graphs", "network",
    "networks", "stream", "streams", "storage", "memory", "cache",
    "transaction", "transactions", "workflow", "workflows", "service",
    "services", "semantic", "ontology", "knowledge", "information",
    "retrieval", "ranking", "search", "web", "text", "document",
    "documents", "corpus", "language", "translation", "clustering",
    "sampling", "estimation", "probabilistic", "bayesian", "inference",
    "kernel", "vector", "feature", "features", "dimension", "reduction",
    "compression", "encoding", "hashing", "bloom", "filter", "filters",
    "trigram", "token", "tokens", "prefix", "suffix", "edit", "metric",
    "benchmark", "benchmarks", "experiment", "experiments", "empirical",
];

/// Author first names / last names for the authors field.
pub const FIRST_NAMES: &[&str] = &[
    "lars", "andreas", "erhard", "hanna", "peter", "tim", "markus",
    "rares", "michael", "chen", "jeffrey", "sanjay", "david", "jim",
    "hung", "dongwon", "anika", "toralf", "daniel", "odej", "ali", "ruey",
    "maria", "wei", "ying", "thomas", "anna", "sofia", "ivan", "petra",
];

pub const LAST_NAMES: &[&str] = &[
    "kolb", "thor", "rahm", "koepcke", "christen", "churches", "hegland",
    "vernica", "carey", "li", "dean", "ghemawat", "dewitt", "gray", "kim",
    "lee", "gross", "kirsten", "warneke", "kao", "dasdan", "hsiao",
    "garcia", "chen", "wang", "mueller", "schmidt", "novak", "petrov",
    "fischer",
];

/// Venues.
pub const VENUES: &[&str] = &[
    "VLDB", "SIGMOD", "ICDE", "EDBT", "BTW", "CIKM", "KDD", "WWW", "TKDE",
    "DKE", "PVLDB", "SOCC",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocabularies_nonempty_and_lowercase_titles() {
        assert!(TITLE_STARTERS.len() > 40);
        assert!(CONTENT_WORDS.len() > 100);
        for w in TITLE_STARTERS.iter().chain(CONTENT_WORDS) {
            assert!(!w.is_empty());
            assert!(w.chars().all(|c| c.is_ascii_lowercase()), "bad word {w}");
        }
    }

    #[test]
    fn starters_are_skewed_toward_a_and_the() {
        let a_like = TITLE_STARTERS.iter().filter(|w| w.starts_with('a')).count();
        assert!(a_like >= 5, "title-prefix skew requires many 'a' starters");
    }
}
