//! `StandardSN`: the sliding-window comparison generator (§4, Figure 4).
//!
//! A window of fixed size `w` moves over a key-sorted entity list; every
//! pair of entities within distance `< w` is compared.  Streaming form:
//! keep the previous `w−1` entities in a ring buffer; each arriving entity
//! pairs with everything in the buffer.  This is exactly the row-by-row
//! access pattern a Hadoop reduce iterator provides, which is why SN fits
//! MapReduce reducers without memory blowup (§3 "memory bottlenecks").

use std::collections::VecDeque;

use crate::er::entity::Pair;

/// Number of comparisons standard SN performs on `n` entities with window
/// `w` (the paper's `(n − w/2)·(w−1)` for `n ≥ w`, exact integer form
/// `(n−w)(w−1) + w(w−1)/2`; all `C(n,2)` pairs when `n < w`).
pub fn expected_pair_count(n: usize, w: usize) -> usize {
    if w < 2 || n < 2 {
        return 0;
    }
    if n < w {
        return n * (n - 1) / 2;
    }
    (n - w) * (w - 1) + w * (w - 1) / 2
}

/// Missing boundary pairs when SRP splits the sorted list into `r`
/// partitions each holding ≥ w entities (§4.1): `(r−1)·w·(w−1)/2`.
pub fn srp_missing_pairs(r: usize, w: usize) -> usize {
    if w < 2 || r < 2 {
        return 0;
    }
    (r - 1) * w * (w - 1) / 2
}

/// A streaming sliding window over items of type `T`.
///
/// `push` hands the new item and each buffered neighbor (oldest first) to
/// the callback — one call per generated comparison.
#[derive(Debug)]
pub struct SlidingWindow<T> {
    w: usize,
    buffer: VecDeque<T>,
    comparisons: u64,
}

impl<T> SlidingWindow<T> {
    /// Window size `w ≥ 2` (a window of 1 compares nothing).
    pub fn new(w: usize) -> Self {
        assert!(w >= 2, "window must be >= 2");
        Self {
            w,
            buffer: VecDeque::with_capacity(w),
            comparisons: 0,
        }
    }

    /// Seed the buffer *without* generating comparisons (RepSN seeds the
    /// window with the predecessor's replicated boundary entities).
    pub fn seed(&mut self, item: T) {
        self.buffer.push_back(item);
        if self.buffer.len() > self.w - 1 {
            self.buffer.pop_front();
        }
    }

    /// Push the next entity; `on_pair(older, newer)` fires for each
    /// window comparison.
    pub fn push<F: FnMut(&T, &T)>(&mut self, item: T, mut on_pair: F) {
        for old in &self.buffer {
            on_pair(old, &item);
            self.comparisons += 1;
        }
        self.seed(item);
    }

    /// Total comparisons generated so far.
    pub fn comparisons(&self) -> u64 {
        self.comparisons
    }

    /// Current buffer length (≤ w−1).
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }
}

/// `StandardSN` over a key-sorted slice of entity ids: collect all window
/// pairs.  (Algorithms 1–2 call this `StandardSN(list(entity), w)`.)
pub fn standard_sn(sorted_ids: &[u64], w: usize) -> Vec<Pair> {
    let mut out = Vec::with_capacity(expected_pair_count(sorted_ids.len(), w));
    let mut win = SlidingWindow::new(w.max(2));
    if w < 2 {
        return out;
    }
    for &id in sorted_ids {
        win.push(id, |&a, &b| out.push(Pair::new(a, b)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Figure 4: entities a,d,b,e,f,h,c,g,i sorted by key; w = 3 →
    /// 15 pairs, exactly the ones listed in the figure.
    #[test]
    fn figure_4_example() {
        // ids: a=1 d=4 b=2 e=5 f=6 h=8 c=3 g=7 i=9 (sorted order)
        let sorted = [1u64, 4, 2, 5, 6, 8, 3, 7, 9];
        let pairs = standard_sn(&sorted, 3);
        assert_eq!(pairs.len(), 15);
        assert_eq!(pairs.len(), expected_pair_count(9, 3));
        let expect = [
            (1, 4), (1, 2), (4, 2), // window a d b
            (4, 5), (2, 5),         // d b e
            (2, 6), (5, 6),         // b e f
            (5, 8), (6, 8),         // e f h
            (6, 3), (8, 3),         // f h c
            (8, 7), (3, 7),         // h c g
            (3, 9), (7, 9),         // c g i
        ];
        let got: std::collections::BTreeSet<Pair> = pairs.into_iter().collect();
        for (a, b) in expect {
            assert!(got.contains(&Pair::new(a, b)), "missing ({a},{b})");
        }
        assert_eq!(got.len(), 15);
    }

    #[test]
    fn pair_count_formula() {
        for (n, w) in [(9, 3), (100, 10), (1000, 50), (10, 10), (5, 2)] {
            let ids: Vec<u64> = (0..n as u64).collect();
            assert_eq!(
                standard_sn(&ids, w).len(),
                expected_pair_count(n, w),
                "n={n} w={w}"
            );
        }
    }

    #[test]
    fn small_n_gives_all_pairs() {
        let ids = [1u64, 2, 3];
        let pairs = standard_sn(&ids, 10);
        assert_eq!(pairs.len(), 3); // C(3,2)
    }

    #[test]
    fn window_distance_property() {
        // every generated pair is within distance < w; every in-distance
        // pair is generated exactly once
        let n = 50;
        let w = 7;
        let ids: Vec<u64> = (0..n as u64).collect();
        let pairs = standard_sn(&ids, w);
        let set: std::collections::BTreeSet<Pair> = pairs.iter().copied().collect();
        assert_eq!(set.len(), pairs.len(), "duplicates generated");
        for i in 0..n as u64 {
            for j in (i + 1)..n as u64 {
                let within = (j - i) < w as u64;
                assert_eq!(set.contains(&Pair::new(i, j)), within);
            }
        }
    }

    #[test]
    fn seed_does_not_compare() {
        let mut win = SlidingWindow::new(3);
        win.seed(10u64);
        win.seed(20);
        let mut pairs = Vec::new();
        win.push(30, |&a, &b| pairs.push((a, b)));
        assert_eq!(pairs, vec![(10, 30), (20, 30)]);
        assert_eq!(win.comparisons(), 2);
    }

    #[test]
    fn seed_evicts_oldest() {
        let mut win = SlidingWindow::new(3); // buffer holds 2
        win.seed(1u64);
        win.seed(2);
        win.seed(3);
        let mut pairs = Vec::new();
        win.push(4, |&a, &b| pairs.push((a, b)));
        assert_eq!(pairs, vec![(2, 4), (3, 4)]);
    }

    #[test]
    fn srp_missing_formula() {
        assert_eq!(srp_missing_pairs(2, 3), 3); // Figure 5: misses 3 pairs
        assert_eq!(srp_missing_pairs(1, 100), 0);
        assert_eq!(srp_missing_pairs(8, 10), 7 * 45);
    }
}
