//! Multi-pass Sorted Neighborhood (§4: "The SN approach may also be
//! repeatedly executed using different blocking keys.  Such a multi-pass
//! strategy diminishes the influence of poor blocking keys … whilst still
//! maintaining the linear complexity").
//!
//! Each pass is a full RepSN run with its own blocking key; results are
//! unioned (set semantics on pairs, max-score on matches).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::er::blockkey::BlockingKey;
use crate::er::entity::{Entity, Pair, ScoredPair};
use crate::mapreduce::counters::Counters;
use crate::sn::types::{SnConfig, SnResult};
use crate::sn::{repsn, SnMode};

/// Union results of several RepSN passes with different blocking keys.
pub fn run(
    entities: &[Entity],
    base_cfg: &SnConfig,
    keys: &[Arc<dyn BlockingKey>],
) -> anyhow::Result<MultipassResult> {
    anyhow::ensure!(!keys.is_empty(), "multipass needs at least one key");
    let counters = Arc::new(Counters::new());
    let mut pair_set: BTreeMap<Pair, f32> = BTreeMap::new();
    let mut per_pass = Vec::new();
    let mut new_per_pass = Vec::new();
    for key in keys {
        let cfg = SnConfig {
            blocking_key: Arc::clone(key),
            ..base_cfg.clone()
        };
        let res = repsn::run(entities, &cfg)?;
        counters.merge(&res.counters);
        let mut newly = 0usize;
        match base_cfg.mode {
            SnMode::Blocking => {
                for p in &res.pairs {
                    if pair_set.insert(*p, 0.0).is_none() {
                        newly += 1;
                    }
                }
            }
            SnMode::Matching(_) => {
                for m in &res.matches {
                    let e = pair_set.entry(m.pair).or_insert_with(|| {
                        newly += 1;
                        m.score
                    });
                    if m.score > *e {
                        *e = m.score;
                    }
                }
            }
        }
        new_per_pass.push(newly);
        per_pass.push(res);
    }
    let is_matching = matches!(base_cfg.mode, SnMode::Matching(_));
    let (pairs, matches) = if is_matching {
        (
            Vec::new(),
            pair_set
                .into_iter()
                .map(|(pair, score)| ScoredPair { pair, score })
                .collect(),
        )
    } else {
        (pair_set.into_keys().collect(), Vec::new())
    };
    Ok(MultipassResult {
        union: SnResult {
            pairs,
            matches,
            counters,
            stats: per_pass.iter().flat_map(|r| r.stats.clone()).collect(),
            profiles: per_pass.iter().flat_map(|r| r.profiles.clone()).collect(),
        },
        per_pass,
        new_per_pass,
    })
}

/// Result of a multi-pass run.
#[derive(Debug)]
pub struct MultipassResult {
    /// Unioned pairs/matches across passes.
    pub union: SnResult,
    /// Individual pass results (diagnostics).
    pub per_pass: Vec<SnResult>,
    /// How many pairs each pass contributed that earlier passes missed.
    pub new_per_pass: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::{TitlePrefixKey, TitleSuffixKey};

    #[test]
    fn second_pass_recovers_dirty_prefix_duplicates() {
        // two duplicates whose titles differ in the FIRST word (prefix key
        // separates them) but share the last word (suffix key unites them)
        let mut entities: Vec<Entity> = (0..60)
            .map(|i| {
                let c1 = (b'a' + (i % 26) as u8) as char;
                Entity::new(i, &format!("{c1}{c1} filler title number{i}"), "")
            })
            .collect();
        entities.push(Entity::new(100, "aa same ending zz", ""));
        entities.push(Entity::new(101, "zz same ending zz", ""));
        let base = SnConfig {
            window: 3,
            num_map_tasks: 2,
            workers: 2,
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            ..Default::default()
        };
        let keys: Vec<Arc<dyn BlockingKey>> = vec![
            Arc::new(TitlePrefixKey::new(2)),
            Arc::new(TitleSuffixKey),
        ];
        let res = run(&entities, &base, &keys).unwrap();
        let pair = Pair::new(100, 101);
        assert!(
            !res.per_pass[0].pair_set().contains(&pair),
            "prefix pass should miss the dirty pair"
        );
        assert!(
            res.per_pass[1].pair_set().contains(&pair),
            "suffix pass should find it"
        );
        assert!(res.union.pair_set().contains(&pair));
        assert!(res.new_per_pass[1] > 0);
    }

    #[test]
    fn union_is_superset_of_each_pass() {
        let entities: Vec<Entity> = (0..80)
            .map(|i| Entity::new(i, &format!("{} word tail{}", (b'a' + (i % 9) as u8) as char, i % 4), ""))
            .collect();
        let base = SnConfig {
            window: 3,
            ..Default::default()
        };
        let keys: Vec<Arc<dyn BlockingKey>> = vec![
            Arc::new(TitlePrefixKey::new(2)),
            Arc::new(TitleSuffixKey),
        ];
        let res = run(&entities, &base, &keys).unwrap();
        let union: std::collections::BTreeSet<_> = res.union.pair_set().into_iter().collect();
        for pass in &res.per_pass {
            for p in pass.pair_set() {
                assert!(union.contains(&p));
            }
        }
    }
}
