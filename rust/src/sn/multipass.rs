//! Multi-pass Sorted Neighborhood (§4: "The SN approach may also be
//! repeatedly executed using different blocking keys.  Such a multi-pass
//! strategy diminishes the influence of poor blocking keys … whilst still
//! maintaining the linear complexity").
//!
//! Each pass is a full RepSN run with its own blocking key; results are
//! unioned (set semantics on pairs, max-score on matches).
//!
//! The passes are *independent* MapReduce jobs, so [`run`] submits all of
//! them to one shared [`JobScheduler`] and their map/reduce tasks
//! interleave across its slots — pass 2's map wave runs while pass 1 is
//! still reducing, instead of the old job-at-a-time loop.  The union is
//! folded in key order regardless of completion order, so the result is
//! byte-identical to the serial baseline ([`run_serial`], kept as the
//! reference the property tests and the skew bench compare against).
//!
//! A [`BalanceStrategy`](crate::sn::loadbalance::BalanceStrategy) on the
//! base config applies to every pass: each per-key submission becomes the
//! two-job BDM + repartition pipeline (see
//! [`repsn::submit`](crate::sn::repsn::submit)), all still interleaved on
//! the one scheduler.  Likewise an [`SnSpill`](crate::sn::types::SnSpill)
//! on the base config makes every pass run disk-backed (concurrent passes
//! share the spill directory; run files are globally uniquely named).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::er::blockkey::BlockingKey;
use crate::er::entity::{Entity, Pair, ScoredPair};
use crate::mapreduce::counters::Counters;
use crate::mapreduce::scheduler::{JobScheduler, SchedulerConfig};
use crate::sn::types::{SnConfig, SnResult};
use crate::sn::{repsn, SnMode};

/// Union results of several RepSN passes with different blocking keys.
///
/// All passes run concurrently on a scheduler with `base_cfg.workers` map
/// and reduce slots (speculation off); use [`run_on`] to supply your own
/// scheduler — e.g. one shared with other jobs, or one with speculative
/// execution enabled.
pub fn run(
    entities: &[Entity],
    base_cfg: &SnConfig,
    keys: &[Arc<dyn BlockingKey>],
) -> anyhow::Result<MultipassResult> {
    let sched = JobScheduler::new(SchedulerConfig::slots(base_cfg.workers.max(1)));
    run_on(entities, base_cfg, keys, &sched)
}

/// As [`run`], submitting every pass to the given shared scheduler.
pub fn run_on(
    entities: &[Entity],
    base_cfg: &SnConfig,
    keys: &[Arc<dyn BlockingKey>],
    sched: &JobScheduler,
) -> anyhow::Result<MultipassResult> {
    anyhow::ensure!(!keys.is_empty(), "multipass needs at least one key");
    // fan out: every per-key job is in flight before the first joins
    let pending: Vec<repsn::PendingRepSn> = keys
        .iter()
        .map(|key| {
            let cfg = SnConfig {
                blocking_key: Arc::clone(key),
                ..base_cfg.clone()
            };
            repsn::submit(entities, &cfg, sched)
        })
        .collect();
    let mut per_pass = Vec::with_capacity(pending.len());
    for p in pending {
        per_pass.push(p.join()?);
    }
    Ok(union_passes(base_cfg, per_pass))
}

/// The serial baseline: one pass at a time, each on its own private
/// worker pool.  Kept as the reference implementation the scheduler path
/// is checked against (`tests/prop_sched.rs`) and the speedup baseline
/// the skew bench measures.
pub fn run_serial(
    entities: &[Entity],
    base_cfg: &SnConfig,
    keys: &[Arc<dyn BlockingKey>],
) -> anyhow::Result<MultipassResult> {
    anyhow::ensure!(!keys.is_empty(), "multipass needs at least one key");
    let mut per_pass = Vec::with_capacity(keys.len());
    for key in keys {
        let cfg = SnConfig {
            blocking_key: Arc::clone(key),
            ..base_cfg.clone()
        };
        per_pass.push(repsn::run(entities, &cfg)?);
    }
    Ok(union_passes(base_cfg, per_pass))
}

/// Fold finished passes (in key order) into the union result.  Pure
/// post-processing: identical no matter how the passes were executed.
fn union_passes(base_cfg: &SnConfig, per_pass: Vec<SnResult>) -> MultipassResult {
    let counters = Arc::new(Counters::new());
    let mut pair_set: BTreeMap<Pair, f32> = BTreeMap::new();
    let mut new_per_pass = Vec::with_capacity(per_pass.len());
    for res in &per_pass {
        counters.merge(&res.counters);
        let mut newly = 0usize;
        match base_cfg.mode {
            SnMode::Blocking => {
                for p in &res.pairs {
                    if pair_set.insert(*p, 0.0).is_none() {
                        newly += 1;
                    }
                }
            }
            SnMode::Matching(_) => {
                for m in &res.matches {
                    let e = pair_set.entry(m.pair).or_insert_with(|| {
                        newly += 1;
                        m.score
                    });
                    if m.score > *e {
                        *e = m.score;
                    }
                }
            }
        }
        new_per_pass.push(newly);
    }
    let is_matching = matches!(base_cfg.mode, SnMode::Matching(_));
    let (pairs, matches) = if is_matching {
        (
            Vec::new(),
            pair_set
                .into_iter()
                .map(|(pair, score)| ScoredPair { pair, score })
                .collect(),
        )
    } else {
        (pair_set.into_keys().collect(), Vec::new())
    };
    MultipassResult {
        union: SnResult {
            pairs,
            matches,
            counters,
            stats: per_pass.iter().flat_map(|r| r.stats.clone()).collect(),
            profiles: per_pass.iter().flat_map(|r| r.profiles.clone()).collect(),
        },
        per_pass,
        new_per_pass,
    }
}

/// Result of a multi-pass run.
#[derive(Debug)]
pub struct MultipassResult {
    /// Unioned pairs/matches across passes.
    pub union: SnResult,
    /// Individual pass results (diagnostics), in blocking-key order.
    pub per_pass: Vec<SnResult>,
    /// How many pairs each pass contributed that earlier passes missed.
    pub new_per_pass: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::{TitlePrefixKey, TitleSuffixKey};

    #[test]
    fn second_pass_recovers_dirty_prefix_duplicates() {
        // two duplicates whose titles differ in the FIRST word (prefix key
        // separates them) but share the last word (suffix key unites them)
        let mut entities: Vec<Entity> = (0..60)
            .map(|i| {
                let c1 = (b'a' + (i % 26) as u8) as char;
                Entity::new(i, &format!("{c1}{c1} filler title number{i}"), "")
            })
            .collect();
        entities.push(Entity::new(100, "aa same ending zz", ""));
        entities.push(Entity::new(101, "zz same ending zz", ""));
        let base = SnConfig {
            window: 3,
            num_map_tasks: 2,
            workers: 2,
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            ..Default::default()
        };
        let keys: Vec<Arc<dyn BlockingKey>> = vec![
            Arc::new(TitlePrefixKey::new(2)),
            Arc::new(TitleSuffixKey),
        ];
        let res = run(&entities, &base, &keys).unwrap();
        let pair = Pair::new(100, 101);
        assert!(
            !res.per_pass[0].pair_set().contains(&pair),
            "prefix pass should miss the dirty pair"
        );
        assert!(
            res.per_pass[1].pair_set().contains(&pair),
            "suffix pass should find it"
        );
        assert!(res.union.pair_set().contains(&pair));
        assert!(res.new_per_pass[1] > 0);
    }

    #[test]
    fn union_is_superset_of_each_pass() {
        let entities: Vec<Entity> = (0..80)
            .map(|i| Entity::new(i, &format!("{} word tail{}", (b'a' + (i % 9) as u8) as char, i % 4), ""))
            .collect();
        let base = SnConfig {
            window: 3,
            ..Default::default()
        };
        let keys: Vec<Arc<dyn BlockingKey>> = vec![
            Arc::new(TitlePrefixKey::new(2)),
            Arc::new(TitleSuffixKey),
        ];
        let res = run(&entities, &base, &keys).unwrap();
        let union: std::collections::BTreeSet<_> = res.union.pair_set().into_iter().collect();
        for pass in &res.per_pass {
            for p in pass.pair_set() {
                assert!(union.contains(&p));
            }
        }
    }

    #[test]
    fn concurrent_run_matches_serial_baseline() {
        let entities: Vec<Entity> = (0..120)
            .map(|i| {
                let c1 = (b'a' + (i % 11) as u8) as char;
                Entity::new(i, &format!("{c1}x some title word{}", i % 6), "")
            })
            .collect();
        let base = SnConfig {
            window: 4,
            num_map_tasks: 3,
            workers: 4,
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            ..Default::default()
        };
        let keys: Vec<Arc<dyn BlockingKey>> = vec![
            Arc::new(TitlePrefixKey::new(2)),
            Arc::new(TitleSuffixKey),
            Arc::new(TitlePrefixKey::new(1)),
        ];
        let serial = run_serial(&entities, &base, &keys).unwrap();
        let concurrent = run(&entities, &base, &keys).unwrap();
        assert_eq!(serial.union.pair_set(), concurrent.union.pair_set());
        assert_eq!(serial.new_per_pass, concurrent.new_per_pass);
        for (s, c) in serial.per_pass.iter().zip(&concurrent.per_pass) {
            assert_eq!(s.pair_set(), c.pair_set());
            assert_eq!(
                s.stats[0].map_output_records,
                c.stats[0].map_output_records
            );
            assert_eq!(
                s.stats[0].reduce_output_records,
                c.stats[0].reduce_output_records
            );
        }
    }
}
