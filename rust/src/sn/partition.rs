//! Monotonic range-partition functions `p : k → i` and skew metrics.
//!
//! §4.1: "A monotonically increasing function p (p(k1) ≥ p(k2) if
//! k1 ≥ k2) ensures that all entities assigned to reducer i have a smaller
//! or equal blocking key than any entity processed by reducer i+1" — and
//! "in practice simple range partitioning functions p may be employed."
//!
//! §5.3 evaluates partitioning strategies by the **Gini coefficient** of
//! their partition sizes (Table 1): the Manual/balanced function (g≈0.13),
//! even key-space splits (Even10/Even8), and skew-shaped variants.

use crate::er::entity::Entity;

/// A monotonic partition function over blocking keys.
pub trait PartitionFn: Send + Sync {
    /// Partition index in `[0, num_partitions)`.  MUST be monotone with
    /// respect to byte-lexicographic key order.
    fn partition(&self, key: &str) -> usize;

    fn num_partitions(&self) -> usize;

    fn name(&self) -> String;
}

/// Range partitioning by explicit upper boundaries.
///
/// `boundaries` has length `r − 1`, sorted ascending;
/// `p(k) = #{ b ∈ boundaries : b ≤ k }` — i.e. partition `i` holds keys in
/// `[boundaries[i−1], boundaries[i])`.
#[derive(Debug, Clone)]
pub struct RangePartition {
    boundaries: Vec<String>,
    label: String,
}

impl RangePartition {
    pub fn new(boundaries: Vec<String>, label: &str) -> Self {
        for w in boundaries.windows(2) {
            assert!(w[0] <= w[1], "boundaries must be sorted");
        }
        Self {
            boundaries,
            label: label.to_string(),
        }
    }

    /// The paper's "manually defined" balanced function: choose boundaries
    /// at the key-distribution quantiles of a sample so the `r` partitions
    /// have near-equal sizes.
    pub fn balanced<F: Fn(&Entity) -> String>(
        entities: &[Entity],
        key_fn: F,
        r: usize,
    ) -> Self {
        assert!(r >= 1);
        let mut keys: Vec<String> = entities.iter().map(key_fn).collect();
        keys.sort_unstable();
        let n = keys.len();
        let mut boundaries = Vec::with_capacity(r.saturating_sub(1));
        for i in 1..r {
            let idx = (i * n) / r;
            let b = keys.get(idx).cloned().unwrap_or_default();
            boundaries.push(b);
        }
        // boundaries may repeat if the quantile lands inside a giant key
        // run; keep them (empty partitions are legal, the engine handles
        // zero-entity reduce tasks)
        Self {
            boundaries,
            label: format!("Manual{r}"),
        }
    }
}

impl PartitionFn for RangePartition {
    fn partition(&self, key: &str) -> usize {
        self.boundaries.partition_point(|b| b.as_str() <= key)
    }

    fn num_partitions(&self) -> usize {
        self.boundaries.len() + 1
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

/// Even split of the two-character key space (§5.3's Even10/Even8).
///
/// Keys are mapped to a numeric position using the *blocking-key
/// alphabet* — space, `0-9`, `a-z`, `~` (what [`TitlePrefixKey`] actually
/// emits) — via the order-preserving rank "number of alphabet characters
/// with byte value ≤ b", and the `A²` position range is cut into `k`
/// equal intervals.  Monotone w.r.t. byte-lexicographic string order by
/// construction.
///
/// [`TitlePrefixKey`]: crate::er::blockkey::TitlePrefixKey
#[derive(Debug, Clone)]
pub struct EvenPartition {
    k: usize,
}

/// The blocking-key alphabet, ascending by byte value.
const KEY_ALPHABET: &[u8] = &[
    b' ', b'0', b'1', b'2', b'3', b'4', b'5', b'6', b'7', b'8', b'9',
    b'a', b'b', b'c', b'd', b'e', b'f', b'g', b'h', b'i', b'j', b'k',
    b'l', b'm', b'n', b'o', b'p', b'q', b'r', b's', b't', b'u', b'v',
    b'w', b'x', b'y', b'z', b'~',
];

impl EvenPartition {
    /// Even split over the blocking-key alphabet.
    pub fn ascii(k: usize) -> Self {
        assert!(k >= 1);
        Self { k }
    }

    fn alpha_size() -> u64 {
        KEY_ALPHABET.len() as u64 + 1 // +1: rank 0 = "below everything"
    }

    /// Order-preserving rank: #alphabet chars with byte ≤ b.
    fn rank(b: u8) -> u64 {
        KEY_ALPHABET.partition_point(|&c| c <= b) as u64
    }

    /// Numeric position of a key in `[0, A²)`.
    fn position(key: &str) -> u64 {
        let bytes = key.as_bytes();
        let a = Self::alpha_size();
        let b0 = bytes.first().map(|&b| Self::rank(b)).unwrap_or(0);
        let b1 = bytes.get(1).map(|&b| Self::rank(b)).unwrap_or(0);
        b0 * a + b1
    }
}

impl PartitionFn for EvenPartition {
    fn partition(&self, key: &str) -> usize {
        let a = Self::alpha_size();
        let span = a * a;
        ((Self::position(key) * self.k as u64) / span) as usize
    }

    fn num_partitions(&self) -> usize {
        self.k
    }

    fn name(&self) -> String {
        format!("Even{}", self.k)
    }
}

/// Gini coefficient of partition sizes (§5.3):
/// `g = (2·Σ i·y_i)/(n·Σ y_i) − (n+1)/n` with `y` ascending, `i` 1-based.
/// 0 = perfectly equal partitions, →1 = maximal inequality.
pub fn gini(sizes: &[usize]) -> f64 {
    let n = sizes.len();
    if n == 0 {
        return 0.0;
    }
    let total: u64 = sizes.iter().map(|&s| s as u64).sum();
    if total == 0 {
        return 0.0;
    }
    let mut y: Vec<u64> = sizes.iter().map(|&s| s as u64).collect();
    y.sort_unstable();
    let weighted: u128 = y
        .iter()
        .enumerate()
        .map(|(i, &v)| (i as u128 + 1) * v as u128)
        .sum();
    (2.0 * weighted as f64) / (n as f64 * total as f64) - (n as f64 + 1.0) / n as f64
}

/// Histogram of partition sizes for a key multiset under `p`.
pub fn partition_sizes(keys: impl Iterator<Item = String>, p: &dyn PartitionFn) -> Vec<usize> {
    let mut sizes = vec![0usize; p.num_partitions()];
    for k in keys {
        sizes[p.partition(&k)] += 1;
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_partition_monotone() {
        let p = RangePartition::new(vec!["d".into(), "m".into()], "test");
        assert_eq!(p.num_partitions(), 3);
        assert_eq!(p.partition("a"), 0);
        assert_eq!(p.partition("c~"), 0);
        assert_eq!(p.partition("d"), 1);
        assert_eq!(p.partition("lz"), 1);
        assert_eq!(p.partition("m"), 2);
        assert_eq!(p.partition("zz"), 2);
    }

    #[test]
    fn balanced_gives_near_equal_sizes() {
        let entities: Vec<Entity> = (0..1000)
            .map(|i| {
                let c = (b'a' + (i % 26) as u8) as char;
                Entity::new(i as u64, &format!("{c}{c} title"), "")
            })
            .collect();
        let p = RangePartition::balanced(&entities, |e| e.title[..2].to_string(), 8);
        let sizes = partition_sizes(
            entities.iter().map(|e| e.title[..2].to_string()),
            &p,
        );
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
        let g = gini(&sizes);
        assert!(g < 0.15, "balanced partition too skewed: g={g} sizes={sizes:?}");
    }

    #[test]
    fn even_partition_monotone_and_covers() {
        let p = EvenPartition::ascii(8);
        let keys = ["  ", "a ", "ab", "mz", "zz", "~~"];
        let mut last = 0;
        for k in keys {
            let i = p.partition(k);
            assert!(i >= last, "non-monotone at {k}");
            assert!(i < 8);
            last = i;
        }
    }

    #[test]
    fn even_partition_spreads_alphabet() {
        let p = EvenPartition::ascii(10);
        let a = p.partition("aa");
        let z = p.partition("zz");
        assert!(z > a + 3, "a→{a} z→{z}");
    }

    #[test]
    fn gini_extremes() {
        assert_eq!(gini(&[10, 10, 10, 10]), 0.0);
        // all mass in one of n partitions → g = (n-1)/n
        let g = gini(&[0, 0, 0, 100]);
        assert!((g - 0.75).abs() < 1e-9);
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[0, 0]), 0.0);
    }

    #[test]
    fn gini_monotone_in_skew() {
        let g1 = gini(&[25, 25, 25, 25]);
        let g2 = gini(&[10, 20, 30, 40]);
        let g3 = gini(&[5, 5, 10, 80]);
        assert!(g1 < g2 && g2 < g3);
    }

    #[test]
    fn partition_sizes_counts() {
        let p = RangePartition::new(vec!["m".into()], "half");
        let keys = vec!["a".to_string(), "b".into(), "x".into()];
        assert_eq!(partition_sizes(keys.into_iter(), &p), vec![2, 1]);
    }
}
