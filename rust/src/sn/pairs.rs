//! Reduce-side window processing shared by SRP / JobSN / RepSN.
//!
//! [`WindowProc`] wraps the sliding window with the configured
//! [`SnMode`]: in Blocking mode every window comparison is emitted as a
//! correspondence (`B` in the figures); in Matching mode comparisons are
//! queued into a [`PairBatcher`] and only matches are emitted.  Entities
//! are encoded at most once per reduce partition (on window entry).
//!
//! Every buffered item carries a `tag` (the SN variants use the *home
//! partition* `p(k)`): the pair filter sees both tags, which is how JobSN
//! phase 2 drops same-partition pairs ("filters correspondences that have
//! already been determined in the first MapReduce job") and how RepSN
//! restricts output to pairs involving at least one original entity.

use std::sync::Arc;

use crate::er::entity::{Entity, Pair};
use crate::er::strategy::{EncodedEntity, PairBatcher};
use crate::mapreduce::counters::Counters;
use crate::mapreduce::types::Emitter;
use crate::sn::types::{counter_names, SnKey, SnMode, SnVal};
use crate::sn::window::SlidingWindow;

/// Identity + provenance of a buffered entity, visible to pair filters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WinItem {
    pub id: u64,
    /// Variant-defined provenance tag (home partition for the SN jobs).
    pub tag: u32,
}

struct Buffered {
    item: WinItem,
    enc: Option<Arc<EncodedEntity>>,
}

/// The per-reduce-partition window processor.
pub struct WindowProc {
    win: SlidingWindow<Buffered>,
    batcher: Option<PairBatcher>,
    /// Pairs collected in blocking mode, flushed on `finish`.
    pending_pairs: Vec<Pair>,
    comparisons: u64,
    filtered: u64,
}

impl WindowProc {
    pub fn new(w: usize, mode: &SnMode) -> Self {
        Self {
            win: SlidingWindow::new(w.max(2)),
            batcher: match mode {
                SnMode::Blocking => None,
                SnMode::Matching(cfg) => Some(PairBatcher::new(cfg.clone())),
            },
            pending_pairs: Vec::new(),
            comparisons: 0,
            filtered: 0,
        }
    }

    fn wrap(&self, e: &Arc<Entity>, tag: u32) -> Buffered {
        Buffered {
            item: WinItem { id: e.id, tag },
            enc: if self.batcher.is_some() {
                Some(Arc::new(EncodedEntity::new(Arc::clone(e))))
            } else {
                None
            },
        }
    }

    /// Seed the window without comparisons (RepSN replica prefix).
    pub fn seed(&mut self, e: &Arc<Entity>, tag: u32) {
        let b = self.wrap(e, tag);
        self.win.seed(b);
    }

    /// Push the next entity, generating its window comparisons.
    /// `pair_filter(older, newer)` can veto a comparison.
    pub fn push<F: FnMut(WinItem, WinItem) -> bool>(
        &mut self,
        e: &Arc<Entity>,
        tag: u32,
        mut pair_filter: F,
    ) {
        let item = self.wrap(e, tag);
        let batcher = &mut self.batcher;
        let pending = &mut self.pending_pairs;
        let mut cmp = 0u64;
        let mut filtered = 0u64;
        self.win.push(item, |old, new| {
            if !pair_filter(old.item, new.item) {
                filtered += 1;
                return;
            }
            cmp += 1;
            match (&old.enc, &new.enc, &mut *batcher) {
                (Some(a), Some(b), Some(batch)) => {
                    batch.push(Arc::clone(a), Arc::clone(b));
                }
                _ => {
                    pending.push(Pair::new(old.item.id, new.item.id));
                }
            }
        });
        self.comparisons += cmp;
        self.filtered += filtered;
    }

    /// Flush results into the reduce emitter under `key`.
    pub fn finish(self, key: &SnKey, out: &mut Emitter<SnKey, SnVal>, counters: &Counters) {
        counters.add(counter_names::COMPARISONS, self.comparisons);
        counters.add(counter_names::PAIRS_FILTERED_DUPLICATE, self.filtered);
        // Output key: partition lineage only, with an empty (non-allocating)
        // blocking-key string — pair outputs are emitted in bulk and a
        // String allocation per pair dominated the blocking-mode profile.
        let out_key = SnKey {
            bound: key.bound,
            part: key.part,
            key: String::new(),
            id: 0,
        };
        match self.batcher {
            None => {
                for p in self.pending_pairs {
                    out.emit(out_key.clone(), SnVal::Pair(p));
                }
            }
            Some(b) => {
                counters.add(counter_names::PAIRS_SKIPPED_SHORTCIRCUIT, b.pairs_skipped);
                let matches = b.finish();
                counters.add(counter_names::MATCHES, matches.len() as u64);
                for m in matches {
                    out.emit(out_key.clone(), SnVal::Match(m));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::strategy::MatchStrategyConfig;

    fn ent(id: u64, title: &str) -> Arc<Entity> {
        Arc::new(Entity::new(id, title, "shared abstract text"))
    }

    fn key() -> SnKey {
        SnKey::srp(0, "aa".into(), 0)
    }

    fn collect_pairs(out: Emitter<SnKey, SnVal>) -> Vec<Pair> {
        out.into_pairs()
            .into_iter()
            .filter_map(|(_, v)| match v {
                SnVal::Pair(p) => Some(p),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn blocking_mode_emits_all_window_pairs() {
        let mut proc = WindowProc::new(3, &SnMode::Blocking);
        for i in 0..5 {
            proc.push(&ent(i, "t"), 0, |_, _| true);
        }
        let counters = Counters::new();
        let mut out = Emitter::new();
        proc.finish(&key(), &mut out, &counters);
        assert_eq!(out.len(), 7); // (5-3)*2 + 3 = 7
        assert_eq!(counters.get(counter_names::COMPARISONS), 7);
    }

    #[test]
    fn matching_mode_emits_only_matches() {
        let cfg = MatchStrategyConfig::default();
        let mut proc = WindowProc::new(2, &SnMode::Matching(cfg));
        proc.push(&ent(1, "identical title here"), 0, |_, _| true);
        proc.push(&ent(2, "identical title here"), 0, |_, _| true);
        proc.push(&ent(3, "zzz completely unrelated qqq"), 0, |_, _| true);
        let counters = Counters::new();
        let mut out = Emitter::new();
        proc.finish(&key(), &mut out, &counters);
        let vals = out.into_pairs();
        assert_eq!(vals.len(), 1);
        match &vals[0].1 {
            SnVal::Match(m) => assert_eq!(m.pair, Pair::new(1, 2)),
            other => panic!("expected match, got {other:?}"),
        }
        assert_eq!(counters.get(counter_names::MATCHES), 1);
        assert_eq!(counters.get(counter_names::COMPARISONS), 2);
    }

    #[test]
    fn tag_filter_vetoes_and_counts() {
        let mut proc = WindowProc::new(3, &SnMode::Blocking);
        for i in 0..4 {
            proc.push(&ent(i, "t"), (i % 2) as u32, |a, b| a.tag != b.tag);
        }
        let counters = Counters::new();
        let mut out = Emitter::new();
        proc.finish(&key(), &mut out, &counters);
        let pairs = collect_pairs(out);
        for p in &pairs {
            assert_ne!(p.a % 2, p.b % 2);
        }
        assert_eq!(
            counters.get(counter_names::COMPARISONS) + counters.get(counter_names::PAIRS_FILTERED_DUPLICATE),
            5
        );
    }

    #[test]
    fn seeded_entities_pair_with_pushed_only() {
        let mut proc = WindowProc::new(3, &SnMode::Blocking);
        proc.seed(&ent(100, "t"), 0);
        proc.seed(&ent(101, "t"), 0);
        proc.push(&ent(1, "t"), 1, |_, _| true);
        let counters = Counters::new();
        let mut out = Emitter::new();
        proc.finish(&key(), &mut out, &counters);
        assert_eq!(
            collect_pairs(out),
            vec![Pair::new(100, 1), Pair::new(101, 1)]
        );
    }
}
