//! Codecs for the SN variants' intermediate record types, and the
//! [`SpillSpec`] builders that plug them into the engine's disk-backed
//! data path.
//!
//! Every SN MapReduce job shuffles one of a handful of `(key, value)`
//! shapes; this module gives each shape a [`Codec`] so
//! [`SnConfig::spill`](crate::sn::types::SnConfig) can route the *whole*
//! SN family — SRP, JobSN (both phases), RepSN, standard blocking,
//! multipass, and the loadbalance BDM + repartition pipeline — through
//! codec-serialized, optionally DEFLATE-compressed run files:
//!
//! | job                          | intermediate `(K, V)`          | spec builder            |
//! |------------------------------|--------------------------------|-------------------------|
//! | SRP / JobSN p1 / RepSN       | `(SnKey, Arc<Entity>)`         | [`entity_job_spec`]     |
//! | JobSN phase 2                | `(SnKey, (u32, Arc<Entity>))`  | [`boundary_job_spec`]   |
//! | standard blocking            | `(String, Arc<Entity>)`        | [`block_job_spec`]      |
//! | BlockSplit / PairRange       | `(SnKey, Ranked)`              | [`ranked_job_spec`]     |
//! | BDM analysis                 | `((String, u32), u64)`         | [`bdm_job_spec`]        |

use std::sync::Arc;

use anyhow::Result;
use byteorder::{LittleEndian, ReadBytesExt, WriteBytesExt};

use crate::er::entity::Entity;
use crate::mapreduce::sortspill::{
    decode_string, encode_string, Codec, KeyValueCodec, SpillSpec, StringCodec, U32Codec, U64Codec,
};
use crate::sn::loadbalance::Ranked;
use crate::sn::types::{SnKey, SnSpill};

/// Codec for the composite [`SnKey`]: `bound`, `part`, blocking key, id.
pub struct SnKeyCodec;

impl Codec<SnKey> for SnKeyCodec {
    fn encode(&self, t: &SnKey, out: &mut Vec<u8>) {
        out.write_u32::<LittleEndian>(t.bound).unwrap();
        out.write_u32::<LittleEndian>(t.part).unwrap();
        encode_string(&t.key, out);
        out.write_u64::<LittleEndian>(t.id).unwrap();
    }

    fn decode(&self, cur: &mut &[u8]) -> Result<SnKey> {
        Ok(SnKey {
            bound: cur.read_u32::<LittleEndian>()?,
            part: cur.read_u32::<LittleEndian>()?,
            key: decode_string(cur)?,
            id: cur.read_u64::<LittleEndian>()?,
        })
    }
}

/// Codec for full [`Entity`] records (every field, so decode∘encode is
/// identity — the reduce side sees exactly the mapped entities).
pub struct EntityCodec;

impl Codec<Entity> for EntityCodec {
    fn encode(&self, e: &Entity, out: &mut Vec<u8>) {
        out.write_u64::<LittleEndian>(e.id).unwrap();
        encode_string(&e.title, out);
        encode_string(&e.abstract_text, out);
        encode_string(&e.authors, out);
        out.write_u16::<LittleEndian>(e.year).unwrap();
        encode_string(&e.venue, out);
    }

    fn decode(&self, cur: &mut &[u8]) -> Result<Entity> {
        Ok(Entity {
            id: cur.read_u64::<LittleEndian>()?,
            title: decode_string(cur)?,
            abstract_text: decode_string(cur)?,
            authors: decode_string(cur)?,
            year: cur.read_u16::<LittleEndian>()?,
            venue: decode_string(cur)?,
        })
    }
}

/// Lift a codec for `T` to `Arc<T>` (decode allocates a fresh `Arc` —
/// spilled runs trade the sharing for bounded memory, by design).
pub struct ArcCodec<C>(pub C);

impl<T, C: Codec<T>> Codec<Arc<T>> for ArcCodec<C> {
    fn encode(&self, t: &Arc<T>, out: &mut Vec<u8>) {
        self.0.encode(t, out);
    }

    fn decode(&self, cur: &mut &[u8]) -> Result<Arc<T>> {
        Ok(Arc::new(self.0.decode(cur)?))
    }
}

/// Codec for the loadbalance [`Ranked`] value: global rank + entity.
pub struct RankedCodec;

impl Codec<Ranked> for RankedCodec {
    fn encode(&self, t: &Ranked, out: &mut Vec<u8>) {
        out.write_u64::<LittleEndian>(t.rank).unwrap();
        EntityCodec.encode(&t.entity, out);
    }

    fn decode(&self, cur: &mut &[u8]) -> Result<Ranked> {
        Ok(Ranked {
            rank: cur.read_u64::<LittleEndian>()?,
            entity: Arc::new(EntityCodec.decode(cur)?),
        })
    }
}

/// Spill spec for the `(SnKey, Arc<Entity>)` jobs (SRP, JobSN phase 1,
/// RepSN).
pub fn entity_job_spec(spill: &SnSpill) -> SpillSpec {
    let codec: Arc<dyn Codec<(SnKey, Arc<Entity>)>> =
        Arc::new(KeyValueCodec::new(SnKeyCodec, ArcCodec(EntityCodec)));
    SpillSpec::new(spill.dir.clone(), codec).with_compress(spill.compress)
}

/// Spill spec for JobSN's phase-2 boundary job:
/// `(SnKey, (u32, Arc<Entity>))`.
pub fn boundary_job_spec(spill: &SnSpill) -> SpillSpec {
    let codec: Arc<dyn Codec<(SnKey, (u32, Arc<Entity>))>> = Arc::new(KeyValueCodec::new(
        SnKeyCodec,
        KeyValueCodec::new(U32Codec, ArcCodec(EntityCodec)),
    ));
    SpillSpec::new(spill.dir.clone(), codec).with_compress(spill.compress)
}

/// Spill spec for standard blocking: `(String, Arc<Entity>)`.
pub fn block_job_spec(spill: &SnSpill) -> SpillSpec {
    let codec: Arc<dyn Codec<(String, Arc<Entity>)>> =
        Arc::new(KeyValueCodec::new(StringCodec, ArcCodec(EntityCodec)));
    SpillSpec::new(spill.dir.clone(), codec).with_compress(spill.compress)
}

/// Spill spec for the BlockSplit / PairRange repartition jobs:
/// `(SnKey, Ranked)`.
pub fn ranked_job_spec(spill: &SnSpill) -> SpillSpec {
    let codec: Arc<dyn Codec<(SnKey, Ranked)>> =
        Arc::new(KeyValueCodec::new(SnKeyCodec, RankedCodec));
    SpillSpec::new(spill.dir.clone(), codec).with_compress(spill.compress)
}

/// Spill spec for the BDM analysis job: `((String, u32), u64)`.
pub fn bdm_job_spec(spill: &SnSpill) -> SpillSpec {
    let codec: Arc<dyn Codec<((String, u32), u64)>> = Arc::new(KeyValueCodec::new(
        KeyValueCodec::new(StringCodec, U32Codec),
        U64Codec,
    ));
    SpillSpec::new(spill.dir.clone(), codec).with_compress(spill.compress)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: PartialEq + std::fmt::Debug>(codec: &dyn Codec<T>, t: &T) {
        let mut buf = Vec::new();
        codec.encode(t, &mut buf);
        let mut cur = buf.as_slice();
        let back = codec.decode(&mut cur).unwrap();
        assert_eq!(&back, t);
        assert!(cur.is_empty(), "decode must consume the record exactly");
    }

    #[test]
    fn snkey_roundtrip() {
        roundtrip(
            &SnKeyCodec,
            &SnKey {
                bound: 3,
                part: 2,
                key: "ab".into(),
                id: 99,
            },
        );
        roundtrip(&SnKeyCodec, &SnKey::srp(0, String::new(), 0));
    }

    #[test]
    fn entity_roundtrip_all_fields() {
        let e = Entity {
            id: 42,
            title: "A Title with ünïcode".into(),
            abstract_text: "Some abstract. ".repeat(10),
            authors: "Kolb, Thor, Rahm".into(),
            year: 2010,
            venue: "BTW".into(),
        };
        roundtrip(&EntityCodec, &e);
        roundtrip(&ArcCodec(EntityCodec), &Arc::new(e));
    }

    #[test]
    fn ranked_roundtrip() {
        let r = Ranked {
            rank: 1234,
            entity: Arc::new(Entity::new(7, "t", "a")),
        };
        let mut buf = Vec::new();
        RankedCodec.encode(&r, &mut buf);
        let mut cur = buf.as_slice();
        let back = RankedCodec.decode(&mut cur).unwrap();
        assert_eq!(back.rank, r.rank);
        assert_eq!(&*back.entity, &*r.entity);
    }

    #[test]
    fn composed_job_record_roundtrip() {
        let codec = KeyValueCodec::new(
            SnKeyCodec,
            KeyValueCodec::new(U32Codec, ArcCodec(EntityCodec)),
        );
        let rec = (
            SnKey::srp(1, "zz".into(), 5),
            (3u32, Arc::new(Entity::new(5, "zz title", "abs"))),
        );
        let mut buf = Vec::new();
        codec.encode(&rec, &mut buf);
        let mut cur = buf.as_slice();
        let (k, (p, e)) = codec.decode(&mut cur).unwrap();
        assert_eq!(k, rec.0);
        assert_eq!(p, 3);
        assert_eq!(&*e, &*rec.1 .1);
        assert!(cur.is_empty());
    }
}
