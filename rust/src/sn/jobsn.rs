//! JobSN — Sorted Neighborhood with an additional MapReduce job
//! (§4.2, Figure 6, Algorithm 1).
//!
//! Phase 1 is SRP with an extended reduce: besides the window
//! correspondences, each reducer emits its first and last `w−1` entities
//! under a *boundary-prefixed* key `bound.r_i.k` ("the key reflects data
//! lineage").  Phase 2 repartitions those boundary entities by `bound`,
//! sorts by the composite key (so the predecessor's tail precedes the
//! successor's head), slides the window once more, and filters pairs whose
//! entities share a partition prefix — those were already produced in
//! phase 1.

use std::sync::Arc;

use crate::er::entity::Entity;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::scheduler::Exec;
use crate::mapreduce::sim::JobProfile;
use crate::mapreduce::types::{Emitter, FnMapTask, ReduceTask, ReduceTaskFactory, ValuesIter};
use crate::mapreduce::JobConfig;
use crate::sn::pairs::WindowProc;
use crate::sn::srp::{group_by_bound, run_srp_job, split_output, BoundPartitioner};
use crate::sn::types::{SnConfig, SnKey, SnMode, SnResult, SnVal};

/// Phase-2 reduce: window over one boundary group, keeping only pairs
/// that cross the partition boundary.
struct BoundaryReduce {
    w: usize,
    mode: SnMode,
}

impl ReduceTask<SnKey, (u32, Arc<Entity>), SnKey, SnVal> for BoundaryReduce {
    fn reduce(
        &mut self,
        key: &SnKey,
        values: ValuesIter<'_, (u32, Arc<Entity>)>,
        out: &mut Emitter<SnKey, SnVal>,
        counters: &Counters,
    ) {
        let mut proc = WindowProc::new(self.w, &self.mode);
        for (part, e) in values {
            // filter: only cross-partition pairs are new (Algorithm 1's
            // "filters correspondences already determined"; the lineage is
            // in the tags)
            proc.push(e, *part, |a, b| a.tag != b.tag);
        }
        proc.finish(key, out, counters);
    }
}

struct BoundaryReduceFactory {
    w: usize,
    mode: SnMode,
}

impl ReduceTaskFactory<SnKey, (u32, Arc<Entity>), SnKey, SnVal> for BoundaryReduceFactory {
    fn create_task(
        &self,
    ) -> Box<dyn ReduceTask<SnKey, (u32, Arc<Entity>), SnKey, SnVal> + Send> {
        Box::new(BoundaryReduce {
            w: self.w,
            mode: self.mode.clone(),
        })
    }
}

/// Run JobSN: SRP + boundary job.  The second job runs with `r − 1`
/// reduce tasks (one per boundary); the paper runs it with a single
/// reducer (`r = 1` in §5.2) — set `second_job_reducers` to override.
pub fn run(entities: &[Entity], cfg: &SnConfig) -> anyhow::Result<SnResult> {
    run_with_options(entities, cfg, None, Exec::Serial)
}

/// As [`run`], on an explicit executor.  On a shared scheduler the two
/// jobs form a dependency chain — phase 2's input is phase 1's boundary
/// output — so they run back-to-back *within* this workflow while their
/// tasks still interleave with any other concurrently submitted job.
pub fn run_on(entities: &[Entity], cfg: &SnConfig, exec: Exec<'_>) -> anyhow::Result<SnResult> {
    run_with_options(entities, cfg, None, exec)
}

/// As [`run`], with an explicit reduce-task count for the second job
/// (§5.2: "The additional MapReduce job of JobSN was executed with one
/// reducer (r=1)" — i.e. all boundary groups on one reduce *slot*; we map
/// this to `workers = 1` equivalently, but expose the knob for ablation).
pub fn run_with_options(
    entities: &[Entity],
    cfg: &SnConfig,
    second_job_reducers: Option<usize>,
    exec: Exec<'_>,
) -> anyhow::Result<SnResult> {
    // A balance strategy replaces JobSN's two-job structure with the
    // loadbalance two-job pipeline: the BDM analysis job takes the place
    // of the boundary job (still SRP-shaped map + extra job, still the
    // same pair set), and the repartition job handles boundaries via
    // rank-contiguous routing, so `second_job_reducers` does not apply.
    if cfg.balance != crate::sn::loadbalance::BalanceStrategy::None {
        return crate::sn::loadbalance::run_balanced(entities, cfg, exec);
    }
    let r = cfg.partitioner.num_partitions();

    // ---- phase 1: SRP + boundary emission --------------------------------
    let res1 = run_srp_job(entities, cfg, r > 1, "jobsn-phase1", exec);
    let (mut pairs, mut matches, boundaries) = split_output(&res1);
    let profile1 = JobProfile::from_stats(
        &res1.stats,
        res1.counters
            .get(crate::mapreduce::counters::names::MAP_OUTPUT_BYTES),
    );

    let counters = Arc::new(Counters::new());
    counters.merge(&res1.counters);

    let mut stats = vec![res1.stats.clone()];
    let mut profiles = vec![profile1];

    // ---- phase 2: boundary job -------------------------------------------
    if r > 1 && !boundaries.is_empty() {
        // map is identity on the lineage-keyed boundary entities
        let input: Vec<(SnKey, (u32, Arc<Entity>))> = boundaries
            .into_iter()
            .map(|(k, e)| {
                let part = k.part;
                (k, (part, e))
            })
            .collect();
        let mapper = Arc::new(FnMapTask::new(
            |k: SnKey,
             v: (u32, Arc<Entity>),
             out: &mut Emitter<SnKey, (u32, Arc<Entity>)>,
             _c: &Counters| {
                out.emit(k, v);
            },
        ));
        let r2 = second_job_reducers.unwrap_or(r - 1);
        let job_cfg = JobConfig::named("jobsn-phase2")
            .with_tasks(cfg.num_map_tasks.min(input.len().max(1)), r2)
            .with_workers(cfg.workers)
            .with_sort_buffer(cfg.sort_buffer_records)
            .with_spill(cfg.spill.as_ref().map(crate::sn::codec::boundary_job_spec))
            .with_push(cfg.push)
            .with_faults(cfg.faults.clone())
            .with_retries(cfg.max_task_retries)
            .with_trace(cfg.trace.clone())
            .with_memory(cfg.memory.clone());
        // boundary index spreads over the phase-2 reduce tasks
        struct BoundaryPartitioner;
        impl crate::mapreduce::types::Partitioner<SnKey> for BoundaryPartitioner {
            fn partition(&self, key: &SnKey, num_reducers: usize) -> usize {
                key.bound as usize % num_reducers
            }
        }
        let res2 = exec.run_job(
            &job_cfg,
            input,
            mapper,
            Arc::new(BoundaryPartitioner),
            group_by_bound(),
            Arc::new(BoundaryReduceFactory {
                w: cfg.window,
                mode: cfg.mode.clone(),
            }),
        );
        let (p2, m2, b2) = split_output(&res2);
        debug_assert!(b2.is_empty());
        pairs.extend(p2);
        matches.extend(m2);
        counters.merge(&res2.counters);
        profiles.push(JobProfile::from_stats(
            &res2.stats,
            res2.counters
                .get(crate::mapreduce::counters::names::MAP_OUTPUT_BYTES),
        ));
        stats.push(res2.stats);
    } else {
        let _ = BoundPartitioner; // silence unused import in r == 1 builds
    }

    Ok(SnResult {
        pairs,
        matches,
        counters,
        stats,
        profiles,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::{BlockingKey, TitlePrefixKey};
    use crate::sn::partition::RangePartition;
    use crate::sn::types::counter_names;
    use crate::sn::window::expected_pair_count;

    fn fig5_entities() -> Vec<Entity> {
        [
            (1, "1a"), (2, "2b"), (3, "3c"), (4, "1d"), (5, "2e"),
            (6, "2f"), (7, "3g"), (8, "2h"), (9, "3i"),
        ]
        .iter()
        .map(|&(id, t)| Entity::new(id, t, ""))
        .collect()
    }

    fn fig5_cfg() -> SnConfig {
        SnConfig {
            window: 3,
            num_map_tasks: 3,
            workers: 2,
            partitioner: Arc::new(RangePartition::new(vec!["3".into()], "fig5")),
            blocking_key: Arc::new(TitlePrefixKey::new(1)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        }
    }

    /// Figure 6: JobSN completes the SRP result to the full 15 pairs,
    /// recovering (f,c), (h,c), (h,g).
    #[test]
    fn figure_6_jobsn_completes_boundary_pairs() {
        let res = run(&fig5_entities(), &fig5_cfg()).unwrap();
        let set = res.pair_set();
        assert_eq!(set.len(), expected_pair_count(9, 3));
        use crate::er::entity::Pair;
        for (a, b) in [(6, 3), (8, 3), (8, 7)] {
            assert!(set.contains(&Pair::new(a, b)), "missing boundary pair ({a},{b})");
        }
    }

    #[test]
    fn jobsn_equals_sequential() {
        let entities: Vec<Entity> = (0..200)
            .map(|i| Entity::new(i, &format!("{}{} title {i}", (b'a' + (i % 20) as u8) as char, (b'a' + (i % 7) as u8) as char), "abs"))
            .collect();
        let cfg = SnConfig {
            window: 4,
            num_map_tasks: 5,
            workers: 3,
            partitioner: Arc::new(RangePartition::balanced(
                &entities,
                |e| TitlePrefixKey::new(2).key(e),
                4,
            )),
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        };
        let res = run(&entities, &cfg).unwrap();
        let mut seq = crate::sn::seq::run_blocking(&entities, &TitlePrefixKey::new(2), 4);
        seq.sort_unstable();
        seq.dedup();
        assert_eq!(res.pair_set(), seq);
        // two jobs ran
        assert_eq!(res.stats.len(), 2);
        assert!(res.counters.get(counter_names::BOUNDARY_ENTITIES) > 0);
    }

    #[test]
    fn jobsn_single_partition_runs_one_job() {
        let entities = fig5_entities();
        let cfg = SnConfig {
            partitioner: Arc::new(crate::sn::partition::EvenPartition::ascii(1)),
            ..fig5_cfg()
        };
        let res = run(&entities, &cfg).unwrap();
        assert_eq!(res.stats.len(), 1);
        assert_eq!(res.pair_set().len(), expected_pair_count(9, 3));
    }

    #[test]
    fn jobsn_one_reducer_second_job_like_paper() {
        let res =
            run_with_options(&fig5_entities(), &fig5_cfg(), Some(1), Exec::Serial).unwrap();
        assert_eq!(res.pair_set().len(), expected_pair_count(9, 3));
    }

    #[test]
    fn jobsn_on_scheduler_matches_serial() {
        let entities = fig5_entities();
        let cfg = fig5_cfg();
        let serial = run(&entities, &cfg).unwrap();
        let sched = crate::mapreduce::scheduler::JobScheduler::with_slots(3);
        let scheduled = run_on(&entities, &cfg, Exec::Scheduler(&sched)).unwrap();
        assert_eq!(serial.pair_set(), scheduled.pair_set());
        assert_eq!(scheduled.stats.len(), 2, "both jobs must run through the scheduler");
    }
}
