//! RepSN — Sorted Neighborhood with entity replication
//! (§4.3, Figure 7, Algorithm 2).
//!
//! A single MapReduce job: every map task keeps, per partition `i < r`,
//! the `w−1` entities with the highest blocking key it has seen for that
//! partition (`map_configure` initializes the lists, `map` maintains them,
//! `map_close` flushes).  Originals are emitted under `p(k).p(k).k`;
//! the boundary candidates are *additionally* emitted under
//! `(p(k)+1).p(k).k`, which routes the copy to the succeeding reducer and
//! — because the composite key sorts by (bound, part, key) — places all
//! replicas at the *head* of that reducer's input.  The reduce step drops
//! all but the last `w−1` replicas (the globally highest of the
//! predecessor partition), seeds the sliding window with them, and then
//! windows the originals, so every emitted pair involves at least one
//! entity of the actual partition.

use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::er::blockkey::BlockingKey;
use crate::er::entity::Entity;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::JobResult;
use crate::mapreduce::scheduler::{Exec, JobHandle, JobScheduler};
use crate::mapreduce::sim::JobProfile;
use crate::mapreduce::types::{
    Emitter, MapTask, MapTaskFactory, ReduceTask, ReduceTaskFactory, ValuesIter,
};
use crate::mapreduce::JobConfig;
use crate::sn::loadbalance::{self, BalanceStrategy};
use crate::sn::pairs::WindowProc;
use crate::sn::partition::PartitionFn;
use crate::sn::srp::{group_by_bound, BoundPartitioner};
use crate::sn::types::{counter_names, SnConfig, SnKey, SnMode, SnResult, SnVal};

/// Min-heap entry for the per-partition replication buffers: keeps the
/// `w−1` largest `(key, id)` entities with O(log w) maintenance
/// (Algorithm 2 lines 11–17 describe the same replace-min policy).
#[derive(PartialEq, Eq)]
struct RepEntry {
    key: String,
    id: u64,
    entity: Arc<Entity>,
}

impl Ord for RepEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed → BinaryHeap pops the smallest (key, id) first
        (&other.key, other.id).cmp(&(&self.key, self.id))
    }
}

impl PartialOrd for RepEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The RepSN map task (Algorithm 2).
struct RepSnMap {
    w: usize,
    r: usize,
    blocking_key: Arc<dyn BlockingKey>,
    partitioner: Arc<dyn PartitionFn>,
    /// `rep[i]`: candidates for replication to reducer `i+1`.
    rep: Vec<BinaryHeap<RepEntry>>,
}

impl MapTask<(), Arc<Entity>, SnKey, Arc<Entity>> for RepSnMap {
    fn configure(&mut self, _out: &mut Emitter<SnKey, Arc<Entity>>, _c: &Counters) {
        // map_configure: one buffer per partition i < r
        self.rep = (0..self.r.saturating_sub(1)).map(|_| BinaryHeap::new()).collect();
    }

    fn map(&mut self, _k: (), e: Arc<Entity>, out: &mut Emitter<SnKey, Arc<Entity>>, _c: &Counters) {
        let k = self.blocking_key.key(&e);
        let part = self.partitioner.partition(&k);
        let id = e.id;
        // maintain the replication buffer for this partition (if not last)
        if part + 1 < self.r && self.w >= 2 {
            let heap = &mut self.rep[part];
            if heap.len() < self.w - 1 {
                heap.push(RepEntry { key: k.clone(), id, entity: Arc::clone(&e) });
            } else if let Some(min) = heap.peek() {
                if (&k, id) > (&min.key, min.id) {
                    heap.pop();
                    heap.push(RepEntry { key: k.clone(), id, entity: Arc::clone(&e) });
                }
            }
        }
        out.emit(SnKey::srp(part as u32, k, id), e);
    }

    fn close(&mut self, out: &mut Emitter<SnKey, Arc<Entity>>, c: &Counters) {
        // map_close: flush replicas with bound = part + 1
        let mut replicated = 0u64;
        for (i, heap) in self.rep.drain(..).enumerate() {
            for entry in heap.into_vec() {
                out.emit(
                    SnKey {
                        bound: (i + 1) as u32,
                        part: i as u32,
                        key: entry.key,
                        id: entry.id,
                    },
                    entry.entity,
                );
                replicated += 1;
            }
        }
        c.add(counter_names::REPLICATED_ENTITIES, replicated);
    }
}

struct RepSnMapFactory {
    w: usize,
    r: usize,
    blocking_key: Arc<dyn BlockingKey>,
    partitioner: Arc<dyn PartitionFn>,
}

impl MapTaskFactory<(), Arc<Entity>, SnKey, Arc<Entity>> for RepSnMapFactory {
    fn create_task(&self) -> Box<dyn MapTask<(), Arc<Entity>, SnKey, Arc<Entity>> + Send> {
        Box::new(RepSnMap {
            w: self.w,
            r: self.r,
            blocking_key: Arc::clone(&self.blocking_key),
            partitioner: Arc::clone(&self.partitioner),
            rep: Vec::new(),
        })
    }
}

struct RepSnReduceFactory {
    w: usize,
    mode: SnMode,
    blocking_key: Arc<dyn BlockingKey>,
    partitioner: Arc<dyn PartitionFn>,
}

impl ReduceTaskFactory<SnKey, Arc<Entity>, SnKey, SnVal> for RepSnReduceFactory {
    fn create_task(&self) -> Box<dyn ReduceTask<SnKey, Arc<Entity>, SnKey, SnVal> + Send> {
        Box::new(RepSnReduceImpl {
            w: self.w,
            mode: self.mode.clone(),
            blocking_key: Arc::clone(&self.blocking_key),
            partitioner: Arc::clone(&self.partitioner),
        })
    }
}

/// Working implementation: recomputes each value's home partition from its
/// blocking key (deterministic) to classify replica vs original.
struct RepSnReduceImpl {
    w: usize,
    mode: SnMode,
    blocking_key: Arc<dyn BlockingKey>,
    partitioner: Arc<dyn PartitionFn>,
}

impl ReduceTask<SnKey, Arc<Entity>, SnKey, SnVal> for RepSnReduceImpl {
    fn reduce(
        &mut self,
        key: &SnKey,
        values: ValuesIter<'_, Arc<Entity>>,
        out: &mut Emitter<SnKey, SnVal>,
        counters: &Counters,
    ) {
        let r_i = key.bound;
        let keep = self.w.saturating_sub(1);
        let mut proc = WindowProc::new(self.w, &self.mode);
        let mut head: std::collections::VecDeque<Arc<Entity>> =
            std::collections::VecDeque::with_capacity(keep + 1);
        let mut discarded = 0u64;
        let mut seeded = false;
        for e in values {
            let part = self.partitioner.partition(&self.blocking_key.key(e)) as u32;
            if part != r_i {
                // replica from the preceding partition (head of the input)
                debug_assert!(part + 1 == r_i, "replica from non-adjacent partition");
                debug_assert!(!seeded, "replica after originals violates sort order");
                head.push_back(Arc::clone(e));
                if head.len() > keep {
                    head.pop_front();
                    discarded += 1;
                }
            } else {
                if !seeded {
                    for rep in head.drain(..) {
                        proc.seed(&rep, r_i.wrapping_sub(1));
                    }
                    seeded = true;
                }
                proc.push(e, r_i, |_, _| true);
            }
        }
        counters.add(counter_names::REPLICAS_DISCARDED, discarded);
        proc.finish(key, out, counters);
    }
}

/// The assembled parts of a RepSN job, shared by every execution path.
#[allow(clippy::type_complexity)]
fn job_parts(
    entities: &[Entity],
    cfg: &SnConfig,
) -> (
    JobConfig,
    Vec<((), Arc<Entity>)>,
    Arc<dyn MapTaskFactory<(), Arc<Entity>, SnKey, Arc<Entity>>>,
    Arc<dyn ReduceTaskFactory<SnKey, Arc<Entity>, SnKey, SnVal>>,
) {
    let r = cfg.partitioner.num_partitions();
    let input: Vec<((), Arc<Entity>)> = entities
        .iter()
        .map(|e| ((), Arc::new(e.clone())))
        .collect();
    let job_cfg = JobConfig::named("repsn")
        .with_tasks(cfg.num_map_tasks, r)
        .with_workers(cfg.workers)
        .with_sort_buffer(cfg.sort_buffer_records)
        .with_spill(cfg.spill.as_ref().map(crate::sn::codec::entity_job_spec))
        .with_push(cfg.push)
        .with_faults(cfg.faults.clone())
        .with_retries(cfg.max_task_retries)
        .with_trace(cfg.trace.clone())
        .with_memory(cfg.memory.clone());
    let mapper: Arc<dyn MapTaskFactory<(), Arc<Entity>, SnKey, Arc<Entity>>> =
        Arc::new(RepSnMapFactory {
            w: cfg.window,
            r,
            blocking_key: Arc::clone(&cfg.blocking_key),
            partitioner: Arc::clone(&cfg.partitioner),
        });
    let reducer: Arc<dyn ReduceTaskFactory<SnKey, Arc<Entity>, SnKey, SnVal>> =
        Arc::new(RepSnReduceFactory {
            w: cfg.window,
            mode: cfg.mode.clone(),
            blocking_key: Arc::clone(&cfg.blocking_key),
            partitioner: Arc::clone(&cfg.partitioner),
        });
    (job_cfg, input, mapper, reducer)
}

/// Post-process a finished RepSN engine job into an [`SnResult`].
fn finish(res: JobResult<SnKey, SnVal>) -> anyhow::Result<SnResult> {
    let (pairs, matches, boundaries) = crate::sn::srp::split_output(&res);
    debug_assert!(boundaries.is_empty());
    let profile = JobProfile::from_stats(
        &res.stats,
        res.counters
            .get(crate::mapreduce::counters::names::MAP_OUTPUT_BYTES),
    );
    Ok(SnResult {
        pairs,
        matches,
        counters: Arc::clone(&res.counters),
        stats: vec![res.stats.clone()],
        profiles: vec![profile],
    })
}

/// Run RepSN (§4.3): the complete SN result in a single MapReduce job.
pub fn run(entities: &[Entity], cfg: &SnConfig) -> anyhow::Result<SnResult> {
    run_on(entities, cfg, Exec::Serial)
}

/// As [`run`], on an explicit executor (serial or shared scheduler).
///
/// With a [`BalanceStrategy`] other than `None` on the config, execution
/// routes through [`loadbalance::run_balanced`]: the BDM analysis job
/// plus the balanced repartition job, same pair set, flattened
/// reduce-task skew.
pub fn run_on(entities: &[Entity], cfg: &SnConfig, exec: Exec<'_>) -> anyhow::Result<SnResult> {
    if cfg.balance != BalanceStrategy::None {
        return loadbalance::run_balanced(entities, cfg, exec);
    }
    let (job_cfg, input, mapper, reducer) = job_parts(entities, cfg);
    finish(exec.run_job(
        &job_cfg,
        input,
        mapper,
        Arc::new(BoundPartitioner),
        group_by_bound(),
        reducer,
    ))
}

/// A RepSN job submitted to a shared scheduler; [`PendingRepSn::join`]
/// blocks for the result.  With a balance strategy the pending work is
/// the whole two-job pipeline (analysis → repartition).
pub struct PendingRepSn {
    inner: PendingInner,
}

enum PendingInner {
    Classic(JobHandle<SnKey, SnVal>),
    Balanced(loadbalance::PendingBalanced),
}

impl PendingRepSn {
    pub fn join(self) -> anyhow::Result<SnResult> {
        match self.inner {
            PendingInner::Classic(handle) => finish(handle.join()),
            PendingInner::Balanced(pending) => pending.join(),
        }
    }
}

/// Submit RepSN to a shared [`JobScheduler`] and return immediately; the
/// job's map/reduce tasks interleave with every other submitted job's on
/// the scheduler's slots (this is how [`multipass`](crate::sn::multipass)
/// runs its independent per-key passes concurrently).  A configured
/// [`BalanceStrategy`] submits the balanced two-job pipeline instead,
/// still on the shared slots — balancing composes with whatever
/// speculation policy the scheduler runs.
pub fn submit(entities: &[Entity], cfg: &SnConfig, sched: &JobScheduler) -> PendingRepSn {
    if cfg.balance != BalanceStrategy::None {
        return PendingRepSn {
            inner: PendingInner::Balanced(loadbalance::submit(entities, cfg, sched)),
        };
    }
    let (job_cfg, input, mapper, reducer) = job_parts(entities, cfg);
    PendingRepSn {
        inner: PendingInner::Classic(sched.submit(
            job_cfg,
            input,
            mapper,
            Arc::new(BoundPartitioner),
            group_by_bound(),
            reducer,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::{BlockingKey, TitlePrefixKey};
    use crate::er::entity::Pair;
    use crate::sn::partition::RangePartition;
    use crate::sn::window::expected_pair_count;

    fn fig7_entities() -> Vec<Entity> {
        [
            (1, "1a"), (2, "2b"), (3, "3c"), (4, "1d"), (5, "2e"),
            (6, "2f"), (7, "3g"), (8, "2h"), (9, "3i"),
        ]
        .iter()
        .map(|&(id, t)| Entity::new(id, t, ""))
        .collect()
    }

    fn fig7_cfg() -> SnConfig {
        SnConfig {
            window: 3,
            num_map_tasks: 3,
            workers: 2,
            partitioner: Arc::new(RangePartition::new(vec!["3".into()], "fig7")),
            blocking_key: Arc::new(TitlePrefixKey::new(1)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        }
    }

    /// Figure 7: RepSN produces the complete 15-pair SN result in one job.
    #[test]
    fn figure_7_repsn_complete_in_one_job() {
        let res = run(&fig7_entities(), &fig7_cfg()).unwrap();
        let set = res.pair_set();
        assert_eq!(set.len(), expected_pair_count(9, 3));
        for (a, b) in [(6, 3), (8, 3), (8, 7)] {
            assert!(set.contains(&Pair::new(a, b)), "missing boundary pair ({a},{b})");
        }
        assert_eq!(res.stats.len(), 1, "RepSN must be a single job");
    }

    #[test]
    fn replication_bounded_by_formula() {
        // m·(r−1)·(w−1) is the paper's max replication count
        let entities: Vec<Entity> = (0..300)
            .map(|i| Entity::new(i, &format!("{}x title", (b'a' + (i % 26) as u8) as char), ""))
            .collect();
        let m = 4;
        let w = 5;
        let cfg = SnConfig {
            window: w,
            num_map_tasks: m,
            workers: 2,
            partitioner: Arc::new(RangePartition::balanced(
                &entities,
                |e| TitlePrefixKey::new(2).key(e),
                6,
            )),
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        };
        let res = run(&entities, &cfg).unwrap();
        let replicated = res.counters.get(counter_names::REPLICATED_ENTITIES);
        assert!(replicated > 0);
        assert!(
            replicated <= (m * (6 - 1) * (w - 1)) as u64,
            "replicated={replicated} > m(r-1)(w-1)"
        );
    }

    #[test]
    fn repsn_equals_sequential() {
        let entities: Vec<Entity> = (0..250)
            .map(|i| {
                let c1 = (b'a' + (i % 23) as u8) as char;
                let c2 = (b'a' + (i % 5) as u8) as char;
                Entity::new(i, &format!("{c1}{c2} title {i}"), "abs")
            })
            .collect();
        let cfg = SnConfig {
            window: 6,
            num_map_tasks: 7,
            workers: 3,
            partitioner: Arc::new(RangePartition::balanced(
                &entities,
                |e| TitlePrefixKey::new(2).key(e),
                5,
            )),
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        };
        let res = run(&entities, &cfg).unwrap();
        let mut seq = crate::sn::seq::run_blocking(&entities, &TitlePrefixKey::new(2), 6);
        seq.sort_unstable();
        seq.dedup();
        assert_eq!(res.pair_set(), seq);
    }

    #[test]
    fn repsn_single_partition_no_replication() {
        let cfg = SnConfig {
            partitioner: Arc::new(crate::sn::partition::EvenPartition::ascii(1)),
            ..fig7_cfg()
        };
        let res = run(&fig7_entities(), &cfg).unwrap();
        assert_eq!(res.counters.get(counter_names::REPLICATED_ENTITIES), 0);
        assert_eq!(res.pair_set().len(), expected_pair_count(9, 3));
    }
}
