//! Standard Blocking on MapReduce — the §3 general workflow (Figure 3).
//!
//! Entities sharing a blocking key form one block; reduce compares all
//! pairs *within* a block (quadratic in block size — the memory/skew
//! discussion of §3 is about exactly this).  Included as the baseline SN
//! is contrasted with, and because §6 notes "Sorted Neighborhood can be
//! substituted with other blocking techniques, e.g., Standard Blocking".

use std::sync::Arc;

use crate::er::entity::{Entity, Pair};
use crate::mapreduce::counters::Counters;
use crate::mapreduce::scheduler::Exec;
use crate::mapreduce::sim::JobProfile;
use crate::mapreduce::types::{Emitter, FnMapTask, FnReduceTask, HashPartitioner, ValuesIter};
use crate::mapreduce::JobConfig;
use crate::runtime::encode::fnv1a64;
use crate::sn::types::{counter_names, SnConfig, SnKey, SnMode, SnResult, SnVal};

/// Run standard blocking.  Reuses [`SnConfig`] for the key function and
/// task counts; `window` is ignored; the partitioner is replaced by key
/// hashing (blocks are independent — no order needed).
pub fn run(entities: &[Entity], cfg: &SnConfig) -> anyhow::Result<SnResult> {
    run_on(entities, cfg, Exec::Serial)
}

/// As [`run`], on an explicit executor (serial or shared scheduler).
pub fn run_on(entities: &[Entity], cfg: &SnConfig, exec: Exec<'_>) -> anyhow::Result<SnResult> {
    let input: Vec<((), Arc<Entity>)> = entities
        .iter()
        .map(|e| ((), Arc::new(e.clone())))
        .collect();
    let bk = Arc::clone(&cfg.blocking_key);
    let mapper = Arc::new(FnMapTask::new(
        move |_k: (), e: Arc<Entity>, out: &mut Emitter<String, Arc<Entity>>, _c: &Counters| {
            out.emit(bk.key(&e), e);
        },
    ));
    let mode = cfg.mode.clone();
    let reducer = Arc::new(FnReduceTask::new(
        move |k: &String,
              values: ValuesIter<'_, Arc<Entity>>,
              out: &mut Emitter<SnKey, SnVal>,
              counters: &Counters| {
            // compare all pairs within the block, streaming with an
            // unbounded "window" (block-local Cartesian product)
            let block: Vec<Arc<Entity>> = values.cloned().collect();
            let key = SnKey::srp(0, k.clone(), 0);
            match &mode {
                SnMode::Blocking => {
                    let mut cmp = 0u64;
                    for i in 0..block.len() {
                        for j in (i + 1)..block.len() {
                            out.emit(key.clone(), SnVal::Pair(Pair::new(block[i].id, block[j].id)));
                            cmp += 1;
                        }
                    }
                    counters.add(counter_names::COMPARISONS, cmp);
                }
                SnMode::Matching(mcfg) => {
                    let mut batcher = crate::er::strategy::PairBatcher::new(mcfg.clone());
                    let enc: Vec<_> = block
                        .iter()
                        .map(|e| {
                            Arc::new(crate::er::strategy::EncodedEntity::new(Arc::clone(e)))
                        })
                        .collect();
                    let mut cmp = 0u64;
                    for i in 0..enc.len() {
                        for j in (i + 1)..enc.len() {
                            batcher.push(Arc::clone(&enc[i]), Arc::clone(&enc[j]));
                            cmp += 1;
                        }
                    }
                    counters.add(counter_names::COMPARISONS, cmp);
                    counters.add(counter_names::PAIRS_SKIPPED_SHORTCIRCUIT, batcher.pairs_skipped);
                    let matches = batcher.finish();
                    counters.add(counter_names::MATCHES, matches.len() as u64);
                    for m in matches {
                        out.emit(key.clone(), SnVal::Match(m));
                    }
                }
            }
        },
    ));
    let r = cfg.partitioner.num_partitions();
    let job_cfg = JobConfig::named("standard-blocking")
        .with_tasks(cfg.num_map_tasks, r)
        .with_workers(cfg.workers)
        .with_sort_buffer(cfg.sort_buffer_records)
        .with_spill(cfg.spill.as_ref().map(crate::sn::codec::block_job_spec))
        .with_push(cfg.push)
        .with_faults(cfg.faults.clone())
        .with_retries(cfg.max_task_retries)
        .with_trace(cfg.trace.clone())
        .with_memory(cfg.memory.clone());
    let res = exec.run_job(
        &job_cfg,
        input,
        mapper,
        Arc::new(HashPartitioner::new(|k: &String| fnv1a64(k.as_bytes()))),
        Arc::new(|a: &String, b: &String| a == b),
        reducer,
    );
    let (pairs, matches, _) = {
        let mut pairs = Vec::new();
        let mut matches = Vec::new();
        for part in &res.outputs {
            for (_, v) in part {
                match v {
                    SnVal::Pair(p) => pairs.push(*p),
                    SnVal::Match(m) => matches.push(*m),
                    SnVal::Entity(_) => unreachable!(),
                }
            }
        }
        (pairs, matches, ())
    };
    let profile = JobProfile::from_stats(
        &res.stats,
        res.counters
            .get(crate::mapreduce::counters::names::MAP_OUTPUT_BYTES),
    );
    Ok(SnResult {
        pairs,
        matches,
        counters: Arc::clone(&res.counters),
        stats: vec![res.stats.clone()],
        profiles: vec![profile],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::TitlePrefixKey;

    #[test]
    fn blocks_compare_within_key_only() {
        let entities: Vec<Entity> = [
            (1, "aa x"), (2, "aa y"), (3, "aa z"), (4, "bb x"), (5, "bb y"),
        ]
        .iter()
        .map(|&(id, t)| Entity::new(id, t, ""))
        .collect();
        let cfg = SnConfig {
            num_map_tasks: 2,
            workers: 2,
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            ..Default::default()
        };
        let res = run(&entities, &cfg).unwrap();
        let set = res.pair_set();
        // C(3,2) + C(2,2)... C(3,2)=3 within "aa", C(2,2)=1 within "bb"
        assert_eq!(set.len(), 4);
        assert!(set.contains(&Pair::new(1, 2)));
        assert!(set.contains(&Pair::new(4, 5)));
        assert!(!set.contains(&Pair::new(3, 4)), "cross-block pair generated");
    }

    #[test]
    fn quadratic_in_block_size() {
        // one hot key with 40 entities → C(40,2) comparisons: the skew
        // problem §3/§5.3 describes
        let entities: Vec<Entity> = (0..40).map(|i| Entity::new(i, "aa hot", "")).collect();
        let cfg = SnConfig {
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            ..Default::default()
        };
        let res = run(&entities, &cfg).unwrap();
        assert_eq!(res.pairs.len(), 40 * 39 / 2);
    }
}
