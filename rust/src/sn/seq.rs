//! Sequential Sorted Neighborhood — the baseline every parallel variant
//! is validated against and that the speedup figures normalize to.

use std::sync::Arc;

use crate::er::blockkey::BlockingKey;
use crate::er::entity::{Entity, Pair, ScoredPair};
use crate::er::strategy::{EncodedEntity, MatchStrategyConfig, PairBatcher};
use crate::sn::window::SlidingWindow;

/// Sort entities by `(blocking key, id)` and return the sorted ids.
pub fn sorted_ids(entities: &[Entity], key_fn: &dyn BlockingKey) -> Vec<u64> {
    let mut keyed: Vec<(String, u64)> = entities
        .iter()
        .map(|e| (key_fn.key(e), e.id))
        .collect();
    keyed.sort_unstable();
    keyed.into_iter().map(|(_, id)| id).collect()
}

/// Sequential SN in blocking mode: all sliding-window correspondences.
pub fn run_blocking(entities: &[Entity], key_fn: &dyn BlockingKey, w: usize) -> Vec<Pair> {
    crate::sn::window::standard_sn(&sorted_ids(entities, key_fn), w)
}

/// Sequential SN with full matching: sort, slide, score, threshold.
/// Returns `(matches, comparisons)`.
pub fn run_matching(
    entities: &[Entity],
    key_fn: &dyn BlockingKey,
    w: usize,
    strategy: &MatchStrategyConfig,
) -> (Vec<ScoredPair>, u64) {
    let mut keyed: Vec<(String, u64, &Entity)> = entities
        .iter()
        .map(|e| (key_fn.key(e), e.id, e))
        .collect();
    keyed.sort_unstable_by(|a, b| (&a.0, a.1).cmp(&(&b.0, b.1)));

    let mut batcher = PairBatcher::new(strategy.clone());
    let mut win: SlidingWindow<Arc<EncodedEntity>> = SlidingWindow::new(w.max(2));
    let mut queue: Vec<(Arc<EncodedEntity>, Arc<EncodedEntity>)> = Vec::new();
    for (_, _, e) in &keyed {
        let enc = Arc::new(EncodedEntity::new(Arc::new((*e).clone())));
        win.push(enc, |a, b| queue.push((Arc::clone(a), Arc::clone(b))));
        for (a, b) in queue.drain(..) {
            batcher.push(a, b);
        }
    }
    let comparisons = win.comparisons();
    (batcher.finish(), comparisons)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::TitlePrefixKey;
    use crate::sn::window::expected_pair_count;

    fn entities() -> Vec<Entity> {
        // 9 entities with keys shaped like Figure 4 (keys 1/2/3 → aa/bb/cc)
        let keys = [
            (1, "aa"), (2, "bb"), (3, "cc"), (4, "aa"), (5, "bb"),
            (6, "bb"), (7, "cc"), (8, "bb"), (9, "cc"),
        ];
        keys.iter()
            .map(|&(id, k)| Entity::new(id, &format!("{k} title {id}"), "abstract"))
            .collect()
    }

    #[test]
    fn blocking_pair_count_matches_formula() {
        let es = entities();
        let pairs = run_blocking(&es, &TitlePrefixKey::new(2), 3);
        assert_eq!(pairs.len(), expected_pair_count(9, 3));
    }

    #[test]
    fn sorted_by_key_then_id() {
        let es = entities();
        let ids = sorted_ids(&es, &TitlePrefixKey::new(2));
        assert_eq!(ids, vec![1, 4, 2, 5, 6, 8, 3, 7, 9]);
    }

    #[test]
    fn matching_finds_injected_duplicate() {
        let mut es = entities();
        es.push(Entity::new(100, "aa title 1", "abstract")); // dup of id 1
        let (matches, comparisons) =
            run_matching(&es, &TitlePrefixKey::new(2), 4, &MatchStrategyConfig::default());
        assert!(comparisons > 0);
        assert!(
            matches.iter().any(|m| m.pair == Pair::new(1, 100)),
            "matches: {matches:?}"
        );
    }

    #[test]
    fn window_of_two_compares_adjacent_only() {
        let es = entities();
        let pairs = run_blocking(&es, &TitlePrefixKey::new(2), 2);
        assert_eq!(pairs.len(), 8);
    }
}
