//! Sorted Neighborhood blocking — sequential and the paper's three
//! MapReduce parallelizations.
//!
//! * [`window`] — `StandardSN`: the sliding-window pair generator.
//! * [`seq`] — the sequential baseline (sort everything, slide once).
//! * [`partition`] — monotonic range-partition functions `p : k → i`
//!   (Manual/balanced, Even-k) and the Gini coefficient of §5.3.
//! * [`srp`] — §4.1 Sorted Reduce Partitions: composite key `p(k).k`,
//!   partition by prefix, sort by blocking key; misses the
//!   `(r−1)·w·(w−1)/2` boundary pairs.
//! * [`jobsn`] — §4.2: SRP + a second MapReduce job over the emitted
//!   boundary entities.
//! * [`repsn`] — §4.3: single job; each map task replicates, per
//!   partition `i < r`, its `w−1` highest-keyed entities to reducer
//!   `i + 1` (composite key `bound.p(k).k`).
//! * [`standard_blocking`] — the §3 baseline (group by exact key).
//! * [`multipass`] — multi-pass SN (§4's robustness extension).
//! * [`balance`] — skew-aware key-range boundary selection (partition
//!   granularity) and the combiner-powered key-histogram job.
//! * [`loadbalance`] — the Kolb et al. 2012 two-job load balancers: a
//!   Block Distribution Matrix analysis job plus BlockSplit / PairRange
//!   repartitioning, selected by [`BalanceStrategy`] on [`SnConfig`].
//! * [`codec`] — binary codecs for every SN intermediate record shape,
//!   letting [`SnSpill`] on [`SnConfig`] route all of the above through
//!   the engine's disk-backed, DEFLATE-compressed run files.
//!
//! ## Phase structure: barrier vs push
//!
//! Every variant above runs each of its MapReduce jobs in one of two
//! phase structures, with byte-identical output either way
//! (`tests/prop_push.rs`):
//!
//! * **Barrier** (default): the paper's Hadoop 0.20 model — a hard
//!   map→reduce barrier inside each job, reduce slots idle during the
//!   whole map wave.  This is the reference path and what the paper's
//!   figures measure.
//! * **Push** ([`SnConfig::push`], or a scheduler-wide
//!   [`PushMode::Push`](crate::mapreduce::scheduler::PushMode)): on a
//!   shared [`JobScheduler`](crate::mapreduce::scheduler::JobScheduler),
//!   each job's sealed runs flow through the engine's push-based
//!   [`ShuffleService`](crate::mapreduce::push::ShuffleService) and its
//!   reduce tasks start on their first runs, overlapping the job's own
//!   map wave (see
//!   [`JobStats::overlap_secs`](crate::mapreduce::JobStats)).  JobSN's
//!   two jobs each push internally; pushing *across* the phase-1 →
//!   phase-2 boundary (phase 2 consuming boundary entities before
//!   phase 1 completes) is a possible follow-up.
//!
//! ## Determinism note
//!
//! The paper sorts by blocking key alone; ties are ordered arbitrarily
//! (Hadoop: by map-task arrival).  To make `pairs(SeqSN) == pairs(JobSN)
//! == pairs(RepSN)` an exact *set* equality — which is what our property
//! tests assert — every implementation here breaks key ties by entity id
//! (the classic Hadoop "secondary sort" idiom).  This changes nothing
//! about which *distances* are compared, only makes tie order stable.

pub mod balance;
pub mod codec;
pub mod jobsn;
pub mod loadbalance;
pub mod multipass;
pub mod pairs;
pub mod partition;
pub mod repsn;
pub mod seq;
pub mod srp;
pub mod standard_blocking;
pub mod types;
pub mod window;

pub use loadbalance::BalanceStrategy;
pub use types::{SnConfig, SnKey, SnMode, SnResult, SnSpill};
