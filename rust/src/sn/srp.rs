//! SRP — Sorted Reduce Partitions (§4.1, Figure 5).
//!
//! The map function generates the blocking key `k` for each entity and
//! prefixes it with the partition `p(k)`, producing the composite key
//! `p(k).k`.  Repartitioning uses the prefix; sorting uses the whole key;
//! since all keys of reducer `i` share prefix `i`, each reducer's input is
//! sorted by the *blocking* key and the sliding window runs per reduce
//! partition.  SRP alone misses the `(r−1)·w·(w−1)/2` boundary pairs —
//! JobSN and RepSN build on the pieces here.

use std::sync::Arc;

use crate::er::blockkey::BlockingKey;
use crate::er::entity::{Entity, Pair, ScoredPair};
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::{GroupFn, JobResult};
use crate::mapreduce::scheduler::Exec;
use crate::mapreduce::sim::JobProfile;
use crate::mapreduce::types::{
    Emitter, FnMapTask, Partitioner, ReduceTask, ReduceTaskFactory, ValuesIter,
};
use crate::mapreduce::JobConfig;
use crate::sn::pairs::WindowProc;
use crate::sn::types::{counter_names, SnConfig, SnKey, SnMode, SnResult, SnVal};

/// Partitioner: route by the composite key's `bound` prefix.
pub(crate) struct BoundPartitioner;

impl Partitioner<SnKey> for BoundPartitioner {
    fn partition(&self, key: &SnKey, num_reducers: usize) -> usize {
        let b = key.bound as usize;
        assert!(b < num_reducers, "bound {b} out of range (r={num_reducers})");
        b
    }
}

/// Grouping comparator: one group per `bound` (Algorithm 1: "group by
/// r_i, order by composed key").
pub(crate) fn group_by_bound() -> GroupFn<SnKey> {
    Arc::new(|a: &SnKey, b: &SnKey| a.bound == b.bound)
}

/// The SRP map function (shared verbatim by JobSN phase 1).
pub(crate) fn srp_mapper(
    cfg: &SnConfig,
) -> Arc<FnMapTask<impl Fn((), Arc<Entity>, &mut Emitter<SnKey, Arc<Entity>>, &Counters)>> {
    let bk = Arc::clone(&cfg.blocking_key);
    let pf = Arc::clone(&cfg.partitioner);
    Arc::new(FnMapTask::new(
        move |_k: (), e: Arc<Entity>, out: &mut Emitter<SnKey, Arc<Entity>>, _c: &Counters| {
            let k = bk.key(&e);
            let part = pf.partition(&k) as u32;
            let id = e.id;
            out.emit(SnKey::srp(part, k, id), e);
        },
    ))
}

/// The SRP reduce task, with optional JobSN boundary emission.
pub(crate) struct SnWindowReduce {
    pub w: usize,
    pub mode: SnMode,
    pub r: usize,
    /// JobSN phase 1: additionally emit the first/last `w−1` entities with
    /// boundary-prefixed keys.
    pub emit_boundaries: bool,
    pub blocking_key: Arc<dyn BlockingKey>,
}

impl ReduceTask<SnKey, Arc<Entity>, SnKey, SnVal> for SnWindowReduce {
    fn reduce(
        &mut self,
        key: &SnKey,
        values: ValuesIter<'_, Arc<Entity>>,
        out: &mut Emitter<SnKey, SnVal>,
        counters: &Counters,
    ) {
        let r_i = key.bound;
        let mut proc = WindowProc::new(self.w, &self.mode);
        // boundary bookkeeping (JobSN phase 1)
        let keep = self.w.saturating_sub(1);
        let mut first: Vec<Arc<Entity>> = Vec::new();
        let mut last: std::collections::VecDeque<Arc<Entity>> = std::collections::VecDeque::new();
        for e in values {
            proc.push(e, r_i, |_, _| true);
            if self.emit_boundaries && keep > 0 {
                if first.len() < keep {
                    first.push(Arc::clone(e));
                }
                last.push_back(Arc::clone(e));
                if last.len() > keep {
                    last.pop_front();
                }
            }
        }
        proc.finish(key, out, counters);
        if self.emit_boundaries {
            // Algorithm 1 lines 12–19: reducer r_i > 1 emits its first w−1
            // entities to boundary r_i − 1; reducer r_i < r emits its last
            // w−1 entities to boundary r_i.  (0-based here.)
            let mut emitted = 0u64;
            if r_i > 0 {
                for e in &first {
                    let k = self.blocking_key.key(e);
                    out.emit(
                        SnKey { bound: r_i - 1, part: r_i, key: k, id: e.id },
                        SnVal::Entity(Arc::clone(e)),
                    );
                    emitted += 1;
                }
            }
            if (r_i as usize) < self.r - 1 {
                for e in &last {
                    let k = self.blocking_key.key(e);
                    out.emit(
                        SnKey { bound: r_i, part: r_i, key: k, id: e.id },
                        SnVal::Entity(Arc::clone(e)),
                    );
                    emitted += 1;
                }
            }
            counters.add(counter_names::BOUNDARY_ENTITIES, emitted);
        }
    }
}

pub(crate) struct SnWindowReduceFactory {
    pub w: usize,
    pub mode: SnMode,
    pub r: usize,
    pub emit_boundaries: bool,
    pub blocking_key: Arc<dyn BlockingKey>,
}

impl ReduceTaskFactory<SnKey, Arc<Entity>, SnKey, SnVal> for SnWindowReduceFactory {
    fn create_task(&self) -> Box<dyn ReduceTask<SnKey, Arc<Entity>, SnKey, SnVal> + Send> {
        Box::new(SnWindowReduce {
            w: self.w,
            mode: self.mode.clone(),
            r: self.r,
            emit_boundaries: self.emit_boundaries,
            blocking_key: Arc::clone(&self.blocking_key),
        })
    }
}

/// Run the SRP job (optionally with JobSN phase-1 boundary emission) and
/// return the raw engine result.  `exec` selects a job-private pool or a
/// shared [`JobScheduler`](crate::mapreduce::scheduler::JobScheduler).
pub(crate) fn run_srp_job(
    entities: &[Entity],
    cfg: &SnConfig,
    emit_boundaries: bool,
    job_name: &str,
    exec: Exec<'_>,
) -> JobResult<SnKey, SnVal> {
    let r = cfg.partitioner.num_partitions();
    let input: Vec<((), Arc<Entity>)> = entities
        .iter()
        .map(|e| ((), Arc::new(e.clone())))
        .collect();
    let job_cfg = JobConfig::named(job_name)
        .with_tasks(cfg.num_map_tasks, r)
        .with_workers(cfg.workers)
        .with_sort_buffer(cfg.sort_buffer_records)
        .with_spill(cfg.spill.as_ref().map(crate::sn::codec::entity_job_spec))
        .with_push(cfg.push)
        .with_faults(cfg.faults.clone())
        .with_retries(cfg.max_task_retries)
        .with_trace(cfg.trace.clone())
        .with_memory(cfg.memory.clone());
    exec.run_job(
        &job_cfg,
        input,
        srp_mapper(cfg),
        Arc::new(BoundPartitioner),
        group_by_bound(),
        Arc::new(SnWindowReduceFactory {
            w: cfg.window,
            mode: cfg.mode.clone(),
            r,
            emit_boundaries,
            blocking_key: Arc::clone(&cfg.blocking_key),
        }),
    )
}

/// Split a raw job result into pairs/matches/boundaries.
pub(crate) fn split_output(
    res: &JobResult<SnKey, SnVal>,
) -> (Vec<Pair>, Vec<ScoredPair>, Vec<(SnKey, Arc<Entity>)>) {
    let mut pairs = Vec::new();
    let mut matches = Vec::new();
    let mut boundaries = Vec::new();
    for part in &res.outputs {
        for (k, v) in part {
            match v {
                SnVal::Pair(p) => pairs.push(*p),
                SnVal::Match(m) => matches.push(*m),
                SnVal::Entity(e) => boundaries.push((k.clone(), Arc::clone(e))),
            }
        }
    }
    (pairs, matches, boundaries)
}

/// Run plain SRP (§4.1): sorted reduce partitions *without* boundary
/// handling.  Misses `(r−1)·w·(w−1)/2` pairs by design.
pub fn run(entities: &[Entity], cfg: &SnConfig) -> anyhow::Result<SnResult> {
    run_on(entities, cfg, Exec::Serial)
}

/// As [`run`], on an explicit executor (serial or shared scheduler).
pub fn run_on(entities: &[Entity], cfg: &SnConfig, exec: Exec<'_>) -> anyhow::Result<SnResult> {
    let res = run_srp_job(entities, cfg, false, "srp", exec);
    let (pairs, matches, _) = split_output(&res);
    let profile = JobProfile::from_stats(
        &res.stats,
        res.counters.get(crate::mapreduce::counters::names::MAP_OUTPUT_BYTES),
    );
    Ok(SnResult {
        pairs,
        matches,
        counters: Arc::clone(&res.counters),
        stats: vec![res.stats.clone()],
        profiles: vec![profile],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::TitlePrefixKey;
    use crate::sn::partition::RangePartition;
    use crate::sn::window::{expected_pair_count, srp_missing_pairs};

    /// The Figure 5 example: 9 entities, 2 reducers, w=3 → 12 of 15 pairs.
    #[test]
    fn figure_5_srp_misses_three_pairs() {
        // entities a..i with blocking keys 1,2,3 encoded as titles
        // key "1"→partition 0, keys "2","3"→... paper: p(k)=1 if k<=2 else 2
        let data = [
            ("a", 1, "1a"), ("b", 2, "2b"), ("c", 3, "3c"), ("d", 4, "1d"),
            ("e", 5, "2e"), ("f", 6, "2f"), ("g", 7, "3g"), ("h", 8, "2h"),
            ("i", 9, "3i"),
        ];
        // titles start with the key digit; TitlePrefixKey(1) gives "1"/"2"/"3"
        let entities: Vec<Entity> = data
            .iter()
            .map(|&(_, id, t)| Entity::new(id, t, ""))
            .collect();
        let cfg = SnConfig {
            window: 3,
            num_map_tasks: 3,
            workers: 2,
            partitioner: Arc::new(RangePartition::new(vec!["3".into()], "fig5")),
            blocking_key: Arc::new(TitlePrefixKey::new(1)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        };
        let res = run(&entities, &cfg).unwrap();
        assert_eq!(res.pairs.len(), 12);
        assert_eq!(
            expected_pair_count(9, 3) - res.pairs.len(),
            srp_missing_pairs(2, 3)
        );
        // the three missing pairs are exactly (f,c), (h,c), (h,g):
        // ids f=6, c=3, h=8, g=7
        let set = res.pair_set();
        for (a, b) in [(6, 3), (8, 3), (8, 7)] {
            assert!(!set.contains(&Pair::new(a, b)), "({a},{b}) must be missing");
        }
    }

    #[test]
    fn single_partition_equals_sequential() {
        let entities: Vec<Entity> = (0..50)
            .map(|i| Entity::new(i, &format!("{:02} title", i % 10), ""))
            .collect();
        let cfg = SnConfig {
            window: 5,
            num_map_tasks: 4,
            workers: 2,
            partitioner: Arc::new(crate::sn::partition::EvenPartition::ascii(1)),
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        };
        let res = run(&entities, &cfg).unwrap();
        let mut seq = crate::sn::seq::run_blocking(&entities, &TitlePrefixKey::new(2), 5);
        seq.sort_unstable();
        assert_eq!(res.pair_set(), seq);
    }
}
