//! Shared types for the SN MapReduce jobs.

use std::path::PathBuf;
use std::sync::Arc;

use crate::er::blockkey::{BlockingKey, TitlePrefixKey};
use crate::er::entity::{Entity, Pair, ScoredPair};
use crate::er::strategy::MatchStrategyConfig;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::JobStats;
use crate::mapreduce::fault::FaultPlan;
use crate::mapreduce::memory::MemoryPool;
use crate::mapreduce::sim::JobProfile;
use crate::mapreduce::trace::TraceSpec;
use crate::mapreduce::types::SizeEstimate;
use crate::sn::loadbalance::BalanceStrategy;
use crate::sn::partition::PartitionFn;

/// The composite intermediate key of Algorithms 1–2.
///
/// * SRP (§4.1) uses `p(k).k` — here `bound == part == p(k)`.
/// * RepSN (§4.3) uses `bound.p(k).k` where `bound` is the *destination*
///   reduce partition (original entities: `bound = p(k)`; replicated:
///   `bound = p(k) + 1`).
/// * JobSN phase 2 (§4.2) uses `boundary.r_i.k`.
///
/// Repartitioning uses `bound`; grouping uses `bound`; sorting uses the
/// full key.  `id` is the determinism tie-break (see [`crate::sn`] module
/// docs) — it is *last*, so it never affects which partition or boundary
/// an entity lands in.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct SnKey {
    pub bound: u32,
    pub part: u32,
    pub key: String,
    pub id: u64,
}

impl SnKey {
    /// SRP-style key: destination = home partition.
    pub fn srp(part: u32, key: String, id: u64) -> Self {
        Self {
            bound: part,
            part,
            key,
            id,
        }
    }
}

impl SizeEstimate for SnKey {
    fn size_bytes(&self) -> usize {
        4 + 4 + self.key.len() + 8
    }
}

/// Values flowing out of SN reduce steps.
#[derive(Debug, Clone)]
pub enum SnVal {
    /// A blocking correspondence (blocking mode output `B`).
    Pair(Pair),
    /// A matched pair with score (matching mode).
    Match(ScoredPair),
    /// A boundary entity re-emitted by JobSN phase 1.
    Entity(Arc<Entity>),
}

impl SizeEstimate for SnVal {
    fn size_bytes(&self) -> usize {
        match self {
            SnVal::Pair(p) => p.size_bytes(),
            SnVal::Match(m) => m.size_bytes(),
            SnVal::Entity(e) => e.size_bytes(),
        }
    }
}

/// What the reduce step does with window pairs.
#[derive(Clone, Default)]
pub enum SnMode {
    /// Emit every sliding-window correspondence (the paper's output `B`,
    /// used to compare blocking approaches).
    #[default]
    Blocking,
    /// Apply the matching strategy and emit only matches (the full ER
    /// workflow).
    Matching(MatchStrategyConfig),
}

impl std::fmt::Debug for SnMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnMode::Blocking => write!(f, "Blocking"),
            SnMode::Matching(c) => write!(f, "Matching({c:?})"),
        }
    }
}

/// Disk-backed intermediate settings shared by every SN variant.
///
/// Threaded through [`SnConfig::spill`]: each SN job builds the matching
/// [`SpillSpec`](crate::mapreduce::sortspill::SpillSpec) for its own
/// intermediate record type (see [`crate::sn::codec`]), so one knob makes
/// the whole variant — including JobSN's second job and the loadbalance
/// BDM pipeline — run disk-backed.
#[derive(Debug, Clone)]
pub struct SnSpill {
    /// Directory for the codec-serialized run files (each file is deleted
    /// as soon as its last reader drops; pass a
    /// [`TempSpillDir`](crate::mapreduce::sortspill::TempSpillDir) path
    /// in tests).
    pub dir: PathBuf,
    /// Whole-run DEFLATE, on by default (the paper's cluster compresses
    /// intermediates, §5.1 — `SHUFFLE_BYTES` then reports compressed
    /// volume, with `SHUFFLE_BYTES_RAW` alongside).
    pub compress: bool,
}

impl SnSpill {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            compress: true,
        }
    }

    pub fn with_compress(mut self, on: bool) -> Self {
        self.compress = on;
        self
    }
}

/// Configuration shared by all SN MapReduce variants.
#[derive(Clone)]
pub struct SnConfig {
    /// Window size `w ≥ 2`.
    pub window: usize,
    /// Map tasks `m`.
    pub num_map_tasks: usize,
    /// Worker slots executing tasks concurrently (the number of reduce
    /// *tasks* is fixed by the partition function — §5.2 runs 10 reduce
    /// tasks on 8 slots).
    pub workers: usize,
    /// The monotonic partition function `p : k → i`.
    pub partitioner: Arc<dyn PartitionFn>,
    /// Blocking-key generator (paper: lowercased 2-letter title prefix).
    pub blocking_key: Arc<dyn BlockingKey>,
    /// Blocking-only or full matching.
    pub mode: SnMode,
    /// Map-side sort memory budget in records, forwarded to
    /// [`crate::mapreduce::JobConfig::sort_buffer_records`] by every SN
    /// job.  `None` (default) sorts whole buckets in memory.
    pub sort_buffer_records: Option<usize>,
    /// Reduce-side load balancing.  [`BalanceStrategy::None`] (default)
    /// is the paper's plain key-range repartitioning; `BlockSplit` /
    /// `PairRange` route `repsn`/`jobsn`/`multipass` through the
    /// [`loadbalance`](crate::sn::loadbalance) two-job pipeline (the
    /// partitioner then only supplies the reduce-task target `r`).
    pub balance: BalanceStrategy,
    /// Disk-backed, optionally compressed intermediates for every job the
    /// variant runs.  `None` (default) keeps runs in memory.
    pub spill: Option<SnSpill>,
    /// Push-based shuffle for every job the variant runs: reduce tasks
    /// start on their first runs instead of after the map wave
    /// ([`crate::mapreduce::JobConfig::push`]).  Takes effect when the
    /// variant executes on a
    /// [`JobScheduler`](crate::mapreduce::scheduler::JobScheduler) (any
    /// [`Exec::Scheduler`](crate::mapreduce::scheduler::Exec)); the
    /// serial executor is the barrier reference path and ignores it.
    /// Output is identical either way (`tests/prop_push.rs`).
    pub push: bool,
    /// Fault-injection plan forwarded to every job the variant runs
    /// ([`crate::mapreduce::JobConfig::faults`]) — the harness knob
    /// behind `tests/prop_fault.rs`.  `None` (default) injects nothing.
    pub faults: Option<FaultPlan>,
    /// Per-job panicked-attempt retry budget
    /// ([`crate::mapreduce::JobConfig::max_task_retries`]).  `None`
    /// (default) defers to the scheduler-wide budget; the serial
    /// executor stays fail-fast regardless.
    pub max_task_retries: Option<u32>,
    /// Task-event trace sink forwarded to every job the variant runs
    /// ([`crate::mapreduce::JobConfig::trace`]).  All jobs of a variant
    /// share the sink — JobSN's two jobs interleave in one stream,
    /// distinguished by the `job` field of each record.  `None` (default)
    /// records nothing and allocates nothing.
    pub trace: Option<TraceSpec>,
    /// Shared memory pool forwarded to every job the variant runs
    /// ([`crate::mapreduce::JobConfig::memory`]) — all jobs of a variant
    /// (and all concurrently running variants handed the same pool)
    /// account map sort buffers, staged shuffle runs, and reduce merge
    /// windows against one byte budget.  `None` (default) accounts
    /// nothing and is a strict no-op.
    pub memory: Option<MemoryPool>,
}

impl Default for SnConfig {
    fn default() -> Self {
        Self {
            window: 3,
            num_map_tasks: 1,
            workers: 1,
            partitioner: Arc::new(crate::sn::partition::EvenPartition::ascii(1)),
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: BalanceStrategy::None,
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        }
    }
}

impl std::fmt::Debug for SnConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SnConfig")
            .field("window", &self.window)
            .field("num_map_tasks", &self.num_map_tasks)
            .field("workers", &self.workers)
            .field("partitions", &self.partitioner.num_partitions())
            .field("mode", &self.mode)
            .field("balance", &self.balance)
            .field("spill", &self.spill)
            .field("push", &self.push)
            .field("faults", &self.faults)
            .field("max_task_retries", &self.max_task_retries)
            .field("trace", &self.trace.is_some())
            .field("memory", &self.memory.is_some())
            .finish()
    }
}

/// Result of an SN run (any variant).
#[derive(Debug)]
pub struct SnResult {
    /// Blocking correspondences (Blocking mode; empty in Matching mode).
    pub pairs: Vec<Pair>,
    /// Matches (Matching mode; empty in Blocking mode).
    pub matches: Vec<ScoredPair>,
    /// Merged counters across all jobs of the variant.
    pub counters: Arc<Counters>,
    /// Engine statistics, one entry per MapReduce job executed
    /// (RepSN/SRP: 1; JobSN: 2).
    pub stats: Vec<JobStats>,
    /// Simulator profiles, one per job (paired with `stats`).
    pub profiles: Vec<JobProfile>,
}

impl SnResult {
    /// Candidate/match pairs as a sorted, deduplicated set (for set
    /// comparisons in tests and benches).
    pub fn pair_set(&self) -> Vec<Pair> {
        let mut v: Vec<Pair> = if self.pairs.is_empty() {
            self.matches.iter().map(|m| m.pair).collect()
        } else {
            self.pairs.clone()
        };
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Counter names used by the SN jobs.
pub mod counter_names {
    pub const COMPARISONS: &str = "sn.window_comparisons";
    pub const BOUNDARY_ENTITIES: &str = "sn.boundary_entities_emitted";
    pub const REPLICATED_ENTITIES: &str = "sn.replicated_entities";
    pub const REPLICAS_DISCARDED: &str = "sn.replicas_discarded_at_reduce";
    pub const PAIRS_FILTERED_DUPLICATE: &str = "sn.pairs_filtered_duplicate";
    pub const MATCHES: &str = "sn.matches";
    pub const PAIRS_SKIPPED_SHORTCIRCUIT: &str = "sn.pairs_skipped_shortcircuit";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snkey_order_is_bound_part_key_id() {
        let a = SnKey { bound: 1, part: 1, key: "b".into(), id: 9 };
        let b = SnKey { bound: 1, part: 1, key: "c".into(), id: 1 };
        let c = SnKey { bound: 2, part: 1, key: "a".into(), id: 1 };
        let d = SnKey { bound: 1, part: 1, key: "b".into(), id: 10 };
        assert!(a < b);
        assert!(b < c);
        assert!(a < d && d < b);
    }

    #[test]
    fn srp_key_sets_bound_to_part() {
        let k = SnKey::srp(3, "ab".into(), 7);
        assert_eq!(k.bound, 3);
        assert_eq!(k.part, 3);
    }

    #[test]
    fn replicated_key_sorts_before_originals_of_next_partition() {
        // RepSN: replica of partition 1 sent to reducer 2 must sort before
        // every original of partition 2 regardless of blocking key.
        let replica = SnKey { bound: 2, part: 1, key: "zz".into(), id: 0 };
        let original = SnKey { bound: 2, part: 2, key: "aa".into(), id: 0 };
        assert!(replica < original);
    }
}
