//! Skew-aware partitioning — the paper's stated future work ("we plan to
//! investigate load balancing and data partitioning mechanisms for
//! MapReduce", §7).
//!
//! Two mechanisms, composable with every SN variant:
//!
//! 1. [`pair_balanced`] — choose range boundaries that equalize the
//!    *estimated SN comparison cost* per partition instead of the entity
//!    count.  For SN the reduce cost of partition `i` is
//!    `≈ size_i · (w−1)` — linear — so entity-balanced boundaries are
//!    already cost-balanced *for SN*; the estimator matters when some
//!    reduce groups carry extra per-entity cost (e.g. matching with very
//!    long abstracts) or when combined with standard blocking (quadratic
//!    blocks).  The estimator is pluggable.
//!
//! 2. [`VirtualPartition`] — split oversized partitions into `v` virtual
//!    sub-ranges handled by *different* reduce tasks.  Sub-range
//!    boundaries inside a partition are ordinary SRP boundaries, so RepSN
//!    / JobSN boundary handling stitches them — giving the correctness of
//!    one big partition with the parallelism of `v` small ones.  (This is
//!    the direction the authors later published as "Load Balancing for
//!    MapReduce-based Entity Resolution", ICDE 2012.)
//!
//! 3. [`key_histogram_job`] / [`manual_partitioner_job`] — the blocking-key
//!    histogram the Manual partitioner is built from, computed as a
//!    MapReduce job *with a map-side combiner* instead of driver-side.
//!    This is the analysis job the paper's "manually defined" partitioning
//!    implies (sample the key distribution, cut it at the quantiles), and
//!    it exercises the combiner on the real SN data path: the map output
//!    is one `(key, 1)` per entity, which the combiner collapses to one
//!    `(key, count)` per distinct key per task before the shuffle.

use std::sync::Arc;

use crate::er::blockkey::BlockingKey;
use crate::er::entity::Entity;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::run_job_with_combiner;
use crate::mapreduce::types::{Emitter, FnMapTask, FnReduceTask, HashPartitioner, ValuesIter};
use crate::mapreduce::{FnCombiner, JobConfig};
use crate::sn::partition::{partition_sizes, PartitionFn, RangePartition};

/// Build boundaries that equalize Σ cost(entity) per partition.
///
/// `cost` estimates the reduce-side cost contribution of one entity
/// (use `|_| 1.0` for entity-count balancing).
pub fn pair_balanced<C>(
    entities: &[Entity],
    key_fn: &dyn BlockingKey,
    r: usize,
    cost: C,
) -> RangePartition
where
    C: Fn(&Entity) -> f64,
{
    assert!(r >= 1);
    let mut keyed: Vec<(String, f64)> = entities
        .iter()
        .map(|e| (key_fn.key(e), cost(e)))
        .collect();
    keyed.sort_by(|a, b| a.0.cmp(&b.0));
    // aggregate equal-key runs: a range boundary can only sit between runs
    let mut runs: Vec<(String, f64)> = Vec::new();
    for (key, c) in keyed {
        match runs.last_mut() {
            Some((k, acc)) if *k == key => *acc += c,
            _ => runs.push((key, c)),
        }
    }
    let total: f64 = runs.iter().map(|(_, c)| *c).sum();
    // greedy: close the current partition when adding the next run would
    // overshoot its fair share of the *remaining* cost — adapts around
    // unsplittable hot runs instead of burning boundaries inside them
    let mut boundaries = Vec::with_capacity(r.saturating_sub(1));
    let mut parts_left = r;
    let mut remaining = total;
    let mut acc = 0.0;
    for (key, c) in &runs {
        if parts_left > 1 && acc > 0.0 {
            let target = remaining / parts_left as f64;
            // close if we're nearer the target without this run
            if acc + c / 2.0 >= target {
                boundaries.push(key.clone());
                parts_left -= 1;
                remaining -= acc;
                acc = 0.0;
            }
        }
        acc += c;
    }
    while boundaries.len() + 1 < r {
        // degenerate tail: repeat the max key (empty partitions are legal)
        boundaries.push(runs.last().map(|(k, _)| k.clone()).unwrap_or_default());
    }
    RangePartition::new(boundaries, &format!("PairBalanced{r}"))
}

/// [`pair_balanced`] boundaries (entity-count cost) with `r` shrunk until
/// every partition holds ≥ `w−1` entities — classic RepSN's one-step
/// boundary-replication assumption, which the *unbalanced* baselines of
/// the load-balancing benches and property tests must satisfy to stay
/// exact (`pair_balanced` never produces empty partitions, so only the
/// minimum size needs enforcing).
pub fn pair_balanced_min_size(
    entities: &[Entity],
    key_fn: &dyn BlockingKey,
    r: usize,
    w: usize,
) -> RangePartition {
    let mut r = r.max(1);
    loop {
        let p = pair_balanced(entities, key_fn, r, |_| 1.0);
        let sizes = partition_sizes(entities.iter().map(|e| key_fn.key(e)), &p);
        if r == 1 || sizes.iter().all(|&s| s + 1 >= w) {
            return p;
        }
        r -= 1;
    }
}

/// Compute the blocking-key histogram as a MapReduce job with a map-side
/// combiner: map emits `(key, 1)` per entity, the combiner pre-sums each
/// sorted run (collapsing a task's records to one per distinct key), and
/// a single reduce task emits the key-sorted histogram.  Returns the
/// histogram and the job's counters (so callers can report the combiner's
/// shuffle saving on real SN data).
pub fn key_histogram_job(
    entities: &[Entity],
    key_fn: &Arc<dyn BlockingKey>,
    num_map_tasks: usize,
    workers: usize,
) -> (Vec<(String, u64)>, Arc<Counters>) {
    let input: Vec<((), Arc<Entity>)> = entities
        .iter()
        .map(|e| ((), Arc::new(e.clone())))
        .collect();
    let bk = Arc::clone(key_fn);
    let mapper = Arc::new(FnMapTask::new(
        move |_k: (), e: Arc<Entity>, out: &mut Emitter<String, u64>, _c: &Counters| {
            out.emit(bk.key(&e), 1);
        },
    ));
    let reducer = Arc::new(FnReduceTask::new(
        |k: &String, vals: ValuesIter<'_, u64>, out: &mut Emitter<String, u64>, _c: &Counters| {
            out.emit(k.clone(), vals.copied().sum());
        },
    ));
    let cfg = JobConfig::named("key-histogram")
        .with_tasks(num_map_tasks.max(1), 1)
        .with_workers(workers.max(1));
    let res = run_job_with_combiner(
        &cfg,
        input,
        mapper,
        Arc::new(HashPartitioner::new(|_: &String| 0)),
        Arc::new(|a: &String, b: &String| a == b),
        reducer,
        Arc::new(FnCombiner::new(|_k: &String, vals: Vec<u64>, _c: &Counters| {
            vec![vals.into_iter().sum()]
        })),
    );
    let counters = Arc::clone(&res.counters);
    (res.merged_output(), counters)
}

/// Boundaries at the count quantiles of a key histogram — exactly the
/// keys [`RangePartition::balanced`] picks from the sorted key multiset,
/// recovered from `(key, count)` runs instead of individual records.
pub fn balanced_from_histogram(hist: &[(String, u64)], r: usize) -> RangePartition {
    assert!(r >= 1);
    let n: u64 = hist.iter().map(|(_, c)| *c).sum();
    let mut boundaries = Vec::with_capacity(r.saturating_sub(1));
    for i in 1..r {
        let idx = (i as u64 * n) / r as u64; // position in the sorted multiset
        let mut cum = 0u64;
        let mut boundary = String::new();
        for (k, c) in hist {
            if cum + c > idx {
                boundary = k.clone();
                break;
            }
            cum += c;
        }
        boundaries.push(boundary);
    }
    RangePartition::new(boundaries, &format!("Manual{r}"))
}

/// The paper's Manual partitioner with its key statistics computed by the
/// engine ([`key_histogram_job`]) rather than driver-side; produces the
/// same boundaries as [`RangePartition::balanced`] on the same input.
pub fn manual_partitioner_job(
    entities: &[Entity],
    key_fn: &Arc<dyn BlockingKey>,
    r: usize,
    num_map_tasks: usize,
    workers: usize,
) -> RangePartition {
    let (hist, _) = key_histogram_job(entities, key_fn, num_map_tasks, workers);
    balanced_from_histogram(&hist, r)
}

/// A partition function that refines a base function by splitting its
/// heaviest partitions into virtual sub-ranges.
pub struct VirtualPartition {
    /// Sorted sub-boundary keys, including the base boundaries.
    inner: RangePartition,
    virtual_of: Vec<usize>,
}

impl VirtualPartition {
    /// Split every partition of `base` whose share of entities exceeds
    /// `max_share` into enough equal-count sub-ranges to go below it.
    /// Total reduce tasks grow accordingly.
    ///
    /// Superseded for hot-*block* splitting by
    /// [`loadbalance`](crate::sn::loadbalance): a key-granularity range
    /// function like this one cannot split a single hot key run, which is
    /// BlockSplit's whole point.  Kept as the lightweight option when a
    /// [`PartitionFn`] is required; its key statistics now come from the
    /// shared [`Bdm`](crate::sn::loadbalance::Bdm) histogram (one
    /// hot-block code path) instead of a private sort of all keys.
    pub fn split_hot(
        entities: &[Entity],
        key_fn: &dyn BlockingKey,
        base: &dyn PartitionFn,
        max_share: f64,
    ) -> Self {
        let hist = crate::sn::loadbalance::Bdm::from_entities(entities, key_fn, 1).key_histogram();
        Self::split_hot_from_histogram(&hist, base, max_share)
    }

    /// As [`VirtualPartition::split_hot`], from a `(key, count)` histogram
    /// in key order (e.g. [`key_histogram_job`] output or
    /// [`Bdm::key_histogram`](crate::sn::loadbalance::Bdm::key_histogram)).
    pub fn split_hot_from_histogram(
        hist: &[(String, u64)],
        base: &dyn PartitionFn,
        max_share: f64,
    ) -> Self {
        assert!(max_share > 0.0 && max_share <= 1.0);
        let n: u64 = hist.iter().map(|(_, c)| *c).sum::<u64>().max(1);
        // group the histogram's key runs by base partition
        let mut per_part: Vec<Vec<(&str, u64)>> = vec![Vec::new(); base.num_partitions()];
        for (k, c) in hist {
            per_part[base.partition(k)].push((k.as_str(), *c));
        }
        let mut boundaries: Vec<String> = Vec::new();
        let mut virtual_of = Vec::new();
        for (part, runs) in per_part.iter().enumerate() {
            let size: u64 = runs.iter().map(|(_, c)| *c).sum();
            let share = size as f64 / n as f64;
            let splits = if share > max_share {
                (share / max_share).ceil() as usize
            } else {
                1
            };
            virtual_of.extend(std::iter::repeat(part).take(splits));
            for v in 1..splits {
                // sub-boundary: the key at cumulative count ⌊v·size/splits⌋
                // within this partition (same quantile walk as
                // `balanced_from_histogram`)
                let idx = (v as u64 * size) / splits as u64;
                let mut cum = 0u64;
                let mut b = runs.last().map(|(k, _)| k.to_string()).unwrap_or_default();
                for (k, c) in runs {
                    if cum + c > idx {
                        b = k.to_string();
                        break;
                    }
                    cum += c;
                }
                boundaries.push(b);
            }
            // base boundary after this partition (except the last): first
            // key of the next non-empty partition, else repeat the global
            // last key (empty partitions are legal)
            if part + 1 < per_part.len() {
                let next = per_part[part + 1..]
                    .iter()
                    .flatten()
                    .next()
                    .map(|(k, _)| k.to_string())
                    .or_else(|| hist.last().map(|(k, _)| k.clone()))
                    .unwrap_or_default();
                boundaries.push(next);
            }
        }
        // RangePartition requires sorted boundaries; sub-keys are sorted
        // within partitions and base boundaries interleave correctly, but
        // duplicate keys can produce equal neighbors — sort defensively.
        boundaries.sort();
        Self {
            inner: RangePartition::new(boundaries, "Virtual"),
            virtual_of,
        }
    }

    /// Which base partition a virtual partition belongs to.
    pub fn base_partition(&self, virtual_idx: usize) -> usize {
        self.virtual_of.get(virtual_idx).copied().unwrap_or(0)
    }
}

impl PartitionFn for VirtualPartition {
    fn partition(&self, key: &str) -> usize {
        self.inner.partition(key)
    }

    fn num_partitions(&self) -> usize {
        self.inner.num_partitions()
    }

    fn name(&self) -> String {
        format!("Virtual({})", self.inner.num_partitions())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::TitlePrefixKey;
    use crate::sn::partition::{gini, EvenPartition};

    /// 60% of entities land on six hot keys "aa".."af" (splittable hot
    /// *partition*), the rest spread over "b*".."u*".  A single hot *key*
    /// would be unsplittable by any monotone range function — that case
    /// is the 2012 follow-up's block-split territory and out of scope.
    fn skewed_entities(n: usize) -> Vec<Entity> {
        (0..n as u64)
            .map(|i| {
                let k = if i % 10 < 6 {
                    format!("a{}", (b'a' + (i % 6) as u8) as char)
                } else {
                    format!(
                        "{}{}",
                        (b'b' + (i % 20) as u8) as char,
                        (b'a' + (i % 7) as u8) as char
                    )
                };
                Entity::new(i, &format!("{k} title {i}"), "")
            })
            .collect()
    }

    #[test]
    fn histogram_job_matches_driver_side_count() {
        let entities = skewed_entities(600);
        let bk: Arc<dyn BlockingKey> = Arc::new(TitlePrefixKey::new(2));
        let (hist, counters) = key_histogram_job(&entities, &bk, 4, 2);
        // reference: driver-side BTreeMap count
        let mut expect: std::collections::BTreeMap<String, u64> = Default::default();
        for e in &entities {
            *expect.entry(bk.key(e)).or_insert(0) += 1;
        }
        let expect: Vec<(String, u64)> = expect.into_iter().collect();
        assert_eq!(hist, expect);
        // the combiner must actually have collapsed records on this path
        use crate::mapreduce::counters::names;
        assert_eq!(counters.get(names::COMBINE_INPUT_RECORDS), 600);
        assert!(
            counters.get(names::COMBINE_OUTPUT_RECORDS)
                < counters.get(names::COMBINE_INPUT_RECORDS)
        );
        assert_eq!(
            counters.get(names::REDUCE_INPUT_RECORDS),
            counters.get(names::COMBINE_OUTPUT_RECORDS)
        );
    }

    #[test]
    fn manual_partitioner_job_equals_driver_side_balanced() {
        let entities = skewed_entities(800);
        let bk_dyn: Arc<dyn BlockingKey> = Arc::new(TitlePrefixKey::new(2));
        let bk = TitlePrefixKey::new(2);
        for r in [1usize, 3, 8] {
            let from_job = manual_partitioner_job(&entities, &bk_dyn, r, 4, 2);
            let driver = RangePartition::balanced(&entities, |e| bk.key(e), r);
            assert_eq!(from_job.num_partitions(), driver.num_partitions());
            assert_eq!(from_job.name(), driver.name());
            for e in &entities {
                let k = bk.key(e);
                assert_eq!(
                    from_job.partition(&k),
                    driver.partition(&k),
                    "partition mismatch for key {k} at r={r}"
                );
            }
        }
    }

    #[test]
    fn pair_balanced_equalizes_costs() {
        let entities = skewed_entities(2000);
        let bk = TitlePrefixKey::new(2);
        let p = pair_balanced(&entities, &bk, 8, |_| 1.0);
        let sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), &p);
        let g = gini(&sizes);
        assert!(g < 0.25, "pair-balanced should be near-equal: {sizes:?} g={g}");
        // compare: the Even split leaves the hot prefix in one partition
        let even = EvenPartition::ascii(8);
        let even_sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), &even);
        assert!(
            gini(&even_sizes) > g,
            "balancing must beat the even split: {even_sizes:?}"
        );
    }

    #[test]
    fn virtual_split_reduces_max_share() {
        let entities = skewed_entities(2000);
        let bk = TitlePrefixKey::new(2);
        let base = EvenPartition::ascii(4);
        let base_sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), &base);
        let base_max = *base_sizes.iter().max().unwrap();
        let vp = VirtualPartition::split_hot(&entities, &bk, &base, 0.25);
        assert!(vp.num_partitions() > base.num_partitions());
        let sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), &vp);
        let max = *sizes.iter().max().unwrap();
        // an unsplittable single hot *key* bounds what any range function
        // can do; but the hot partition here spans multiple keys and must
        // shrink
        assert!(
            max < base_max,
            "virtual split failed: base {base_sizes:?} → {sizes:?}"
        );
    }

    #[test]
    fn virtual_partition_is_monotone() {
        let entities = skewed_entities(500);
        let bk = TitlePrefixKey::new(2);
        let vp = VirtualPartition::split_hot(&entities, &bk, &EvenPartition::ascii(4), 0.3);
        let mut keys: Vec<String> = entities.iter().map(|e| bk.key(e)).collect();
        keys.sort();
        let mut last = 0;
        for k in &keys {
            let i = vp.partition(k);
            assert!(i >= last, "non-monotone at {k}");
            last = i;
        }
    }

    #[test]
    fn repsn_on_virtual_partitions_is_still_exact() {
        // the headline property: virtual sub-partitions + RepSN boundary
        // replication == sequential SN
        use crate::sn::types::{SnConfig, SnMode};
        let entities = skewed_entities(400);
        let bk = TitlePrefixKey::new(2);
        let vp = Arc::new(VirtualPartition::split_hot(
            &entities,
            &bk,
            &EvenPartition::ascii(4),
            0.2,
        ));
        let w = 4;
        // assumption check: virtual partitions still ≥ w−1 entities
        let sizes = partition_sizes(entities.iter().map(|e| bk.key(e)), vp.as_ref());
        if sizes.iter().any(|&s| s < w - 1) {
            // fall back: property vacuous for this corpus shape
            return;
        }
        let cfg = SnConfig {
            window: w,
            num_map_tasks: 4,
            workers: 2,
            partitioner: vp,
            blocking_key: Arc::new(TitlePrefixKey::new(2)),
            mode: SnMode::Blocking,
            sort_buffer_records: None,
            balance: Default::default(),
            spill: None,
            push: false,
            faults: None,
            max_task_retries: None,
            trace: None,
            memory: None,
        };
        let res = crate::sn::repsn::run(&entities, &cfg).unwrap();
        let mut expect = crate::sn::seq::run_blocking(&entities, &TitlePrefixKey::new(2), w);
        expect.sort_unstable();
        expect.dedup();
        assert_eq!(res.pair_set(), expect);
    }
}
