//! PairRange: enumerate every SN comparison pair globally and give each
//! reduce task a near-equal contiguous range of pair indices.
//!
//! The strategy of arXiv:1108.1631 §4.2, adapted to Sorted Neighborhood.
//! The global pair enumeration orders pairs by their *later* element's
//! rank, then by decreasing earlier-element rank: pair `(i, j)` (ranks in
//! the global `(key, id)` sort order, `0 < j − i < w`) has index
//! `cum_pairs(j) + (j − 1 − i)` — a closed form, so both mapper and
//! reducer compute it from ranks alone, no lookup tables.  The `P` total
//! pairs are cut into `r` ranges of `⌈P/r⌉`/`⌊P/r⌋`; range `t` *is*
//! reduce task `t`, so per-task pair counts are equal by construction —
//! the finest-grained balancing possible, at the price of a little more
//! replication than BlockSplit.
//!
//! The mapper derives each entity's rank from the BDM ([`Bdm::rank`]),
//! computes the closed interval of pair indices the entity participates
//! in ([`pair_span`]) and emits one copy to every range overlapping it.
//! The reducer walks its copies in rank order (the composite-key sort)
//! through the shared sliding window and keeps exactly the comparisons
//! whose pair index falls inside its range — pairs outside are some other
//! task's responsibility, so the union over tasks is the exact
//! unbalanced-RepSN pair set with no duplicates.

use std::sync::Arc;

use super::bdm::Bdm;
use super::{cum_pairs, pair_index, total_pairs, Ranked};
use crate::er::blockkey::BlockingKey;
use crate::er::entity::Entity;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::JobResult;
use crate::mapreduce::scheduler::Exec;
use crate::mapreduce::types::{
    Emitter, MapTask, MapTaskFactory, ReduceTask, ReduceTaskFactory, ValuesIter,
};
use crate::mapreduce::JobConfig;
use crate::sn::pairs::WindowProc;
use crate::sn::srp::{group_by_bound, BoundPartitioner};
use crate::sn::types::{counter_names, SnConfig, SnKey, SnMode, SnVal};

/// A PairRange plan: the pair-index range starts, one per reduce task.
#[derive(Debug, Clone)]
pub struct PairRangePlan {
    /// Start pair index of each range; `starts[0] == 0`, strictly
    /// increasing (empty ranges are dropped, so `num_tasks ≤ r`).
    starts: Vec<u64>,
    total: u64,
    n: u64,
    w: usize,
}

impl PairRangePlan {
    pub fn num_tasks(&self) -> usize {
        self.starts.len()
    }

    pub fn total_pairs(&self) -> u64 {
        self.total
    }

    /// Which reduce task owns pair index `idx`.
    pub fn range_of(&self, idx: u64) -> usize {
        debug_assert!(idx < self.total);
        self.starts[1..].partition_point(|&s| s <= idx)
    }

    /// Half-open pair-index range `[lo, hi)` of task `t`.
    pub fn bounds(&self, t: usize) -> (u64, u64) {
        (
            self.starts[t],
            self.starts.get(t + 1).copied().unwrap_or(self.total),
        )
    }
}

/// Cut the `total_pairs(n, w)` global pair indices into ≤ `r` near-equal
/// contiguous ranges.
pub fn plan(n: u64, r: usize, w: usize) -> PairRangePlan {
    let w = w.max(2);
    let r = r.max(1);
    let total = total_pairs(n, w);
    let mut starts: Vec<u64> = (0..r as u64)
        .map(|t| ((total as u128 * t as u128) / r as u128) as u64)
        .collect();
    starts.dedup(); // drop empty ranges when total < r
    PairRangePlan {
        starts,
        total,
        n,
        w,
    }
}

/// Closed interval `[lo, hi]` of global pair indices involving the entity
/// at rank `t`, or `None` if it participates in no pair (`n < 2`).
pub fn pair_span(t: u64, n: u64, w: usize) -> Option<(u64, u64)> {
    let w = w.max(2) as u64;
    if n < 2 {
        return None;
    }
    // as the later element: indices cum(t) .. cum(t) + min(t, w−1) − 1
    let later = (t >= 1).then(|| {
        let c = cum_pairs(t, w as usize);
        (c, c + t.min(w - 1) - 1)
    });
    // as the earlier element: partner ranks t+1 ..= min(n−1, t+w−1)
    let jmax = (n - 1).min(t + w - 1);
    let earlier = (jmax > t).then(|| {
        (
            cum_pairs(t + 1, w as usize), // pair (t, t+1): offset 0
            cum_pairs(jmax, w as usize) + (jmax - 1 - t),
        )
    });
    match (later, earlier) {
        (Some((a, b)), Some((c, d))) => Some((a.min(c), b.max(d))),
        (Some(s), None) | (None, Some(s)) => Some(s),
        (None, None) => None,
    }
}

/// The PairRange map task: rank-derive, then emit one copy per
/// overlapping range.
struct PairRangeMap {
    bdm: Arc<Bdm>,
    plan: Arc<PairRangePlan>,
    blocking_key: Arc<dyn BlockingKey>,
    ranks: super::bdm::RankTracker,
    replicated: u64,
}

impl MapTask<u32, Arc<Entity>, SnKey, Ranked> for PairRangeMap {
    fn configure(&mut self, _out: &mut Emitter<SnKey, Ranked>, _c: &Counters) {
        self.ranks.reset();
        self.replicated = 0;
    }

    fn map(&mut self, part: u32, e: Arc<Entity>, out: &mut Emitter<SnKey, Ranked>, _c: &Counters) {
        let k = self.blocking_key.key(&e);
        let rank = self.ranks.rank(&self.bdm, &k, part);
        let Some((lo, hi)) = pair_span(rank, self.plan.n, self.plan.w) else {
            return;
        };
        let t_lo = self.plan.range_of(lo);
        let t_hi = self.plan.range_of(hi);
        for t in t_lo..=t_hi {
            out.emit(
                SnKey {
                    bound: t as u32,
                    part: t as u32,
                    key: k.clone(),
                    id: e.id,
                },
                Ranked {
                    rank,
                    entity: Arc::clone(&e),
                },
            );
        }
        self.replicated += (t_hi - t_lo) as u64;
    }

    fn close(&mut self, _out: &mut Emitter<SnKey, Ranked>, c: &Counters) {
        c.add(counter_names::REPLICATED_ENTITIES, self.replicated);
    }
}

struct PairRangeMapFactory {
    bdm: Arc<Bdm>,
    plan: Arc<PairRangePlan>,
    blocking_key: Arc<dyn BlockingKey>,
}

impl MapTaskFactory<u32, Arc<Entity>, SnKey, Ranked> for PairRangeMapFactory {
    fn create_task(&self) -> Box<dyn MapTask<u32, Arc<Entity>, SnKey, Ranked> + Send> {
        Box::new(PairRangeMap {
            bdm: Arc::clone(&self.bdm),
            plan: Arc::clone(&self.plan),
            blocking_key: Arc::clone(&self.blocking_key),
            ranks: Default::default(),
            replicated: 0,
        })
    }
}

/// The PairRange reduce task: slide the shared window over the received
/// rank-ordered copies and keep exactly the in-range pair indices.
///
/// Entity ranks travel through the window's provenance tag, which is
/// `u32` — fine for this testbed's corpus sizes (`run_balanced` checks).
struct PairRangeReduce {
    w: usize,
    mode: SnMode,
    plan: Arc<PairRangePlan>,
}

impl ReduceTask<SnKey, Ranked, SnKey, SnVal> for PairRangeReduce {
    fn reduce(
        &mut self,
        key: &SnKey,
        values: ValuesIter<'_, Ranked>,
        out: &mut Emitter<SnKey, SnVal>,
        counters: &Counters,
    ) {
        let (lo, hi) = self.plan.bounds(key.bound as usize);
        let w = self.w.max(2);
        let mut proc = WindowProc::new(w, &self.mode);
        for v in values {
            debug_assert!(v.rank <= u32::MAX as u64);
            proc.push(&v.entity, v.rank as u32, |older, newer| {
                let (i, j) = (older.tag as u64, newer.tag as u64);
                if j - i >= w as u64 {
                    return false; // rank gap wider than the window
                }
                let idx = pair_index(i, j, w);
                lo <= idx && idx < hi
            });
        }
        proc.finish(key, out, counters);
    }
}

struct PairRangeReduceFactory {
    w: usize,
    mode: SnMode,
    plan: Arc<PairRangePlan>,
}

impl ReduceTaskFactory<SnKey, Ranked, SnKey, SnVal> for PairRangeReduceFactory {
    fn create_task(&self) -> Box<dyn ReduceTask<SnKey, Ranked, SnKey, SnVal> + Send> {
        Box::new(PairRangeReduce {
            w: self.w,
            mode: self.mode.clone(),
            plan: Arc::clone(&self.plan),
        })
    }
}

/// Run the PairRange repartition job over the pipeline's shared
/// [`partitioned_input`](super::bdm::partitioned_input).
pub(super) fn run_job(
    input: Vec<(u32, Arc<Entity>)>,
    cfg: &SnConfig,
    bdm: Arc<Bdm>,
    plan: Arc<PairRangePlan>,
    exec: Exec<'_>,
) -> JobResult<SnKey, SnVal> {
    let m = cfg.num_map_tasks.max(1);
    let job_cfg = JobConfig::named("pairrange")
        .with_tasks(m, plan.num_tasks())
        .with_workers(cfg.workers)
        .with_sort_buffer(cfg.sort_buffer_records)
        .with_spill(cfg.spill.as_ref().map(crate::sn::codec::ranked_job_spec))
        .with_push(cfg.push)
        .with_faults(cfg.faults.clone())
        .with_retries(cfg.max_task_retries)
        .with_trace(cfg.trace.clone())
        .with_memory(cfg.memory.clone());
    let mapper: Arc<dyn MapTaskFactory<u32, Arc<Entity>, SnKey, Ranked>> =
        Arc::new(PairRangeMapFactory {
            bdm,
            plan: Arc::clone(&plan),
            blocking_key: Arc::clone(&cfg.blocking_key),
        });
    let reducer: Arc<dyn ReduceTaskFactory<SnKey, Ranked, SnKey, SnVal>> =
        Arc::new(PairRangeReduceFactory {
            w: cfg.window,
            mode: cfg.mode.clone(),
            plan,
        });
    exec.run_job(
        &job_cfg,
        input,
        mapper,
        Arc::new(BoundPartitioner),
        group_by_bound(),
        reducer,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_cover_every_pair_exactly() {
        // every pair index is inside both endpoints' spans
        let (n, w) = (40u64, 5usize);
        for j in 1..n {
            for i in j.saturating_sub(w as u64 - 1)..j {
                let idx = pair_index(i, j, w);
                for t in [i, j] {
                    let (lo, hi) = pair_span(t, n, w).unwrap();
                    assert!(
                        lo <= idx && idx <= hi,
                        "pair ({i},{j}) idx {idx} outside span of {t} [{lo},{hi}]"
                    );
                }
            }
        }
    }

    #[test]
    fn pair_indices_are_dense() {
        let (n, w) = (30u64, 4usize);
        let mut seen = vec![false; total_pairs(n, w) as usize];
        for j in 1..n {
            for i in j.saturating_sub(w as u64 - 1)..j {
                let idx = pair_index(i, j, w) as usize;
                assert!(!seen[idx], "index {idx} assigned twice");
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "pair indices must be dense");
    }

    #[test]
    fn plan_ranges_are_near_equal() {
        let p = plan(1000, 8, 10);
        assert_eq!(p.num_tasks(), 8);
        let sizes: Vec<u64> = (0..8).map(|t| { let (lo, hi) = p.bounds(t); hi - lo }).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max - min <= 1, "ranges must differ by ≤ 1 pair: {sizes:?}");
        assert_eq!(sizes.iter().sum::<u64>(), total_pairs(1000, 10));
    }

    #[test]
    fn degenerate_plans() {
        // fewer pairs than tasks → empty ranges dropped
        let p = plan(3, 8, 2); // 2 pairs
        assert!(p.num_tasks() <= 2);
        assert_eq!(p.total_pairs(), 2);
        // no pairs at all
        let p1 = plan(1, 4, 3);
        assert_eq!(p1.total_pairs(), 0);
        assert_eq!(p1.num_tasks(), 1);
        assert!(pair_span(0, 1, 3).is_none());
    }
}
