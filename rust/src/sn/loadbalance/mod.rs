//! Skew-aware load balancing: BDM analysis job + BlockSplit / PairRange
//! repartitioning (the Kolb, Thor & Rahm 2012 direction,
//! arXiv:1108.1631, adapted to Sorted Neighborhood).
//!
//! ## Why speculation is not enough
//!
//! PR 2's speculation sweep (`BENCH_skew.json`) demonstrates the paper's
//! limitation: cloning a straggler rescues *machine* skew (slow node,
//! fast clone elsewhere) but cannot beat *data* skew — the clone re-runs
//! the same oversized partition.  Worse, a monotone key-range partitioner
//! ([`PartitionFn`](crate::sn::partition::PartitionFn)) cannot split a
//! hot *block* (one giant blocking-key run) at all: every equal key lands
//! in one partition.  Fixing data skew needs the *output partitioning
//! itself* to be computed from the data — by a prior MapReduce job.
//!
//! ## The two-job architecture
//!
//! 1. **Analysis** — the [`bdm`] module's Block Distribution Matrix job
//!    counts entities per (blocking key × map input partition), a real
//!    engine job with a map-side combiner (the
//!    [`key_histogram_job`](crate::sn::balance::key_histogram_job)
//!    pattern with the partition dimension added).  Its prefix sums let
//!    the second job's mappers compute every entity's **global rank** in
//!    the `(key, id)` SN sort order from local information alone.
//! 2. **Balanced repartition** — one of two strategies turns ranks into
//!    reduce routing:
//!    * [`blocksplit`] cuts the rank space at BDM *cell* boundaries
//!      (block × input partition sub-blocks) so each reduce task gets a
//!      near-equal share of the window-pair cost; oversized blocks are
//!      split mid-run, small blocks ride along unsplit, and RepSN-style
//!      replication of the `w−1` highest ranks per cut stitches the
//!      windows.
//!    * [`pairrange`] enumerates all `P` comparison pairs by a closed-form
//!      global index and assigns each reduce task a contiguous range of
//!      `≈ P/r` pair indices — exact balance, slightly more replication.
//!
//! Both strategies emit **exactly the pair set of unbalanced RepSN**
//! (property-tested in `tests/prop_balance.rs`); only *where* each pair
//! is produced changes.  They plug in behind [`BalanceStrategy`] on
//! [`SnConfig`](crate::sn::types::SnConfig): `repsn`, `jobsn` and (through
//! them) `multipass` dispatch here when a strategy is selected, on
//! whatever executor they were given — so balanced jobs run on the shared
//! [`JobScheduler`](crate::mapreduce::scheduler::JobScheduler) and
//! *compose with* speculation rather than replacing it (speculation still
//! covers machine skew; the repartitioning removes the data skew it
//! cannot).
//!
//! ## Observability
//!
//! [`counter_names::PAIRS_TOTAL`] / [`counter_names::PAIRS_MAX_TASK`]
//! expose the reduce-pair skew ratio (`max / (total / tasks)`), and
//! [`counter_names::BLOCKS_SPLIT`] reports how many blocks BlockSplit had
//! to cut; `benches/fig9_skew.rs` sweeps speculation vs BlockSplit vs
//! PairRange into `BENCH_balance.json`, with
//! [`sim::reduce_secs_from_pairs`](crate::mapreduce::sim::reduce_secs_from_pairs)
//! as the matching simulator cost model.

pub mod bdm;
pub mod blocksplit;
pub mod pairrange;

pub use bdm::{bdm_job, Bdm, BdmJobResult};
pub use blocksplit::BlockSplitPlan;
pub use pairrange::PairRangePlan;

use std::sync::Arc;

use crate::er::entity::Entity;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::JobStats;
use crate::mapreduce::scheduler::{Exec, JobScheduler};
use crate::mapreduce::sim::JobProfile;
use crate::mapreduce::types::SizeEstimate;
use crate::sn::types::{SnConfig, SnResult};

/// Which reduce-side load-balancing strategy an SN job runs with.
///
/// Threaded through [`SnConfig`](crate::sn::types::SnConfig): `None` is
/// the paper's plain key-range repartitioning; the other two run the
/// two-job architecture of this module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BalanceStrategy {
    /// Plain RepSN: reduce tasks = key-range partitions, skew and all.
    #[default]
    None,
    /// BDM analysis + block splitting at sub-block granularity.
    BlockSplit,
    /// BDM analysis + contiguous global pair-index ranges.
    PairRange,
}

impl BalanceStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            BalanceStrategy::None => "none",
            BalanceStrategy::BlockSplit => "blocksplit",
            BalanceStrategy::PairRange => "pairrange",
        }
    }

    /// Parse a CLI flag value (`none` / `blocksplit` / `pairrange`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(BalanceStrategy::None),
            "blocksplit" | "block-split" => Some(BalanceStrategy::BlockSplit),
            "pairrange" | "pair-range" => Some(BalanceStrategy::PairRange),
            _ => None,
        }
    }
}

/// Counter names reported by the balanced jobs.
pub mod counter_names {
    /// Total reduce-task output records of the repartition job (in SN
    /// blocking mode: the total window-pair count).
    pub const PAIRS_TOTAL: &str = "balance.pairs_total";
    /// The largest single reduce task's output record count — the
    /// numerator of the reduce-pair skew ratio the strategies flatten.
    pub const PAIRS_MAX_TASK: &str = "balance.pairs_max_task";
    /// Blocks (key runs) BlockSplit cut across ≥ 2 reduce tasks.
    pub const BLOCKS_SPLIT: &str = "balance.blocks_split";
}

/// An intermediate value carrying its entity's global `(key, id)` rank —
/// what lets balanced reduce tasks reason about window adjacency and pair
/// indices without any global state.
#[derive(Debug, Clone)]
pub struct Ranked {
    pub rank: u64,
    pub entity: Arc<Entity>,
}

impl SizeEstimate for Ranked {
    fn size_bytes(&self) -> usize {
        8 + self.entity.size_bytes()
    }
}

/// Number of SN window pairs whose *later* element has global rank `< j`:
/// `Σ_{t<j} min(t, w−1)`, closed form.  `cum_pairs(n, w)` is the total
/// pair count ([`total_pairs`]) and matches
/// [`expected_pair_count`](crate::sn::window::expected_pair_count).
pub fn cum_pairs(j: u64, w: usize) -> u64 {
    let w1 = (w.max(2) - 1) as u64;
    if j <= w1 {
        j * j.saturating_sub(1) / 2
    } else {
        w1 * (w1 - 1) / 2 + (j - w1) * w1
    }
}

/// Total SN window pairs over `n` rank-ordered entities.
pub fn total_pairs(n: u64, w: usize) -> u64 {
    cum_pairs(n, w)
}

/// Window pairs whose later element's rank lies in `[a, b)` — the reduce
/// cost of a contiguous rank segment under RepSN semantics (the later
/// element's reducer produces the pair).
pub fn segment_pairs(a: u64, b: u64, w: usize) -> u64 {
    cum_pairs(b, w) - cum_pairs(a, w)
}

/// Global index of pair `(i, j)` (`i < j`, `j − i < w`): pairs are
/// ordered by later element, then by decreasing earlier element.
pub fn pair_index(i: u64, j: u64, w: usize) -> u64 {
    debug_assert!(i < j && j - i < w.max(2) as u64);
    cum_pairs(j, w) + (j - 1 - i)
}

/// Reduce-side pair skew of a finished job: `(max per-task output
/// records, total)`.  In SN blocking mode output records are window
/// pairs, so `max / (total / tasks)` is the skew ratio the balanced
/// strategies flatten; apply it to an unbalanced RepSN job's
/// [`JobStats`] for the baseline.
pub fn reduce_pair_skew(stats: &JobStats) -> (u64, u64) {
    let max = stats
        .reduce_task_output_records
        .iter()
        .copied()
        .max()
        .unwrap_or(0);
    let total = stats.reduce_task_output_records.iter().sum();
    (max, total)
}

/// Run the two-job balanced pipeline on `exec`: BDM analysis, then the
/// repartition job of `cfg.balance`.  The partitioner on `cfg` only
/// contributes its partition count (the reduce-task target `r`); routing
/// is computed from the BDM.  Result shape matches the other SN variants:
/// two `stats`/`profiles` entries (analysis + repartition, like JobSN's
/// two jobs), merged counters, and a pair set identical to unbalanced
/// RepSN.
pub fn run_balanced(
    entities: &[Entity],
    cfg: &SnConfig,
    exec: Exec<'_>,
) -> anyhow::Result<SnResult> {
    if cfg.balance == BalanceStrategy::None {
        return crate::sn::repsn::run_on(entities, cfg, exec);
    }
    if !check_viable(entities.len(), cfg)? {
        return Ok(empty_result());
    }
    // one id-sort + deep copy for the whole pipeline; the second job gets
    // shallow Arc clones of the same records
    let input = bdm::partitioned_input(entities, cfg.num_map_tasks.max(1));
    run_pipeline(input, cfg, exec)
}

/// The pipeline's viability guards, shared by [`run_balanced`] and
/// [`submit`] so they cannot drift: `Ok(true)` = run it, `Ok(false)` =
/// the result is trivially empty, `Err` = unusable config.
fn check_viable(n_entities: usize, cfg: &SnConfig) -> anyhow::Result<bool> {
    anyhow::ensure!(cfg.window >= 2, "SN window must be ≥ 2");
    anyhow::ensure!(
        n_entities < u32::MAX as usize,
        "corpus too large for the u32 rank tags"
    );
    Ok(n_entities >= 2)
}

fn empty_result() -> SnResult {
    SnResult {
        pairs: Vec::new(),
        matches: Vec::new(),
        counters: Arc::new(Counters::new()),
        stats: Vec::new(),
        profiles: Vec::new(),
    }
}

/// The two jobs themselves, over a prebuilt
/// [`partitioned_input`](bdm::partitioned_input) (guards already checked).
fn run_pipeline(
    input: Vec<(u32, Arc<Entity>)>,
    cfg: &SnConfig,
    exec: Exec<'_>,
) -> anyhow::Result<SnResult> {
    let m = cfg.num_map_tasks.max(1);
    let r = cfg.partitioner.num_partitions().max(1);

    // ---- job 1: BDM analysis ---------------------------------------------
    let analysis = bdm::bdm_job(
        input.clone(),
        &cfg.blocking_key,
        m,
        cfg.workers,
        cfg.sort_buffer_records,
        cfg.spill.as_ref().map(crate::sn::codec::bdm_job_spec),
        cfg.push,
        cfg.faults.clone(),
        cfg.max_task_retries,
        cfg.trace.clone(),
        cfg.memory.clone(),
        exec,
    );
    let matrix = Arc::new(analysis.bdm);
    let counters = Arc::new(Counters::new());
    counters.merge(&analysis.counters);

    // ---- job 2: balanced repartition -------------------------------------
    let res = match cfg.balance {
        BalanceStrategy::BlockSplit => {
            let plan = Arc::new(blocksplit::plan(&matrix, r, cfg.window));
            counters.add(counter_names::BLOCKS_SPLIT, plan.blocks_split);
            blocksplit::run_job(input, cfg, matrix, plan, exec)
        }
        BalanceStrategy::PairRange => {
            let plan = Arc::new(pairrange::plan(matrix.num_entities(), r, cfg.window));
            pairrange::run_job(input, cfg, matrix, plan, exec)
        }
        BalanceStrategy::None => unreachable!(),
    };
    let (pairs, matches, boundaries) = crate::sn::srp::split_output(&res);
    debug_assert!(boundaries.is_empty());
    let profile = JobProfile::from_stats(
        &res.stats,
        res.counters
            .get(crate::mapreduce::counters::names::MAP_OUTPUT_BYTES),
    );
    counters.merge(&res.counters);
    let (max_task, total) = reduce_pair_skew(&res.stats);
    counters.add(counter_names::PAIRS_TOTAL, total);
    counters.add(counter_names::PAIRS_MAX_TASK, max_task);
    Ok(SnResult {
        pairs,
        matches,
        counters,
        stats: vec![analysis.stats, res.stats.clone()],
        profiles: vec![analysis.profile, profile],
    })
}

/// A balanced pipeline submitted to a shared scheduler;
/// [`PendingBalanced::join`] blocks for the result.
pub struct PendingBalanced {
    handle: std::thread::JoinHandle<anyhow::Result<SnResult>>,
}

impl PendingBalanced {
    pub fn join(self) -> anyhow::Result<SnResult> {
        match self.handle.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

/// Submit the two-job balanced pipeline to a shared [`JobScheduler`] and
/// return immediately.  A driver thread chains the BDM job and the
/// repartition job (a DAG edge, like JobSN's phase 1 → phase 2) while
/// both jobs' tasks interleave with every other submitted job's on the
/// scheduler's slots — this is how `multipass` runs balanced per-key
/// passes concurrently.
pub fn submit(entities: &[Entity], cfg: &SnConfig, sched: &JobScheduler) -> PendingBalanced {
    let cfg = cfg.clone();
    let sched = sched.clone();
    let work: Box<dyn FnOnce() -> anyhow::Result<SnResult> + Send> =
        if cfg.balance == BalanceStrategy::None {
            // direct callers with no strategy get run_balanced's RepSN
            // delegation, which needs the corpus itself (repsn::submit
            // never routes this case here)
            let entities = entities.to_vec();
            Box::new(move || run_balanced(&entities, &cfg, Exec::Scheduler(&sched)))
        } else {
            match check_viable(entities.len(), &cfg) {
                Err(e) => Box::new(move || Err(e)),
                Ok(false) => Box::new(move || Ok(empty_result())),
                // common case: ship the partition-tagged input (shallow
                // Arc clones after the one deep copy) to the driver thread
                Ok(true) => {
                    let input = bdm::partitioned_input(entities, cfg.num_map_tasks.max(1));
                    Box::new(move || run_pipeline(input, &cfg, Exec::Scheduler(&sched)))
                }
            }
        };
    let handle = std::thread::Builder::new()
        .name("snmr-balance".into())
        .spawn(work)
        .expect("spawn balance driver");
    PendingBalanced { handle }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sn::window::expected_pair_count;

    #[test]
    fn cum_pairs_matches_window_formula() {
        for (n, w) in [(0u64, 3usize), (1, 3), (5, 2), (9, 3), (100, 10), (50, 60)] {
            assert_eq!(
                total_pairs(n, w),
                expected_pair_count(n as usize, w) as u64,
                "n={n} w={w}"
            );
        }
    }

    #[test]
    fn segment_pairs_tile_the_total() {
        let (n, w) = (137u64, 7usize);
        let cuts = [0u64, 20, 55, 90, 137];
        let sum: u64 = cuts.windows(2).map(|c| segment_pairs(c[0], c[1], w)).sum();
        assert_eq!(sum, total_pairs(n, w));
    }

    #[test]
    fn pair_index_enumerates_segments_consistently() {
        // indices of pairs with later element in [a, b) fill
        // [cum(a), cum(b)) exactly
        let w = 4usize;
        for (a, b) in [(0u64, 10u64), (10, 25), (3, 7)] {
            let mut idxs: Vec<u64> = Vec::new();
            for j in a.max(1)..b {
                for i in j.saturating_sub(w as u64 - 1)..j {
                    idxs.push(pair_index(i, j, w));
                }
            }
            idxs.sort_unstable();
            let expect: Vec<u64> = (cum_pairs(a.max(1), w)..cum_pairs(b, w)).collect();
            assert_eq!(idxs, expect, "a={a} b={b}");
        }
    }

    #[test]
    fn strategy_parse_round_trips() {
        for s in [
            BalanceStrategy::None,
            BalanceStrategy::BlockSplit,
            BalanceStrategy::PairRange,
        ] {
            assert_eq!(BalanceStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(BalanceStrategy::parse("nope"), None);
    }
}
