//! The Block Distribution Matrix (BDM) analysis job.
//!
//! Kolb, Thor & Rahm's load-balancing strategies (arXiv:1108.1631) start
//! with a lightweight MapReduce **analysis job** that counts, for every
//! blocking key (= block) and every **map input partition**, how many
//! entities fall into that cell.  The resulting |B| × m matrix is enough
//! to (a) compute every block's size and pair count, and (b) assign each
//! entity a **global rank** in the `(blocking key, id)` sort order from
//! purely local information: the mapper of the *second* job knows its
//! input partition `p` and counts how many same-key entities it has seen
//! locally, and the BDM supplies the rank offset of cell `(key, p)`.
//!
//! The rank arithmetic relies on one input invariant, established by
//! [`partitioned_input`]: the job input is sorted by entity id and cut
//! into `m` contiguous chunks (the same [`even_splits`] arithmetic the
//! engine's split step uses, so chunk `p` *is* map task `p`'s split).
//! Then, inside one key run, every entity of chunk `p` has a smaller id
//! than every entity of chunk `p+1`, and `rank = key_start + cell_offset
//! + local_index` reproduces the `(key, id)` order exactly — which is why
//! the balanced repartitioners emit the very same pair set as unbalanced
//! RepSN (`tests/prop_balance.rs` asserts it).
//!
//! The job itself reuses the [`key_histogram_job`] pattern: map emits one
//! `((key, partition), 1)` per entity, a map-side combiner collapses each
//! task's records to one per distinct cell before the shuffle, and a
//! single reduce task emits the cell-sorted matrix.
//!
//! [`key_histogram_job`]: crate::sn::balance::key_histogram_job
//! [`even_splits`]: crate::mapreduce::splits::even_splits

use std::sync::Arc;

use crate::er::blockkey::BlockingKey;
use crate::er::entity::Entity;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::JobStats;
use crate::mapreduce::scheduler::Exec;
use crate::mapreduce::sim::JobProfile;
use crate::mapreduce::splits::even_splits;
use crate::mapreduce::types::{Emitter, FnMapTask, FnReduceTask, HashPartitioner, ValuesIter};
use crate::mapreduce::{FnCombiner, JobConfig};

/// One cell of the matrix: `count` entities of `key` in input partition
/// `part`, whose key run starts at global rank `start`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BdmCell {
    pub key_idx: usize,
    pub part: u32,
    /// Global rank of the first entity of this cell.
    pub start: u64,
    pub count: u64,
}

/// The Block Distribution Matrix: entity counts per
/// (blocking key × map input partition), with the prefix sums that turn a
/// `(key, partition, local index)` triple into a global `(key, id)` rank.
#[derive(Debug, Clone)]
pub struct Bdm {
    m: usize,
    /// Distinct blocking keys, sorted ascending.
    keys: Vec<String>,
    /// `key_starts[k]` = global rank of the first entity of key `k`;
    /// `key_starts[K]` = total entity count.
    key_starts: Vec<u64>,
    /// `cell_starts[k]` has length `m + 1`: prefix sums of key `k`'s
    /// per-partition counts (cell `(k, p)` holds ranks
    /// `key_starts[k] + cell_starts[k][p] .. key_starts[k] + cell_starts[k][p+1]`).
    cell_starts: Vec<Vec<u64>>,
}

impl Bdm {
    /// Build from the analysis job's reduce output: `((key, part), count)`
    /// rows sorted by `(key, part)` (a single reducer emits them sorted).
    pub fn from_rows(rows: Vec<((String, u32), u64)>, m: usize) -> Self {
        let m = m.max(1);
        let mut keys: Vec<String> = Vec::new();
        let mut per_key_counts: Vec<Vec<u64>> = Vec::new();
        for ((key, part), count) in rows {
            if keys.last().map(|k| k != &key).unwrap_or(true) {
                keys.push(key);
                per_key_counts.push(vec![0; m]);
            }
            let row = per_key_counts.last_mut().unwrap();
            row[part as usize] += count;
        }
        let mut key_starts = Vec::with_capacity(keys.len() + 1);
        let mut cell_starts = Vec::with_capacity(keys.len());
        let mut rank = 0u64;
        for counts in &per_key_counts {
            key_starts.push(rank);
            let mut prefix = Vec::with_capacity(m + 1);
            let mut off = 0u64;
            prefix.push(0);
            for &c in counts {
                off += c;
                prefix.push(off);
            }
            cell_starts.push(prefix);
            rank += off;
        }
        key_starts.push(rank);
        Self {
            m,
            keys,
            key_starts,
            cell_starts,
        }
    }

    /// Driver-side reference constructor (no MapReduce job): the matrix
    /// [`bdm_job`] computes, built directly.  Shared statistics source for
    /// [`VirtualPartition::split_hot`](crate::sn::balance::VirtualPartition)
    /// and the property tests that pin the job to it.
    pub fn from_entities(entities: &[Entity], key_fn: &dyn BlockingKey, m: usize) -> Self {
        let mut cells: std::collections::BTreeMap<(String, u32), u64> = Default::default();
        for (part, e) in partition_assignment(entities, m) {
            *cells.entry((key_fn.key(e), part)).or_insert(0) += 1;
        }
        Self::from_rows(cells.into_iter().collect(), m)
    }

    pub fn num_partitions(&self) -> usize {
        self.m
    }

    pub fn num_keys(&self) -> usize {
        self.keys.len()
    }

    pub fn num_entities(&self) -> u64 {
        *self.key_starts.last().unwrap_or(&0)
    }

    /// Global `(key, id)` rank of the `local_idx`-th entity (in id order)
    /// of `key` within input partition `part`.  Panics if the key is
    /// unknown — the analysis job and the balanced job must run over the
    /// same corpus and key function.
    pub fn rank(&self, key: &str, part: u32, local_idx: u64) -> u64 {
        let k = self
            .keys
            .binary_search_by(|probe| probe.as_str().cmp(key))
            .unwrap_or_else(|_| panic!("key {key:?} not in the BDM"));
        let cell = &self.cell_starts[k];
        debug_assert!(local_idx < cell[part as usize + 1] - cell[part as usize]);
        self.key_starts[k] + cell[part as usize] + local_idx
    }

    /// Global rank range `[start, end)` of one key's run.
    pub fn key_run(&self, key_idx: usize) -> (u64, u64) {
        (self.key_starts[key_idx], self.key_starts[key_idx + 1])
    }

    /// Index of the key whose run contains global rank `rank`.
    pub fn key_of_rank(&self, rank: u64) -> usize {
        debug_assert!(rank < self.num_entities());
        self.key_starts[1..].partition_point(|&s| s <= rank)
    }

    pub fn key(&self, key_idx: usize) -> &str {
        &self.keys[key_idx]
    }

    /// Non-empty cells in global rank order (key-major, partition-minor):
    /// the candidate split granularity of BlockSplit — a block can be cut
    /// at any cell boundary, never inside one.
    pub fn cells(&self) -> Vec<BdmCell> {
        let mut out = Vec::new();
        for (k, prefix) in self.cell_starts.iter().enumerate() {
            for p in 0..self.m {
                let count = prefix[p + 1] - prefix[p];
                if count > 0 {
                    out.push(BdmCell {
                        key_idx: k,
                        part: p as u32,
                        start: self.key_starts[k] + prefix[p],
                        count,
                    });
                }
            }
        }
        out
    }

    /// Collapse the partition dimension: the blocking-key histogram
    /// (`(key, block size)` in key order), as
    /// [`key_histogram_job`](crate::sn::balance::key_histogram_job)
    /// computes it.
    pub fn key_histogram(&self) -> Vec<(String, u64)> {
        self.keys
            .iter()
            .enumerate()
            .map(|(k, key)| (key.clone(), self.key_starts[k + 1] - self.key_starts[k]))
            .collect()
    }
}

/// Assign each entity its map input partition: sort by id, cut into `m`
/// contiguous chunks with the engine's own [`even_splits`] arithmetic.
/// Both the analysis job and the balanced job feed their input through
/// this, which is what makes the rank invariant (module docs) hold.
fn partition_assignment(entities: &[Entity], m: usize) -> Vec<(u32, &Entity)> {
    let mut by_id: Vec<&Entity> = entities.iter().collect();
    by_id.sort_by_key(|e| e.id);
    let mut out = Vec::with_capacity(by_id.len());
    for (p, (start, end)) in even_splits(by_id.len(), m.max(1)).into_iter().enumerate() {
        for e in &by_id[start..end] {
            out.push((p as u32, *e));
        }
    }
    out
}

/// The id-sorted, partition-tagged job input shared by the analysis job
/// and both balanced repartition jobs.  The record key is the input
/// partition index; with `num_map_tasks = m` the engine's contiguous
/// splits coincide with the tagged chunks, so one map task sees exactly
/// one partition's records, in id order.  Built **once** per balanced
/// pipeline — the second job reuses it with shallow `Arc` clones.
pub fn partitioned_input(entities: &[Entity], m: usize) -> Vec<(u32, Arc<Entity>)> {
    partition_assignment(entities, m)
        .into_iter()
        .map(|(p, e)| (p, Arc::new(e.clone())))
        .collect()
}

/// The mapper-local half of the BDM rank derivation: counts same-key
/// entities seen so far and combines the local index with the matrix
/// offsets.  This is the single implementation both repartition mappers
/// route through, so their rank assignments can never diverge.
///
/// Counts are keyed by blocking key alone: one map task sees exactly one
/// input partition (the engine's contiguous splits coincide with the
/// [`partitioned_input`] tags by construction), asserted in debug builds.
#[derive(Default)]
pub struct RankTracker {
    part: Option<u32>,
    seen: std::collections::HashMap<String, u64>,
}

impl RankTracker {
    /// Global `(key, id)` rank of the next `key`-keyed entity of input
    /// partition `part` (records must arrive in id order, which
    /// [`partitioned_input`] + the engine's contiguous splits guarantee).
    pub fn rank(&mut self, bdm: &Bdm, key: &str, part: u32) -> u64 {
        debug_assert_eq!(
            *self.part.get_or_insert(part),
            part,
            "one map task must see exactly one input partition"
        );
        // allocate the key String only on first sighting
        if !self.seen.contains_key(key) {
            self.seen.insert(key.to_string(), 0);
        }
        let local = self.seen.get_mut(key).unwrap();
        let rank = bdm.rank(key, part, *local);
        *local += 1;
        rank
    }

    /// Forget all counts (map-task `configure`).
    pub fn reset(&mut self) {
        self.part = None;
        self.seen.clear();
    }
}

/// Everything the analysis job produces: the matrix plus the job's
/// observability (merged into the balanced run's [`SnResult`]).
///
/// [`SnResult`]: crate::sn::types::SnResult
pub struct BdmJobResult {
    pub bdm: Bdm,
    pub counters: Arc<Counters>,
    pub stats: JobStats,
    pub profile: JobProfile,
}

/// Compute the BDM as a MapReduce job with a map-side combiner: map emits
/// `((key, partition), 1)` per entity, the combiner pre-sums each sorted
/// run (one record per distinct cell per task reaches the shuffle), and a
/// single reduce task emits the cell-sorted matrix.  `input` is the
/// [`partitioned_input`] the repartition job will reuse.  `spill`
/// (usually [`crate::sn::codec::bdm_job_spec`] via
/// [`SnConfig::spill`](crate::sn::types::SnConfig)) routes even this
/// analysis job's combined cell counts through disk-backed runs.
#[allow(clippy::too_many_arguments)]
pub fn bdm_job(
    input: Vec<(u32, Arc<Entity>)>,
    key_fn: &Arc<dyn BlockingKey>,
    m: usize,
    workers: usize,
    sort_buffer_records: Option<usize>,
    spill: Option<crate::mapreduce::sortspill::SpillSpec>,
    push: bool,
    faults: Option<crate::mapreduce::fault::FaultPlan>,
    max_task_retries: Option<u32>,
    trace: Option<crate::mapreduce::trace::TraceSpec>,
    memory: Option<crate::mapreduce::memory::MemoryPool>,
    exec: Exec<'_>,
) -> BdmJobResult {
    let m = m.max(1);
    let bk = Arc::clone(key_fn);
    let mapper = Arc::new(FnMapTask::new(
        move |part: u32, e: Arc<Entity>, out: &mut Emitter<(String, u32), u64>, _c: &Counters| {
            out.emit((bk.key(&e), part), 1);
        },
    ));
    let reducer = Arc::new(FnReduceTask::new(
        |k: &(String, u32),
         vals: ValuesIter<'_, u64>,
         out: &mut Emitter<(String, u32), u64>,
         _c: &Counters| {
            out.emit(k.clone(), vals.copied().sum());
        },
    ));
    let cfg = JobConfig::named("bdm")
        .with_tasks(m, 1)
        .with_workers(workers.max(1))
        .with_sort_buffer(sort_buffer_records)
        .with_spill(spill)
        .with_push(push)
        .with_faults(faults)
        .with_retries(max_task_retries)
        .with_trace(trace)
        .with_memory(memory);
    let res = exec.run_job_with_combiner(
        &cfg,
        input,
        mapper,
        Arc::new(HashPartitioner::new(|_: &(String, u32)| 0)),
        Arc::new(|a: &(String, u32), b: &(String, u32)| a == b),
        reducer,
        Arc::new(FnCombiner::new(
            |_k: &(String, u32), vals: Vec<u64>, _c: &Counters| vec![vals.into_iter().sum()],
        )),
    );
    let counters = Arc::clone(&res.counters);
    let stats = res.stats.clone();
    let profile = JobProfile::from_stats(
        &stats,
        counters.get(crate::mapreduce::counters::names::MAP_OUTPUT_BYTES),
    );
    let bdm = Bdm::from_rows(res.merged_output(), m);
    BdmJobResult {
        bdm,
        counters,
        stats,
        profile,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::TitlePrefixKey;

    fn entities(n: usize) -> Vec<Entity> {
        (0..n as u64)
            .map(|i| {
                let c = (b'a' + (i % 7) as u8) as char;
                Entity::new(i, &format!("{c}{c} title {i}"), "")
            })
            .collect()
    }

    #[test]
    fn job_matches_driver_side_matrix() {
        let es = entities(200);
        let bk: Arc<dyn BlockingKey> = Arc::new(TitlePrefixKey::new(2));
        let job = bdm_job(
            partitioned_input(&es, 4),
            &bk,
            4,
            2,
            None,
            None,
            false,
            None,
            None,
            None,
            Exec::Serial,
        );
        let reference = Bdm::from_entities(&es, bk.as_ref(), 4);
        assert_eq!(job.bdm.keys, reference.keys);
        assert_eq!(job.bdm.key_starts, reference.key_starts);
        assert_eq!(job.bdm.cell_starts, reference.cell_starts);
        // combiner collapsed per-task records to one per distinct cell
        use crate::mapreduce::counters::names;
        assert_eq!(job.counters.get(names::COMBINE_INPUT_RECORDS), 200);
        assert!(
            job.counters.get(names::COMBINE_OUTPUT_RECORDS)
                < job.counters.get(names::COMBINE_INPUT_RECORDS)
        );
    }

    #[test]
    fn ranks_reproduce_key_id_order() {
        // shuffle ids so input order ≠ id order
        let mut es = entities(150);
        es.reverse();
        let bk = TitlePrefixKey::new(2);
        let m = 3;
        let bdm = Bdm::from_entities(&es, &bk, m);
        // recompute each entity's (part, local) the way a mapper would
        let mut local: std::collections::HashMap<(u32, String), u64> = Default::default();
        let mut ranked: Vec<(u64, String, u64)> = Vec::new(); // (rank, key, id)
        for (part, e) in partition_assignment(&es, m) {
            let k = bk.key(e);
            let l = local.entry((part, k.clone())).or_insert(0);
            ranked.push((bdm.rank(&k, part, *l), k, e.id));
            *l += 1;
        }
        ranked.sort();
        // ranks are 0..n and ordered exactly like (key, id)
        let mut sorted: Vec<(String, u64)> =
            es.iter().map(|e| (bk.key(e), e.id)).collect();
        sorted.sort();
        assert_eq!(ranked.len(), sorted.len());
        for (i, ((rank, key, id), (sk, sid))) in ranked.iter().zip(&sorted).enumerate() {
            assert_eq!(*rank, i as u64, "ranks must be dense");
            assert_eq!((key, id), (sk, sid), "rank order must be (key, id) order");
        }
    }

    #[test]
    fn histogram_collapses_partitions() {
        let es = entities(90);
        let bk = TitlePrefixKey::new(2);
        let bdm = Bdm::from_entities(&es, &bk, 5);
        let mut expect: std::collections::BTreeMap<String, u64> = Default::default();
        for e in &es {
            *expect.entry(bk.key(e)).or_insert(0) += 1;
        }
        assert_eq!(
            bdm.key_histogram(),
            expect.into_iter().collect::<Vec<_>>()
        );
        assert_eq!(bdm.num_entities(), 90);
    }

    #[test]
    fn cells_are_rank_ordered_and_cover() {
        let es = entities(77);
        let bdm = Bdm::from_entities(&es, &TitlePrefixKey::new(2), 4);
        let cells = bdm.cells();
        let mut next = 0u64;
        for c in &cells {
            assert_eq!(c.start, next, "cells must tile the rank space");
            next = c.start + c.count;
        }
        assert_eq!(next, 77);
    }
}
