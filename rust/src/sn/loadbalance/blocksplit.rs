//! BlockSplit: split oversized blocks at BDM cell boundaries and assign
//! the resulting sub-blocks to reduce tasks by pair count.
//!
//! The strategy of arXiv:1108.1631 §4.1, adapted to Sorted Neighborhood:
//! the unit of work is a contiguous range of the global `(key, id)` sort
//! order, and the cost of a range is its **window pair count** (the SN
//! analogue of the paper's `|b|·(|b|−1)/2` block cost — see
//! [`segment_pairs`](super::segment_pairs)).  Planning walks the BDM's
//! cells — `(blocking key × input partition)` sub-blocks, the paper's
//! split granularity — in rank order and greedily closes a reduce task
//! when it has accumulated its fair share of the remaining pair cost.  An
//! oversized block (hot key run) is thereby *split across reduce tasks at
//! sub-block boundaries*, which no monotone key-range partitioner can do:
//! the cut happens mid-run, between ids.  Small blocks stay unsplit and
//! ride along whole.
//!
//! Execution is a single RepSN-shaped job.  The mapper derives each
//! entity's global rank from the BDM ([`Bdm::rank`]), routes it to
//! `task_of(rank)` (the composite key's `bound` — split and unsplit
//! blocks alike become normal reduce groups), and replicates its `w−1`
//! highest-ranked entities per task to the succeeding task exactly like
//! RepSN's map does per partition.  Every reduce task therefore receives
//! a contiguous rank range plus the `w−1` ranks before it, seeds the
//! window with those replicas and slides over the originals — emitting
//! precisely the SN pairs whose *later* element lives in its range.  The
//! union over tasks is the exact unbalanced-RepSN pair set
//! (`tests/prop_balance.rs`), with the per-task maximum flattened to
//! ≈ `pairs_total / r`.
//!
//! Every cut keeps at least `w−1` entities on both sides, the same
//! minimum-partition-size assumption classic RepSN's one-step boundary
//! replication already relies on.

use std::collections::BinaryHeap;
use std::sync::Arc;

use super::bdm::Bdm;
use super::{segment_pairs, total_pairs, Ranked};
use crate::er::blockkey::BlockingKey;
use crate::er::entity::Entity;
use crate::mapreduce::counters::Counters;
use crate::mapreduce::engine::JobResult;
use crate::mapreduce::scheduler::Exec;
use crate::mapreduce::types::{
    Emitter, MapTask, MapTaskFactory, ReduceTask, ReduceTaskFactory, ValuesIter,
};
use crate::mapreduce::JobConfig;
use crate::sn::pairs::WindowProc;
use crate::sn::srp::{group_by_bound, BoundPartitioner};
use crate::sn::types::{counter_names, SnConfig, SnKey, SnMode, SnVal};

/// A BlockSplit repartitioning plan: reduce-task start ranks chosen at
/// BDM cell boundaries so per-task pair counts are near-equal.
#[derive(Debug, Clone)]
pub struct BlockSplitPlan {
    /// Start rank of each reduce task; `starts[0] == 0`, strictly
    /// increasing, every task spans ≥ `w−1` entities.
    starts: Vec<u64>,
    n: u64,
    /// Number of blocks (key runs) cut across two or more reduce tasks.
    pub blocks_split: u64,
    /// Cost-model prediction of each task's pair count; in blocking mode
    /// the measured per-task output matches this exactly.
    pub expected_pairs: Vec<u64>,
}

impl BlockSplitPlan {
    pub fn num_tasks(&self) -> usize {
        self.starts.len()
    }

    /// Which reduce task owns global rank `rank`.
    pub fn task_of(&self, rank: u64) -> usize {
        self.starts[1..].partition_point(|&s| s <= rank)
    }

    /// First rank of task `t`.
    pub fn start(&self, t: usize) -> u64 {
        self.starts[t]
    }

    /// One-past-last rank of task `t`.
    pub fn end(&self, t: usize) -> u64 {
        self.starts.get(t + 1).copied().unwrap_or(self.n)
    }
}

/// Choose up to `r` reduce tasks from the BDM: walk cells in rank order,
/// close the current task when adding the next cell would overshoot its
/// fair share of the *remaining* pair cost (the same adaptive rule as
/// [`pair_balanced`](crate::sn::balance::pair_balanced), at sub-block
/// instead of whole-block granularity).
pub fn plan(bdm: &Bdm, r: usize, w: usize) -> BlockSplitPlan {
    let n = bdm.num_entities();
    let w = w.max(2);
    let min_size = (w - 1) as u64;
    let total = total_pairs(n, w);
    let mut starts = vec![0u64];
    let mut parts_left = r.max(1);
    let mut remaining = total as f64;
    let mut seg_start = 0u64;
    for cell in bdm.cells() {
        let b = cell.start;
        if parts_left <= 1 || b == seg_start {
            continue;
        }
        // a cut is feasible only if both sides keep ≥ w−1 entities (the
        // RepSN replication-stitching assumption) and every later task
        // can still be that large
        if b - seg_start < min_size || n - b < min_size * (parts_left as u64 - 1) {
            continue;
        }
        let acc = segment_pairs(seg_start, b, w) as f64;
        let next = segment_pairs(b, b + cell.count, w) as f64;
        let target = remaining / parts_left as f64;
        if acc + next / 2.0 >= target {
            starts.push(b);
            parts_left -= 1;
            remaining -= acc;
            seg_start = b;
        }
    }
    // which key runs did the cuts land inside?
    let mut split_keys: Vec<usize> = starts[1..]
        .iter()
        .filter_map(|&cut| {
            let k = bdm.key_of_rank(cut);
            (bdm.key_run(k).0 < cut).then_some(k)
        })
        .collect();
    split_keys.dedup();
    let expected_pairs = (0..starts.len())
        .map(|t| {
            let end = starts.get(t + 1).copied().unwrap_or(n);
            segment_pairs(starts[t], end, w)
        })
        .collect();
    BlockSplitPlan {
        starts,
        n,
        blocks_split: split_keys.len() as u64,
        expected_pairs,
    }
}

/// Min-heap entry for the per-task replication buffers (RepSN's
/// replace-min policy, keyed by global rank instead of `(key, id)`).
struct RepRank {
    rank: u64,
    key: String,
    id: u64,
    entity: Arc<Entity>,
}

impl PartialEq for RepRank {
    fn eq(&self, other: &Self) -> bool {
        self.rank == other.rank
    }
}
impl Eq for RepRank {}

impl Ord for RepRank {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed → BinaryHeap pops the smallest rank first
        other.rank.cmp(&self.rank)
    }
}

impl PartialOrd for RepRank {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The BlockSplit map task: rank-derive, route, replicate.
struct BlockSplitMap {
    w: usize,
    bdm: Arc<Bdm>,
    plan: Arc<BlockSplitPlan>,
    blocking_key: Arc<dyn BlockingKey>,
    ranks: super::bdm::RankTracker,
    /// `rep[t]`: candidates for replication to reduce task `t + 1`.
    rep: Vec<BinaryHeap<RepRank>>,
}

impl MapTask<u32, Arc<Entity>, SnKey, Ranked> for BlockSplitMap {
    fn configure(&mut self, _out: &mut Emitter<SnKey, Ranked>, _c: &Counters) {
        let tasks = self.plan.num_tasks();
        self.rep = (0..tasks.saturating_sub(1)).map(|_| BinaryHeap::new()).collect();
        self.ranks.reset();
    }

    fn map(&mut self, part: u32, e: Arc<Entity>, out: &mut Emitter<SnKey, Ranked>, _c: &Counters) {
        let k = self.blocking_key.key(&e);
        let rank = self.ranks.rank(&self.bdm, &k, part);
        let bound = self.plan.task_of(rank);
        if bound + 1 < self.plan.num_tasks() && self.w >= 2 {
            let heap = &mut self.rep[bound];
            if heap.len() < self.w - 1 {
                heap.push(RepRank {
                    rank,
                    key: k.clone(),
                    id: e.id,
                    entity: Arc::clone(&e),
                });
            } else if let Some(min) = heap.peek() {
                if rank > min.rank {
                    heap.pop();
                    heap.push(RepRank {
                        rank,
                        key: k.clone(),
                        id: e.id,
                        entity: Arc::clone(&e),
                    });
                }
            }
        }
        out.emit(
            SnKey {
                bound: bound as u32,
                part: bound as u32,
                key: k,
                id: e.id,
            },
            Ranked { rank, entity: e },
        );
    }

    fn close(&mut self, out: &mut Emitter<SnKey, Ranked>, c: &Counters) {
        let mut replicated = 0u64;
        for (t, heap) in self.rep.drain(..).enumerate() {
            for entry in heap.into_vec() {
                out.emit(
                    SnKey {
                        bound: (t + 1) as u32,
                        part: t as u32,
                        key: entry.key,
                        id: entry.id,
                    },
                    Ranked {
                        rank: entry.rank,
                        entity: entry.entity,
                    },
                );
                replicated += 1;
            }
        }
        c.add(counter_names::REPLICATED_ENTITIES, replicated);
    }
}

struct BlockSplitMapFactory {
    w: usize,
    bdm: Arc<Bdm>,
    plan: Arc<BlockSplitPlan>,
    blocking_key: Arc<dyn BlockingKey>,
}

impl MapTaskFactory<u32, Arc<Entity>, SnKey, Ranked> for BlockSplitMapFactory {
    fn create_task(&self) -> Box<dyn MapTask<u32, Arc<Entity>, SnKey, Ranked> + Send> {
        Box::new(BlockSplitMap {
            w: self.w,
            bdm: Arc::clone(&self.bdm),
            plan: Arc::clone(&self.plan),
            blocking_key: Arc::clone(&self.blocking_key),
            ranks: Default::default(),
            rep: Vec::new(),
        })
    }
}

/// The BlockSplit reduce task: RepSN's seed-and-slide, classifying
/// replicas by rank (< the task's start rank) instead of by recomputed
/// home partition.
struct BlockSplitReduce {
    w: usize,
    mode: SnMode,
    plan: Arc<BlockSplitPlan>,
}

impl ReduceTask<SnKey, Ranked, SnKey, SnVal> for BlockSplitReduce {
    fn reduce(
        &mut self,
        key: &SnKey,
        values: ValuesIter<'_, Ranked>,
        out: &mut Emitter<SnKey, SnVal>,
        counters: &Counters,
    ) {
        let b = key.bound;
        let start = self.plan.start(b as usize);
        let keep = self.w.saturating_sub(1);
        let mut proc = WindowProc::new(self.w, &self.mode);
        let mut head: std::collections::VecDeque<Arc<Entity>> =
            std::collections::VecDeque::with_capacity(keep + 1);
        let mut discarded = 0u64;
        let mut seeded = false;
        for v in values {
            if v.rank < start {
                // replica from the preceding task (head of the input)
                debug_assert!(!seeded, "replica after originals violates sort order");
                head.push_back(Arc::clone(&v.entity));
                if head.len() > keep {
                    head.pop_front();
                    discarded += 1;
                }
            } else {
                if !seeded {
                    for rep in head.drain(..) {
                        proc.seed(&rep, b.wrapping_sub(1));
                    }
                    seeded = true;
                }
                proc.push(&v.entity, b, |_, _| true);
            }
        }
        counters.add(counter_names::REPLICAS_DISCARDED, discarded);
        proc.finish(key, out, counters);
    }
}

struct BlockSplitReduceFactory {
    w: usize,
    mode: SnMode,
    plan: Arc<BlockSplitPlan>,
}

impl ReduceTaskFactory<SnKey, Ranked, SnKey, SnVal> for BlockSplitReduceFactory {
    fn create_task(&self) -> Box<dyn ReduceTask<SnKey, Ranked, SnKey, SnVal> + Send> {
        Box::new(BlockSplitReduce {
            w: self.w,
            mode: self.mode.clone(),
            plan: Arc::clone(&self.plan),
        })
    }
}

/// Run the BlockSplit repartition job over the pipeline's shared
/// [`partitioned_input`](super::bdm::partitioned_input).
pub(super) fn run_job(
    input: Vec<(u32, Arc<Entity>)>,
    cfg: &SnConfig,
    bdm: Arc<Bdm>,
    plan: Arc<BlockSplitPlan>,
    exec: Exec<'_>,
) -> JobResult<SnKey, SnVal> {
    let m = cfg.num_map_tasks.max(1);
    let job_cfg = JobConfig::named("blocksplit")
        .with_tasks(m, plan.num_tasks())
        .with_workers(cfg.workers)
        .with_sort_buffer(cfg.sort_buffer_records)
        .with_spill(cfg.spill.as_ref().map(crate::sn::codec::ranked_job_spec))
        .with_push(cfg.push)
        .with_faults(cfg.faults.clone())
        .with_retries(cfg.max_task_retries)
        .with_trace(cfg.trace.clone())
        .with_memory(cfg.memory.clone());
    let mapper: Arc<dyn MapTaskFactory<u32, Arc<Entity>, SnKey, Ranked>> =
        Arc::new(BlockSplitMapFactory {
            w: cfg.window,
            bdm,
            plan: Arc::clone(&plan),
            blocking_key: Arc::clone(&cfg.blocking_key),
        });
    let reducer: Arc<dyn ReduceTaskFactory<SnKey, Ranked, SnKey, SnVal>> =
        Arc::new(BlockSplitReduceFactory {
            w: cfg.window,
            mode: cfg.mode.clone(),
            plan,
        });
    exec.run_job(
        &job_cfg,
        input,
        mapper,
        Arc::new(BoundPartitioner),
        group_by_bound(),
        reducer,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::er::blockkey::TitlePrefixKey;

    /// One hot key holding 60% of the corpus — unsplittable by any
    /// monotone key-range function, BlockSplit's home turf.
    fn hot_key_entities(n: usize) -> Vec<Entity> {
        (0..n as u64)
            .map(|i| {
                let k = if i % 10 < 6 {
                    "aa".to_string()
                } else {
                    format!("{}{}", (b'b' + (i % 13) as u8) as char, (b'a' + (i % 7) as u8) as char)
                };
                Entity::new(i, &format!("{k} title {i}"), "")
            })
            .collect()
    }

    #[test]
    fn plan_cuts_the_hot_block() {
        let es = hot_key_entities(1000);
        let bdm = Bdm::from_entities(&es, &TitlePrefixKey::new(2), 8);
        let w = 10;
        let p = plan(&bdm, 8, w);
        assert!(p.num_tasks() > 1);
        assert!(
            p.blocks_split >= 1,
            "the 600-entity hot block must be split: {p:?}"
        );
        // the plan's tasks tile [0, n) with ≥ w−1 entities each
        let mut prev = 0;
        for t in 0..p.num_tasks() {
            assert_eq!(p.start(t), prev);
            assert!(p.end(t) - p.start(t) >= (w - 1) as u64);
            prev = p.end(t);
        }
        assert_eq!(prev, 1000);
        // pair cost near-equal: max ≤ 2× mean
        let total: u64 = p.expected_pairs.iter().sum();
        let max = *p.expected_pairs.iter().max().unwrap();
        assert_eq!(total, total_pairs(1000, w));
        assert!(
            max as f64 <= 2.0 * total as f64 / p.num_tasks() as f64,
            "lumpy plan: {:?}",
            p.expected_pairs
        );
    }

    #[test]
    fn plan_respects_min_task_size() {
        // tiny corpus, huge window: fewer tasks than requested
        let es = hot_key_entities(20);
        let bdm = Bdm::from_entities(&es, &TitlePrefixKey::new(2), 4);
        let p = plan(&bdm, 8, 15);
        for t in 0..p.num_tasks() {
            assert!(p.end(t) - p.start(t) >= 14);
        }
    }

    #[test]
    fn task_of_matches_starts() {
        let es = hot_key_entities(500);
        let bdm = Bdm::from_entities(&es, &TitlePrefixKey::new(2), 4);
        let p = plan(&bdm, 6, 5);
        for rank in 0..500u64 {
            let t = p.task_of(rank);
            assert!(p.start(t) <= rank && rank < p.end(t));
        }
    }
}
