//! # snmr — Parallel Sorted Neighborhood Blocking with MapReduce
//!
//! A full reproduction of Kolb, Thor & Rahm, *"Parallel Sorted Neighborhood
//! Blocking with MapReduce"* (2010), as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: an
//!   in-process MapReduce runtime with Hadoop-0.20 semantics
//!   ([`mapreduce`]), the entity-resolution workflow of §3 ([`er`]), and
//!   the paper's three Sorted-Neighborhood parallelizations — SRP, JobSN
//!   and RepSN ([`sn`]) — plus baselines, partition functions and skew
//!   tooling.
//! * **Layer 2/1 (build-time Python)** — the pairwise matcher (edit
//!   distance on titles + trigram Dice on abstracts) as a JAX model over
//!   Pallas kernels, AOT-lowered to HLO text and executed from Rust via
//!   PJRT ([`runtime`]); Python never runs on the request path.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for the reproduced tables/figures.

pub mod data;
pub mod er;
pub mod mapreduce;
pub mod metrics;
pub mod runtime;
pub mod sn;
pub mod util;
