//! Deterministic PRNGs: SplitMix64 (seeding) and Xoshiro256** (bulk).
//!
//! Every stochastic component in the crate (corpus generation, duplicate
//! injection, property tests, skew shaping) threads one of these through
//! explicitly — there is no ambient/global randomness, which is what makes
//! `EXPERIMENTS.md` runs bit-reproducible.

/// SplitMix64: tiny, passes BigCrush, ideal for seeding and for places that
/// need a few independent streams derived from one seed.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-task determinism that
    /// does not depend on scheduling order).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; unbiased enough for
    /// simulation purposes and much faster than rejection).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Zipf-like rank sampler over `[0, n)` with exponent `s` using inverse
    /// CDF on a precomputed table-free approximation (rejection-inversion,
    /// Hörmann & Derflinger).  Used for realistic word-frequency skew.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        if n == 1 {
            return 0;
        }
        // simple inversion on the harmonic CDF approximation
        let nf = n as f64;
        if (s - 1.0).abs() < 1e-9 {
            let h = nf.ln();
            let u = self.f64() * h;
            return (u.exp() - 1.0).min(nf - 1.0).max(0.0) as usize;
        }
        let a = 1.0 - s;
        let h = (nf.powf(a) - 1.0) / a;
        let u = self.f64() * h;
        let x = (u * a + 1.0).powf(1.0 / a) - 1.0;
        (x.min(nf - 1.0).max(0.0)) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Gaussian via Box–Muller (one value, second discarded: simplicity
    /// over speed — only used in corpus shaping).
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_first_outputs() {
        // Reference values from the public-domain splitmix64.c
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn rng_deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[9] * 3, "{counts:?}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let overlap = (0..100)
            .filter(|_| a.next_u64() == b.next_u64())
            .count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(21);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.2, "var={var}");
    }
}
