//! Fixed-size worker pool over `std::thread` + channels.
//!
//! The MapReduce engine schedules map/reduce *tasks* onto a bounded number
//! of worker *slots* — exactly the Hadoop model the paper configures ("each
//! node was configured to run at most two map and reduce tasks in
//! parallel").  `tokio`/`rayon` are unavailable offline; a small explicit
//! pool is also easier to instrument with the per-slot busy-time metrics the
//! cluster simulator is calibrated from.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool.  Jobs are executed FIFO; `join` blocks until
/// all submitted jobs have completed.  Panics inside jobs are caught and
/// surfaced by `join`.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `size >= 1` workers.
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "pool needs at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("snmr-worker-{i}"))
                    .spawn(move || worker_loop(rx, pending, panics))
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            pending,
            panics,
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.  Returns the number of
    /// jobs that panicked since the last call (0 = all clean).
    pub fn join(&self) -> usize {
        let (lock, cvar) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
        self.panics.swap(0, Ordering::SeqCst)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
    panics: Arc<AtomicUsize>,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Err(_) => return, // sender dropped: shutdown
            Ok(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panics.fetch_add(1, Ordering::SeqCst);
                }
                let (lock, cvar) = &*pending;
                let mut n = lock.lock().unwrap();
                *n -= 1;
                if *n == 0 {
                    cvar.notify_all();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `tasks` (indexed closures) on `workers` threads and collect results
/// in task order.  Convenience wrapper used by the engine's phases.
pub fn run_indexed<T, F>(workers: usize, count: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..count).map(|_| None).collect()));
    let pool = ThreadPool::new(workers.max(1));
    for i in 0..count {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let r = f(i);
            results.lock().unwrap()[i] = Some(r);
        });
    }
    let panics = pool.join();
    assert_eq!(panics, 0, "{panics} task(s) panicked");
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("results still shared"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("task did not run"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(pool.join(), 0);
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn join_then_reuse() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&c);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            assert_eq!(pool.join(), 0);
            assert_eq!(c.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn panic_is_counted_not_fatal() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        assert_eq!(pool.join(), 1);
        // pool still usable
        pool.execute(|| {});
        assert_eq!(pool.join(), 0);
    }

    #[test]
    fn run_indexed_preserves_order() {
        let out = run_indexed(3, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_is_sequential_total_order() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let pool = ThreadPool::new(1);
        for i in 0..20 {
            let log = Arc::clone(&log);
            pool.execute(move || log.lock().unwrap().push(i));
        }
        pool.join();
        assert_eq!(*log.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }
}
