//! Fixed-size worker pool over `std::thread` + channels.
//!
//! The MapReduce engine schedules map/reduce *tasks* onto a bounded number
//! of worker *slots* — exactly the Hadoop model the paper configures ("each
//! node was configured to run at most two map and reduce tasks in
//! parallel").  `tokio`/`rayon` are unavailable offline; a small explicit
//! pool is also easier to instrument with the per-slot busy-time metrics the
//! cluster simulator is calibrated from.

use std::cell::UnsafeCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool.  Jobs are executed FIFO; `join` blocks until
/// all submitted jobs have completed.  Panics inside jobs are caught and
/// surfaced by `join`.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `size >= 1` workers.
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "pool needs at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("snmr-worker-{i}"))
                    .spawn(move || worker_loop(rx, pending, panics))
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            pending,
            panics,
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.  Returns the number of
    /// jobs that panicked since the last call (0 = all clean).
    pub fn join(&self) -> usize {
        let (lock, cvar) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
        self.panics.swap(0, Ordering::SeqCst)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Jobs submitted but not yet finished (queued + running).  The job
    /// scheduler's straggler detector uses `in_flight() < size()` as its
    /// "a slot is idle" test before cloning a slow task — speculation must
    /// never delay a primary task that is still waiting for a slot.
    pub fn in_flight(&self) -> usize {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap()
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
    panics: Arc<AtomicUsize>,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Err(_) => return, // sender dropped: shutdown
            Ok(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panics.fetch_add(1, Ordering::SeqCst);
                }
                let (lock, cvar) = &*pending;
                let mut n = lock.lock().unwrap();
                *n -= 1;
                if *n == 0 {
                    cvar.notify_all();
                }
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Atomic-index slot ownership: the task-input / task-result handoff
// ---------------------------------------------------------------------------

const SLOT_EMPTY: u8 = 0;
const SLOT_FULL: u8 = 1;
const SLOT_TAKEN: u8 = 2;
const SLOT_WRITING: u8 = 3;

/// A fixed-size vector of single-use slots with per-slot atomic ownership.
///
/// Each slot is filled exactly once (`put`) and emptied exactly once
/// (`take`); both transfer ownership through a per-slot atomic state
/// machine, so concurrent workers operating on *distinct* indices never
/// contend on a shared lock.  This replaces the engine's former
/// `Arc<Mutex<Vec<Option<T>>>>` scatter/gather handoff, which serialized
/// every worker through one mutex at the start and end of every task.
pub struct OnceSlots<T> {
    slots: Vec<UnsafeCell<Option<T>>>,
    state: Vec<AtomicU8>,
}

// SAFETY: slot contents are only accessed by the thread that won the
// corresponding atomic state transition, so `&OnceSlots` can be shared
// across threads whenever the payload itself can be moved between them.
unsafe impl<T: Send> Sync for OnceSlots<T> {}

impl<T> OnceSlots<T> {
    /// All slots pre-filled from `items` (the fan-out direction).
    pub fn filled(items: Vec<T>) -> Self {
        let state = (0..items.len()).map(|_| AtomicU8::new(SLOT_FULL)).collect();
        Self {
            slots: items.into_iter().map(|t| UnsafeCell::new(Some(t))).collect(),
            state,
        }
    }

    /// `n` empty slots awaiting `put` (the gather direction).
    pub fn empty(n: usize) -> Self {
        Self {
            slots: (0..n).map(|_| UnsafeCell::new(None)).collect(),
            state: (0..n).map(|_| AtomicU8::new(SLOT_EMPTY)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Take ownership of slot `i`.  Panics if the slot was never filled or
    /// was already taken — each index has exactly one consumer.
    pub fn take(&self, i: usize) -> T {
        let prev = self.state[i].swap(SLOT_TAKEN, Ordering::AcqRel);
        assert_eq!(prev, SLOT_FULL, "slot {i} taken while in state {prev}");
        // SAFETY: the swap above observed FULL, so the filling thread's
        // release store happened-before this point and no other thread can
        // observe FULL again — this thread exclusively owns the cell.
        unsafe { (*self.slots[i].get()).take().expect("slot verified FULL") }
    }

    /// Fill slot `i`.  Panics on double-fill.
    pub fn put(&self, i: usize, t: T) {
        let prev = self.state[i].swap(SLOT_WRITING, Ordering::AcqRel);
        assert_eq!(prev, SLOT_EMPTY, "slot {i} filled while in state {prev}");
        // SAFETY: the transition EMPTY→WRITING grants exclusive access;
        // readers only touch the cell after observing FULL below.
        unsafe {
            *self.slots[i].get() = Some(t);
        }
        self.state[i].store(SLOT_FULL, Ordering::Release);
    }

    /// Racing fill: fill slot `i` iff it is still empty, returning whether
    /// this caller won.  The value of a losing attempt is dropped.  This is
    /// the first-completion-wins primitive speculative task execution is
    /// built on: the original task and its clone both `try_put`, exactly
    /// one transition EMPTY→WRITING succeeds, and the loser's result never
    /// becomes observable.
    pub fn try_put(&self, i: usize, t: T) -> bool {
        if self.state[i]
            .compare_exchange(SLOT_EMPTY, SLOT_WRITING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        // SAFETY: as in `put` — winning the EMPTY→WRITING CAS grants this
        // thread exclusive access to the cell.
        unsafe {
            *self.slots[i].get() = Some(t);
        }
        self.state[i].store(SLOT_FULL, Ordering::Release);
        true
    }

    /// Consume all slots in index order.  Panics if any slot is unfilled.
    pub fn into_vec(self) -> Vec<T> {
        self.slots
            .into_iter()
            .enumerate()
            .map(|(i, c)| c.into_inner().unwrap_or_else(|| panic!("slot {i} never filled")))
            .collect()
    }
}

/// Distribute owned `items` over `workers` threads and collect `f`'s
/// results in item order.  Input and output both travel through
/// [`OnceSlots`], so no worker ever blocks on a shared lock to pick up its
/// input or deposit its result.
pub fn run_owned<I, T, F>(workers: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send + 'static,
    T: Send + 'static,
    F: Fn(usize, I) -> T + Send + Sync + 'static,
{
    let count = items.len();
    let f = Arc::new(f);
    let inputs = Arc::new(OnceSlots::filled(items));
    let results = Arc::new(OnceSlots::<T>::empty(count));
    let pool = ThreadPool::new(workers.max(1));
    for i in 0..count {
        let f = Arc::clone(&f);
        let inputs = Arc::clone(&inputs);
        let results = Arc::clone(&results);
        pool.execute(move || {
            let item = inputs.take(i);
            results.put(i, f(i, item));
        });
    }
    let panics = pool.join();
    assert_eq!(panics, 0, "{panics} task(s) panicked");
    drop(pool);
    drop(inputs);
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("results still shared"))
        .into_vec()
}

/// Run `tasks` (indexed closures) on `workers` threads and collect results
/// in task order.  Convenience wrapper used by the engine's phases.
pub fn run_indexed<T, F>(workers: usize, count: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    run_owned(workers, vec![(); count], move |i, _: ()| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(pool.join(), 0);
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn join_then_reuse() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&c);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            assert_eq!(pool.join(), 0);
            assert_eq!(c.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn panic_is_counted_not_fatal() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        assert_eq!(pool.join(), 1);
        // pool still usable
        pool.execute(|| {});
        assert_eq!(pool.join(), 0);
    }

    #[test]
    fn run_indexed_preserves_order() {
        let out = run_indexed(3, 50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_owned_moves_items_without_locks() {
        let items: Vec<Vec<u64>> = (0..40).map(|i| vec![i, i + 1]).collect();
        let out = run_owned(4, items, |i, v: Vec<u64>| {
            assert_eq!(v[0], i as u64);
            v.iter().sum::<u64>()
        });
        assert_eq!(out, (0..40).map(|i| 2 * i + 1).collect::<Vec<u64>>());
    }

    #[test]
    fn run_owned_empty_input() {
        let out: Vec<u64> = run_owned(3, Vec::<u64>::new(), |_, v| v);
        assert!(out.is_empty());
    }

    #[test]
    fn once_slots_take_and_put_round_trip() {
        let slots = OnceSlots::filled(vec!["a".to_string(), "b".to_string()]);
        assert_eq!(slots.len(), 2);
        assert_eq!(slots.take(1), "b");
        assert_eq!(slots.take(0), "a");
        let sink = OnceSlots::empty(2);
        sink.put(0, 10u32);
        sink.put(1, 20u32);
        assert_eq!(sink.into_vec(), vec![10, 20]);
    }

    #[test]
    fn try_put_first_wins_second_loses() {
        let sink = OnceSlots::empty(1);
        assert!(sink.try_put(0, 1u32));
        assert!(!sink.try_put(0, 2u32));
        assert_eq!(sink.take(0), 1);
        // after the winner was taken, a late loser still loses
        assert!(!sink.try_put(0, 3u32));
    }

    #[test]
    fn in_flight_drains_to_zero() {
        let pool = ThreadPool::new(2);
        for _ in 0..10 {
            pool.execute(|| {});
        }
        assert_eq!(pool.join(), 0);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    #[should_panic(expected = "taken while in state")]
    fn once_slots_double_take_panics() {
        let slots = OnceSlots::filled(vec![1u8]);
        let _ = slots.take(0);
        let _ = slots.take(0);
    }

    #[test]
    #[should_panic(expected = "filled while in state")]
    fn once_slots_double_put_panics() {
        let sink = OnceSlots::empty(1);
        sink.put(0, 1u8);
        sink.put(0, 2u8);
    }

    #[test]
    fn single_worker_is_sequential_total_order() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let pool = ThreadPool::new(1);
        for i in 0..20 {
            let log = Arc::clone(&log);
            pool.execute(move || log.lock().unwrap().push(i));
        }
        pool.join();
        assert_eq!(*log.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }
}
