//! Tiny command-line parser (`clap` is unavailable offline).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style used by `snmr` and the bench binaries.  Unknown flags are
//! an error so typos fail fast instead of silently running the default
//! experiment.

use std::collections::BTreeMap;

/// A declared flag: `takes_value = false` makes it a boolean switch.
#[derive(Debug, Clone)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
}

/// Declare a value-taking flag.
pub const fn flag(name: &'static str, help: &'static str) -> Flag {
    Flag { name, help, takes_value: true }
}

/// Declare a boolean switch.
pub const fn switch(name: &'static str, help: &'static str) -> Flag {
    Flag { name, help, takes_value: false }
}

/// Parsed arguments: one optional subcommand, flags, and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
    known: Vec<Flag>,
}

impl Args {
    /// Parse from an explicit token list (testable) with a set of known
    /// flag names; `with_subcommand` controls whether the first bare token
    /// is a subcommand or a positional.
    pub fn parse_from(
        tokens: &[String],
        known_flags: &[Flag],
        with_subcommand: bool,
    ) -> Result<Args, String> {
        let mut args = Args {
            known: known_flags.to_vec(),
            ..Default::default()
        };
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.check_known(k)?;
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    args.check_known(name)?;
                    let takes_value = args
                        .known
                        .iter()
                        .find(|f| f.name == name)
                        .map(|f| f.takes_value)
                        // unknown-but-allowed (empty spec): infer from shape
                        .unwrap_or_else(|| {
                            it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                        });
                    if takes_value {
                        let v = it.next().ok_or_else(|| {
                            format!("--{name} expects a value\n{}", args.usage_flags())
                        })?;
                        args.flags.insert(name.to_string(), v.clone());
                    } else {
                        args.bools.push(name.to_string());
                    }
                }
            } else if with_subcommand && args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env(known_flags: &[Flag], with_subcommand: bool) -> Result<Args, String> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Self::parse_from(&tokens, known_flags, with_subcommand)
    }

    fn check_known(&self, name: &str) -> Result<(), String> {
        if self.known.is_empty() || self.known.iter().any(|f| f.name == name) {
            Ok(())
        } else {
            Err(format!(
                "unknown flag --{name}\n{}",
                self.usage_flags()
            ))
        }
    }

    pub fn usage_flags(&self) -> String {
        let mut s = String::from("flags:\n");
        for f in &self.known {
            s.push_str(&format!("  --{:<18} {}\n", f.name, f.help));
        }
        s
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
            || self
                .flags
                .get(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected float, got '{v}'")),
        }
    }

    /// Comma-separated list of integers, e.g. `--workers 1,2,4,8`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad integer '{s}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = Args::parse_from(
            &toks("run --workers 8 --verbose input.txt"),
            &[flag("workers", ""), switch("verbose", "")],
            true,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_usize("workers", 1).unwrap(), 8);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse_from(&toks("--n=42"), &[flag("n", "")], false).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse_from(&toks("--nope 1"), &[flag("yes", "")], false).is_err());
    }

    #[test]
    fn list_flag() {
        let a = Args::parse_from(&toks("--ws 1,2,4,8"), &[flag("ws", "")], false).unwrap();
        assert_eq!(a.get_usize_list("ws", &[]).unwrap(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from(&[], &[flag("x", "")], false).unwrap();
        assert_eq!(a.get_usize("x", 7).unwrap(), 7);
        assert_eq!(a.get_or("x", "d"), "d");
        assert!(!a.get_bool("x"));
    }

    #[test]
    fn underscores_in_numbers() {
        let a = Args::parse_from(&toks("--n 1_400_000"), &[flag("n", "")], false).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 1_400_000);
    }
}
