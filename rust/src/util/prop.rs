//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! Usage pattern (see `rust/tests/prop_sn.rs` for real cases):
//!
//! ```no_run
//! use snmr::util::prop::Cases;
//! Cases::new("window pairs formula", 200).run(|rng| {
//!     let n = rng.range(1, 500);
//!     // ... build inputs from rng, assert the invariant ...
//!     assert!(n >= 1);
//!     Ok(())
//! });
//! ```
//!
//! Failures report the case seed so the exact input can be replayed with
//! `Cases::replay(seed, ...)`.  No shrinking — cases are kept small by
//! construction instead.

use super::rng::Rng;

/// A named batch of randomized test cases.
pub struct Cases {
    name: String,
    count: usize,
    base_seed: u64,
}

impl Cases {
    pub fn new(name: &str, count: usize) -> Self {
        // Base seed is stable per property name so failures reproduce even
        // without recording anything.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Self {
            name: name.to_string(),
            count,
            base_seed: h,
        }
    }

    /// Override the seed (e.g. from the `SNMR_PROP_SEED` env var).
    pub fn seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    /// Run the property on `count` seeded cases; panics with the failing
    /// seed on the first violation.
    pub fn run<F>(&self, mut prop: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for i in 0..self.count {
            let case_seed = self
                .base_seed
                .wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "property '{}' failed on case {} (seed {:#x}): {}",
                    self.name, i, case_seed, msg
                );
            }
        }
    }

    /// Re-run a single failing case by seed.
    pub fn replay<F>(seed: u64, mut prop: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("replay(seed={seed:#x}) failed: {msg}");
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Equality helper with value printing.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {}  ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        Cases::new("always true", 50).run(|_| {
            ran += 1;
            Ok(())
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_seed() {
        Cases::new("always false", 10).run(|_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first = Vec::new();
        Cases::new("det", 5).run(|rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        Cases::new("det", 5).run(|rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn macros_work() {
        fn prop(x: u32) -> Result<(), String> {
            prop_assert!(x < 10, "x too big: {x}");
            prop_assert_eq!(x % 2, 0);
            Ok(())
        }
        assert!(prop(4).is_ok());
        assert!(prop(12).is_err());
        assert!(prop(3).is_err());
    }
}
