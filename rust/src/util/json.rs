//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Scope: everything this crate needs — the AOT `manifest.json`, the
//! `encode_golden.json` parity vectors, and machine-readable bench reports.
//! Full RFC 8259 value model, recursive-descent parser, no streaming.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (useful for golden files and diffs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helper for objects.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document.  Errors carry the byte offset for debugging.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let combined = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode multi-byte utf8
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_values() {
        for src in [
            "null",
            "true",
            "false",
            "0",
            "-12",
            "3.5",
            "1e3",
            "\"hi\"",
            "[]",
            "[1,2,3]",
            "{}",
        ] {
            let v = parse(src).unwrap();
            let re = parse(&v.to_string()).unwrap();
            assert_eq!(v, re, "src={src}");
        }
    }

    #[test]
    fn parses_nested_structure() {
        let v = parse(r#"{"a": [1, {"b": "c"}, null], "d": -2.5e2}"#).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-250.0));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\n\t\"\\ A ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A ü");
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "tru", "\"", "{\"a\"}", "1 2"] {
            assert!(parse(src).is_err(), "src={src}");
        }
    }

    #[test]
    fn object_order_is_deterministic() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }
}
