//! Human-readable formatting for reports and bench output.

use std::time::Duration;

/// `1234567` → `"1,234,567"`.
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Duration → `"1.5s"`, `"230ms"`, `"12.3µs"`, `"2m03s"`, `"1h02m"`.
pub fn duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 3600.0 {
        let h = (s / 3600.0).floor();
        let m = ((s - h * 3600.0) / 60.0).round();
        format!("{h:.0}h{m:02.0}m")
    } else if s >= 60.0 {
        let m = (s / 60.0).floor();
        let sec = s - m * 60.0;
        format!("{m:.0}m{sec:02.0}s")
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Bytes → `"1.2 GiB"` etc.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Rate → `"1.2M pairs/s"` style.
pub fn rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2}G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2}M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.1}k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commas_formats() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(1234567), "1,234,567");
    }

    #[test]
    fn duration_formats() {
        assert_eq!(duration(Duration::from_secs(7260)), "2h01m");
        assert_eq!(duration(Duration::from_secs(123)), "2m03s");
        assert_eq!(duration(Duration::from_millis(1500)), "1.50s");
        assert_eq!(duration(Duration::from_millis(230)), "230.0ms");
        assert_eq!(duration(Duration::from_micros(12)), "12.0µs");
    }

    #[test]
    fn bytes_formats() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(1536), "1.5 KiB");
        assert_eq!(bytes(128 * 1024 * 1024), "128.0 MiB");
    }

    #[test]
    fn rate_formats() {
        assert_eq!(rate(1_500_000.0), "1.50M/s");
        assert_eq!(rate(2_500.0), "2.5k/s");
        assert_eq!(rate(10.0), "10.0/s");
    }
}
