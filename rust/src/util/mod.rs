//! Small self-contained utilities.
//!
//! The build environment is fully offline and the vendored crate set does
//! not include `rand`, `clap`, `serde`, `rayon`, `criterion` or `proptest`,
//! so this module provides the minimal, well-tested equivalents the rest of
//! the crate needs:
//!
//! * [`rng`] — seeded SplitMix64 / Xoshiro256** PRNGs (deterministic
//!   experiments are a hard requirement for the reproduction).
//! * [`cli`] — a tiny `--flag value` argument parser for the binaries.
//! * [`json`] — a JSON writer plus a small recursive-descent reader (used
//!   for the artifact manifest and golden-vector parity tests).
//! * [`threadpool`] — fixed-size worker pool used by the MapReduce engine.
//! * [`prop`] — a miniature property-testing harness (seeded shrink-free
//!   random case generation) used by the invariant tests.

pub mod cli;
pub mod humanize;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;
