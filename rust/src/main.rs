//! `snmr` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `generate` — build a synthetic publication corpus and store it as a
//!   compressed sequence file in the (spill-backed) DFS.
//! * `run`      — execute an ER workflow (SRP / JobSN / RepSN / standard
//!   blocking) over a generated or ad-hoc corpus, with the native or the
//!   AOT-compiled XLA matcher, and report matches, quality, counters and
//!   per-phase timings.
//! * `simulate` — replay a measured job profile on a simulated cluster
//!   (the Figure-8 methodology; see DESIGN.md §3).
//! * `inspect`  — corpus statistics: blocking-key histogram, partition
//!   sizes and Gini coefficients for the §5.3 partition functions.
//!
//! Run `snmr <cmd> --help-flags` to list flags.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use snmr::data::corpus::{generate, CorpusConfig};
use snmr::er::blockkey::{BlockingKey, TitlePrefixKey};
use snmr::er::matcher::{NativeScorer, PairScorer};
use snmr::er::strategy::MatchStrategyConfig;
use snmr::er::workflow::{self, BlockingStrategy, WorkflowConfig};
use snmr::mapreduce::dfs::{Dfs, DfsConfig};
use snmr::mapreduce::seqfile;
use snmr::mapreduce::sim::{simulate_job_chain, ClusterSpec};
use snmr::metrics::report::Table;
use snmr::runtime::matcher_exec::XlaMatcher;
use snmr::sn::partition::{gini, partition_sizes, EvenPartition, PartitionFn, RangePartition};
use snmr::sn::types::SnConfig;
use snmr::util::cli::{flag, switch, Args, Flag};
use snmr::util::humanize;

const FLAGS: &[Flag] = &[
    flag("n", "corpus size (entities), default 10000"),
    flag("seed", "corpus seed"),
    flag("dup-fraction", "duplicate fraction, default 0.15"),
    flag("out", "output directory (generate) / corpus file (run)"),
    flag("input", "corpus sequence file to load"),
    flag("strategy", "srp | jobsn | repsn | standard (default repsn)"),
    flag("window", "SN window size w (default 10)"),
    flag("maps", "number of map tasks m (default 8)"),
    flag("partitions", "number of reduce partitions (default 10)"),
    flag("workers", "concurrent worker slots (default 2)"),
    flag("partitioner", "manual | evenK (e.g. even8), default manual"),
    flag("matcher", "native | native-full | xla (default native)"),
    flag("artifacts", "artifact dir for the xla matcher"),
    flag("cores", "simulate: comma list of core counts (default 1,2,4,8)"),
    switch("blocking-only", "emit candidate pairs without matching"),
    switch("no-compress", "generate: write uncompressed sequence file"),
    switch("help-flags", "print flag help"),
];

fn main() {
    let args = match Args::from_env(FLAGS, true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if args.get_bool("help-flags") {
        println!("{}", args.usage_flags());
        return;
    }
    let result = match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(&args),
        Some("run") => cmd_run(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("inspect") => cmd_inspect(&args),
        other => {
            eprintln!(
                "usage: snmr <generate|run|simulate|inspect> [flags]\n\
                 (got {other:?})\n{}",
                args.usage_flags()
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn corpus_cfg(args: &Args) -> Result<CorpusConfig> {
    Ok(CorpusConfig {
        n_entities: args.get_usize("n", 10_000).map_err(anyhow::Error::msg)?,
        dup_fraction: args
            .get_f64("dup-fraction", 0.15)
            .map_err(anyhow::Error::msg)?,
        seed: args.get_u64("seed", 0xC15E_5EED).map_err(anyhow::Error::msg)?,
        ..Default::default()
    })
}

fn load_or_generate(args: &Args) -> Result<Vec<snmr::er::Entity>> {
    if let Some(path) = args.get("input") {
        let bytes = std::fs::read(path).with_context(|| format!("read {path}"))?;
        let records = seqfile::read_records(&bytes)?;
        let entities = records
            .iter()
            .map(|(k, v)| snmr::er::Entity::from_record(k, v))
            .collect::<Result<Vec<_>>>()?;
        println!(
            "loaded {} entities from {path}",
            humanize::commas(entities.len() as u64)
        );
        Ok(entities)
    } else {
        let cfg = corpus_cfg(args)?;
        let corpus = generate(&cfg);
        println!(
            "generated {} entities ({} truth pairs, seed {:#x})",
            humanize::commas(corpus.entities.len() as u64),
            humanize::commas(corpus.truth_pairs().len() as u64),
            cfg.seed
        );
        Ok(corpus.entities)
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = corpus_cfg(args)?;
    let out_dir = PathBuf::from(args.get_or("out", "data"));
    let corpus = generate(&cfg);
    let records: Vec<(String, Vec<String>)> =
        corpus.entities.iter().map(|e| e.to_record()).collect();
    let bytes = seqfile::write_records(&records, !args.get_bool("no-compress"))?;
    let n_bytes = bytes.len();
    let mut dfs = Dfs::new(DfsConfig {
        spill_dir: Some(out_dir.clone()),
        ..Default::default()
    });
    dfs.write("/corpus.seq", bytes)?;
    // ground truth alongside, as a sequence file of pair records
    let truth_records: Vec<(String, Vec<String>)> = corpus
        .truth_pairs()
        .iter()
        .map(|p| (p.a.to_string(), vec![p.b.to_string()]))
        .collect();
    let tbytes = seqfile::write_records(&truth_records, true)?;
    dfs.write("/truth.seq", tbytes)?;
    println!(
        "wrote {} entities ({}) to {}/corpus.seq (+truth.seq, {} pairs)",
        humanize::commas(corpus.entities.len() as u64),
        humanize::bytes(n_bytes as u64),
        out_dir.display(),
        humanize::commas(truth_records.len() as u64),
    );
    Ok(())
}

fn build_partitioner(
    args: &Args,
    entities: &[snmr::er::Entity],
    key: &dyn BlockingKey,
) -> Result<Arc<dyn PartitionFn>> {
    let parts = args.get_usize("partitions", 10).map_err(anyhow::Error::msg)?;
    match args.get_or("partitioner", "manual") {
        "manual" => Ok(Arc::new(RangePartition::balanced(
            entities,
            |e| key.key(e),
            parts,
        ))),
        s if s.starts_with("even") => {
            let k: usize = s[4..].parse().context("evenK: bad K")?;
            Ok(Arc::new(EvenPartition::ascii(k)))
        }
        other => bail!("unknown partitioner '{other}'"),
    }
}

fn build_scorer(args: &Args) -> Result<Arc<dyn PairScorer>> {
    match args.get_or("matcher", "native") {
        "native" => Ok(Arc::new(NativeScorer { short_circuit: true })),
        "native-full" => Ok(Arc::new(NativeScorer {
            short_circuit: false,
        })),
        "xla" => {
            let dir = args
                .get("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(snmr::runtime::artifact::default_dir);
            Ok(Arc::new(XlaMatcher::load(&dir)?))
        }
        other => bail!("unknown matcher '{other}'"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let entities = load_or_generate(args)?;
    let strategy = BlockingStrategy::parse(args.get_or("strategy", "repsn"))
        .context("bad --strategy")?;
    let key: Arc<dyn BlockingKey> = Arc::new(TitlePrefixKey::new(2));
    let partitioner = build_partitioner(args, &entities, key.as_ref())?;
    let sn = SnConfig {
        window: args.get_usize("window", 10).map_err(anyhow::Error::msg)?,
        num_map_tasks: args.get_usize("maps", 8).map_err(anyhow::Error::msg)?,
        workers: args.get_usize("workers", 2).map_err(anyhow::Error::msg)?,
        partitioner,
        blocking_key: Arc::clone(&key),
        mode: Default::default(),
        sort_buffer_records: None,
        balance: Default::default(),
        spill: None,
        push: false,
        faults: None,
        max_task_retries: None,
        trace: None,
        memory: None,
    };
    let mut cfg = WorkflowConfig::new(strategy, sn);
    if !args.get_bool("blocking-only") {
        cfg = cfg.with_matching(MatchStrategyConfig {
            threshold: snmr::er::matcher::THRESHOLD,
            scorer: build_scorer(args)?,
        });
    }
    let t0 = std::time::Instant::now();
    let res = workflow::run(&entities, &cfg)?;
    let wall = t0.elapsed();
    println!(
        "\n{} over {} entities: {} in {}",
        strategy.name(),
        humanize::commas(entities.len() as u64),
        if args.get_bool("blocking-only") {
            format!(
                "{} candidate pairs",
                humanize::commas(res.pairs.len() as u64)
            )
        } else {
            format!("{} matches", humanize::commas(res.matches.len() as u64))
        },
        humanize::duration(wall)
    );
    println!("\ncounters:\n{}", res.counters.render());
    for (i, s) in res.stats.iter().enumerate() {
        println!(
            "job {}: map {} | shuffle {} | reduce {} | total {}",
            i + 1,
            humanize::duration(std::time::Duration::from_secs_f64(s.map_phase_secs)),
            humanize::duration(std::time::Duration::from_secs_f64(s.shuffle_phase_secs)),
            humanize::duration(std::time::Duration::from_secs_f64(s.reduce_phase_secs)),
            humanize::duration(std::time::Duration::from_secs_f64(s.total_secs)),
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let entities = load_or_generate(args)?;
    let strategy = BlockingStrategy::parse(args.get_or("strategy", "repsn"))
        .context("bad --strategy")?;
    let key: Arc<dyn BlockingKey> = Arc::new(TitlePrefixKey::new(2));
    let partitioner = build_partitioner(args, &entities, key.as_ref())?;
    let sn = SnConfig {
        window: args.get_usize("window", 10).map_err(anyhow::Error::msg)?,
        num_map_tasks: args.get_usize("maps", 8).map_err(anyhow::Error::msg)?,
        workers: 1, // interference-free per-task timings for the simulator
        partitioner,
        blocking_key: Arc::clone(&key),
        mode: Default::default(),
        sort_buffer_records: None,
        balance: Default::default(),
        spill: None,
        push: false,
        faults: None,
        max_task_retries: None,
        trace: None,
        memory: None,
    };
    let mut cfg = WorkflowConfig::new(strategy, sn);
    if !args.get_bool("blocking-only") {
        cfg = cfg.with_matching(MatchStrategyConfig {
            threshold: snmr::er::matcher::THRESHOLD,
            scorer: build_scorer(args)?,
        });
    }
    let res = workflow::run(&entities, &cfg)?;
    let cores = args
        .get_usize_list("cores", &[1, 2, 4, 8])
        .map_err(anyhow::Error::msg)?;
    let mut table = Table::new(
        &format!("{} simulated on paper-like clusters", strategy.name()),
        &["cores", "nodes", "time_s", "speedup"],
    );
    let mut t1 = None;
    for &c in &cores {
        let spec = ClusterSpec::paper_like(c);
        let (_, total) = simulate_job_chain(&res.profiles, &spec);
        let t1v = *t1.get_or_insert(total);
        table.row(vec![
            c.to_string(),
            spec.nodes.to_string(),
            format!("{total:.1}"),
            format!("{:.2}", t1v / total),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let entities = load_or_generate(args)?;
    let key = TitlePrefixKey::new(2);
    let mut table = Table::new(
        "Partition functions and resulting data skew (cf. Table 1)",
        &["p", "partitions", "gini", "largest"],
    );
    let balanced = RangePartition::balanced(&entities, |e| key.key(e), 10);
    let fns: Vec<(String, Arc<dyn PartitionFn>)> = vec![
        ("Manual".into(), Arc::new(balanced)),
        ("Even10".into(), Arc::new(EvenPartition::ascii(10))),
        ("Even8".into(), Arc::new(EvenPartition::ascii(8))),
    ];
    for (name, p) in fns {
        let sizes = partition_sizes(entities.iter().map(|e| key.key(e)), p.as_ref());
        let g = gini(&sizes);
        table.row(vec![
            name,
            sizes.len().to_string(),
            format!("{g:.2}"),
            humanize::commas(*sizes.iter().max().unwrap_or(&0) as u64),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
