//! The shared barrier-job driver: split → map wave → shuffle transpose →
//! reduce wave → stats, written once.
//!
//! Both executors run this exact control flow for barrier (two-wave)
//! jobs — the serial [`run_job`](super::run_job) plugs in private-pool
//! wave closures, the [`JobScheduler`](super::scheduler::JobScheduler)
//! plugs in shared-slot, speculation-capable ones.  Before this module
//! the plumbing lived twice (engine + scheduler) and the push-based
//! shuffle would have made it three; now a wave executor is just the two
//! closures and everything else — split accounting, per-phase timings,
//! counter folds, the transpose, stats assembly — cannot drift between
//! paths.

use std::sync::Arc;
use std::time::Instant;

use super::config::JobConfig;
use super::counters::{names, Counters};
use super::engine::{
    record_map_wave, record_reduce_wave, split_input, transpose_runs, JobOutcome, JobResult,
    JobStats, MapTaskOutput, ReduceTaskOutput,
};
use super::sortspill::Run;
use super::trace::{JobTraceCtx, TraceEvent};

/// Fold a finished map wave into `stats` and the job counters, and
/// transpose run ownership for the reduce side.  Shared by the barrier
/// driver below **and** the scheduler's push path (where the runs
/// already flowed through the `ShuffleService`, so the returned
/// per-reducer lists come back empty and only the byte accounting
/// matters) — one accounting surface, so the two phase structures
/// cannot drift.
pub(crate) fn record_map_phase<KT, VT>(
    stats: &mut JobStats,
    counters: &Counters,
    map_outputs: Vec<MapTaskOutput<KT, VT>>,
    r: usize,
    has_combiner: bool,
    compressed_spill: bool,
) -> Vec<Vec<Run<(KT, VT)>>> {
    stats.map_task_secs = map_outputs.iter().map(|o| o.secs).collect();
    for s in &stats.map_task_secs {
        stats.map_task_us_hist.record((s * 1e6) as u64);
    }
    stats.map_output_records = record_map_wave(counters, &map_outputs, has_combiner);
    stats.spill_bytes_written = map_outputs.iter().map(|o| o.spill_file_bytes).sum();
    let (per_reducer_runs, shuffle_bytes, shuffle_bytes_raw) = transpose_runs(map_outputs, r);
    counters.add(names::SHUFFLE_BYTES, shuffle_bytes.iter().sum());
    counters.add(names::SHUFFLE_BYTES_RAW, shuffle_bytes_raw.iter().sum());
    for b in &shuffle_bytes {
        stats.shuffle_bytes_hist.record(*b);
    }
    stats.shuffle_bytes_per_reducer = shuffle_bytes;
    stats.shuffle_bytes_raw = shuffle_bytes_raw.iter().sum();
    stats.intermediate_compressed = compressed_spill && stats.spill_bytes_written > 0;
    per_reducer_runs
}

/// Fold a finished reduce wave into `stats` and the job counters —
/// per-task timings, output-record skew vector, and the runtime/size
/// histograms — shared by the barrier driver below and the scheduler's
/// push path.
pub(crate) fn record_reduce_phase<KO, VO>(
    stats: &mut JobStats,
    counters: &Counters,
    red_outputs: &[ReduceTaskOutput<KO, VO>],
) {
    stats.reduce_task_secs = red_outputs.iter().map(|o| o.secs).collect();
    stats.reduce_task_output_records = red_outputs.iter().map(|o| o.output.len() as u64).collect();
    for s in &stats.reduce_task_secs {
        stats.reduce_task_us_hist.record((s * 1e6) as u64);
    }
    for n in &stats.reduce_task_output_records {
        stats.reduce_records_hist.record(*n);
    }
    stats.reduce_output_records = record_reduce_wave(counters, red_outputs);
}

/// Drive one barrier job: `map_wave` executes every split into a
/// [`MapTaskOutput`] (on whatever slots the caller owns), the driver
/// transposes run ownership, and `reduce_wave` executes the per-reducer
/// run bundles.  All counter recording and [`JobStats`] assembly happens
/// here, identically for every executor.
pub(crate) fn drive_barrier_job<KI, VI, KT, VT, KO, VO, MW, RW>(
    config: &JobConfig,
    input: Vec<(KI, VI)>,
    counters: &Arc<Counters>,
    has_combiner: bool,
    map_wave: MW,
    reduce_wave: RW,
    trace: Option<JobTraceCtx>,
) -> JobResult<KO, VO>
where
    MW: FnOnce(Vec<Vec<(KI, VI)>>) -> Vec<MapTaskOutput<KT, VT>>,
    RW: FnOnce(Vec<Vec<Run<(KT, VT)>>>) -> Vec<ReduceTaskOutput<KO, VO>>,
{
    let t_start = Instant::now();
    let r = config.num_reduce_tasks;
    let compressed_spill = config.spill.as_ref().map(|s| s.compress()).unwrap_or(false);

    // ---- split ------------------------------------------------------------
    counters.add(names::MAP_INPUT_RECORDS, input.len() as u64);
    let splits = split_input(input, config.num_map_tasks); // may be fewer for tiny inputs

    // ---- map wave ----------------------------------------------------------
    let t_map = Instant::now();
    let map_outputs = map_wave(splits);
    let map_phase_secs = t_map.elapsed().as_secs_f64();

    let mut stats = JobStats {
        map_phase_secs,
        map_wave_done_secs: t_start.elapsed().as_secs_f64(),
        ..Default::default()
    };
    // Stamp the trace with the *same* f64 the stats carry, so metrics
    // derived from the event stream equal the stats fields bit-for-bit.
    if let Some(t) = &trace {
        t.emit_job_at(TraceEvent::MapWaveDone, stats.map_wave_done_secs);
    }

    // ---- shuffle -----------------------------------------------------------
    // Transpose run ownership only — the k-way merge itself streams inside
    // each reduce task.
    let t_shuffle = Instant::now();
    let per_reducer_runs =
        record_map_phase(&mut stats, counters, map_outputs, r, has_combiner, compressed_spill);
    stats.shuffle_phase_secs = t_shuffle.elapsed().as_secs_f64();

    // ---- reduce wave -------------------------------------------------------
    // On the barrier paths the first reduce task starts here — strictly
    // after the whole map wave; overlap_secs stays 0 (the push shuffle is
    // what makes it positive).
    let t_reduce = Instant::now();
    stats.reduce_first_start_secs = t_start.elapsed().as_secs_f64();
    if let Some(t) = &trace {
        t.emit_job_at(TraceEvent::ReduceFirstStart, stats.reduce_first_start_secs);
    }
    let red_outputs = reduce_wave(per_reducer_runs);
    stats.reduce_phase_secs = t_reduce.elapsed().as_secs_f64();
    record_reduce_phase(&mut stats, counters, &red_outputs);
    let outputs: Vec<Vec<(KO, VO)>> = red_outputs.into_iter().map(|o| o.output).collect();
    stats.total_secs = t_start.elapsed().as_secs_f64();
    if let Some(t) = &trace {
        t.emit_job_at(TraceEvent::JobFinished, stats.total_secs);
    }

    // ---- fault-tolerance accounting ---------------------------------------
    // Both wave executors report retries/failures through the job counters
    // (the serial path never retries, so these stay 0 there); the scheduler
    // fills in the per-task dead-letter descriptors afterwards.
    stats.task_retries = counters.get(names::TASK_RETRIES);
    stats.tasks_failed = counters.get(names::TASKS_FAILED);
    let outcome = if counters.get(names::DEAD_LETTERED) > 0 {
        JobOutcome::Degraded
    } else {
        JobOutcome::Ok
    };

    JobResult {
        outputs,
        counters: Arc::clone(counters),
        stats,
        outcome,
    }
}
