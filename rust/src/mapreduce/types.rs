//! Core MapReduce abstractions: tasks, factories, partitioners, emitters.
//!
//! The shape mirrors Hadoop's old (`mapred`) API, which is what the paper's
//! pseudo-code assumes: `map_configure` / `map` / `map_close` on the map
//! side (Algorithm 2 keeps per-task replication state in `configure`), and
//! a `reduce(key, values-iterator)` on the reduce side that can only stream
//! values forward ("similar to a forward SQL cursor", §3).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::counters::Counters;

/// Collects `(key, value)` pairs emitted by user code, together with a
/// byte-size estimate used for shuffle accounting.
pub struct Emitter<K, V> {
    pairs: Vec<(K, V)>,
    bytes: u64,
}

impl<K: SizeEstimate, V: SizeEstimate> Emitter<K, V> {
    pub fn new() -> Self {
        Self {
            pairs: Vec::new(),
            bytes: 0,
        }
    }

    /// Emit one pair.
    #[inline]
    pub fn emit(&mut self, key: K, value: V) {
        self.bytes += (key.size_bytes() + value.size_bytes()) as u64;
        self.pairs.push((key, value));
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub(crate) fn into_pairs(self) -> Vec<(K, V)> {
        self.pairs
    }

    /// Drain the buffered pairs, leaving the emitter reusable.
    ///
    /// The engine drains mid-task when a map-side sort budget is
    /// configured, feeding batches into the bounded
    /// [`crate::mapreduce::sortspill::RunSorter`]s so emitted records
    /// never pile up past the budget.  Byte accounting ([`Self::bytes`])
    /// keeps accumulating across drains; [`Self::len`] counts only the
    /// records buffered since the last drain.
    pub(crate) fn take_pairs(&mut self) -> Vec<(K, V)> {
        std::mem::take(&mut self.pairs)
    }
}

impl<K: SizeEstimate, V: SizeEstimate> Default for Emitter<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// A map *task* instance: owns per-task mutable state.  Created fresh for
/// every input split by a [`MapTaskFactory`].
pub trait MapTask<KI, VI, KT, VT>
where
    KT: SizeEstimate,
    VT: SizeEstimate,
{
    /// Hadoop `configure`: called once before the first record.
    fn configure(&mut self, _out: &mut Emitter<KT, VT>, _counters: &Counters) {}

    /// Called once per input record.
    fn map(&mut self, key: KI, value: VI, out: &mut Emitter<KT, VT>, counters: &Counters);

    /// Hadoop `close`: called once after the last record (RepSN flushes its
    /// replication buffers here).
    fn close(&mut self, _out: &mut Emitter<KT, VT>, _counters: &Counters) {}
}

/// Factory: the engine creates one task instance per map split.
pub trait MapTaskFactory<KI, VI, KT, VT>: Send + Sync
where
    KT: SizeEstimate,
    VT: SizeEstimate,
{
    fn create_task(&self) -> Box<dyn MapTask<KI, VI, KT, VT> + Send>;
}

/// Forward-only iterator over the values of one reduce group.
///
/// Mirrors Hadoop's reduce-value iterator: user code cannot rewind — the
/// memory-bottleneck discussion in §3 of the paper hinges on this.
pub struct ValuesIter<'a, V> {
    values: &'a [V],
    pos: usize,
    consumed: &'a AtomicU64,
}

impl<'a, V> ValuesIter<'a, V> {
    pub(crate) fn new(values: &'a [V], consumed: &'a AtomicU64) -> Self {
        Self {
            values,
            pos: 0,
            consumed,
        }
    }

    /// Number of values in the group (Hadoop doesn't expose this; the SN
    /// reducers do not use it — provided for tests/metrics only).
    pub fn group_len(&self) -> usize {
        self.values.len()
    }
}

impl<'a, V> Iterator for ValuesIter<'a, V> {
    type Item = &'a V;

    fn next(&mut self) -> Option<&'a V> {
        let v = self.values.get(self.pos);
        if v.is_some() {
            self.pos += 1;
            self.consumed.fetch_add(1, Ordering::Relaxed);
        }
        v
    }
}

/// A reduce task instance (one per reduce partition).
pub trait ReduceTask<KT, VT, KO, VO>
where
    KO: SizeEstimate,
    VO: SizeEstimate,
{
    fn configure(&mut self, _out: &mut Emitter<KO, VO>, _counters: &Counters) {}

    /// One call per *group* (grouping comparator semantics); `key` is the
    /// first key of the group, `values` iterates the group's values in
    /// sort-key order.
    fn reduce(
        &mut self,
        key: &KT,
        values: ValuesIter<'_, VT>,
        out: &mut Emitter<KO, VO>,
        counters: &Counters,
    );

    fn close(&mut self, _out: &mut Emitter<KO, VO>, _counters: &Counters) {}
}

/// Factory: one reduce task instance per reduce partition.
pub trait ReduceTaskFactory<KT, VT, KO, VO>: Send + Sync
where
    KO: SizeEstimate,
    VO: SizeEstimate,
{
    fn create_task(&self) -> Box<dyn ReduceTask<KT, VT, KO, VO> + Send>;
}

/// Decides the reduce partition for an intermediate key.
pub trait Partitioner<K>: Send + Sync {
    fn partition(&self, key: &K, num_reducers: usize) -> usize;
}

/// Default partitioner: hash of the key (FNV-1a over `Debug` is wrong; we
/// require a user hash function instead — see `HashPartitioner::new`).
pub struct HashPartitioner<K> {
    hash: Box<dyn Fn(&K) -> u64 + Send + Sync>,
}

impl<K> HashPartitioner<K> {
    pub fn new(hash: impl Fn(&K) -> u64 + Send + Sync + 'static) -> Self {
        Self {
            hash: Box::new(hash),
        }
    }
}

impl<K> Partitioner<K> for HashPartitioner<K> {
    fn partition(&self, key: &K, num_reducers: usize) -> usize {
        ((self.hash)(key) % num_reducers as u64) as usize
    }
}

/// Cheap, conservative serialized-size estimate used for shuffle-byte
/// accounting and the DFS materialization model.
pub trait SizeEstimate {
    fn size_bytes(&self) -> usize;
}

impl SizeEstimate for String {
    fn size_bytes(&self) -> usize {
        self.len() + 4
    }
}

impl SizeEstimate for &str {
    fn size_bytes(&self) -> usize {
        self.len() + 4
    }
}

impl SizeEstimate for u32 {
    fn size_bytes(&self) -> usize {
        4
    }
}

impl SizeEstimate for u64 {
    fn size_bytes(&self) -> usize {
        8
    }
}

impl SizeEstimate for f32 {
    fn size_bytes(&self) -> usize {
        4
    }
}

impl SizeEstimate for f64 {
    fn size_bytes(&self) -> usize {
        8
    }
}

impl SizeEstimate for () {
    fn size_bytes(&self) -> usize {
        0
    }
}

impl<A: SizeEstimate, B: SizeEstimate> SizeEstimate for (A, B) {
    fn size_bytes(&self) -> usize {
        self.0.size_bytes() + self.1.size_bytes()
    }
}

impl<T: SizeEstimate> SizeEstimate for Vec<T> {
    fn size_bytes(&self) -> usize {
        4 + self.iter().map(|t| t.size_bytes()).sum::<usize>()
    }
}

impl<T: SizeEstimate> SizeEstimate for Option<T> {
    fn size_bytes(&self) -> usize {
        1 + self.as_ref().map(|t| t.size_bytes()).unwrap_or(0)
    }
}

impl<T: SizeEstimate> SizeEstimate for Arc<T> {
    fn size_bytes(&self) -> usize {
        (**self).size_bytes()
    }
}

// ---------------------------------------------------------------------------
// Closure adapters: stateless map/reduce functions without factory boilerplate
// ---------------------------------------------------------------------------

/// Stateless map function as a task factory.
pub struct FnMapTask<F> {
    f: Arc<F>,
}

impl<F> FnMapTask<F> {
    pub fn new(f: F) -> Self {
        Self { f: Arc::new(f) }
    }
}

struct FnMapInstance<F> {
    f: Arc<F>,
}

impl<KI, VI, KT, VT, F> MapTask<KI, VI, KT, VT> for FnMapInstance<F>
where
    KT: SizeEstimate,
    VT: SizeEstimate,
    F: Fn(KI, VI, &mut Emitter<KT, VT>, &Counters),
{
    fn map(&mut self, key: KI, value: VI, out: &mut Emitter<KT, VT>, counters: &Counters) {
        (self.f)(key, value, out, counters)
    }
}

impl<KI, VI, KT, VT, F> MapTaskFactory<KI, VI, KT, VT> for FnMapTask<F>
where
    KT: SizeEstimate,
    VT: SizeEstimate,
    F: Fn(KI, VI, &mut Emitter<KT, VT>, &Counters) + Send + Sync + 'static,
    KI: 'static,
    VI: 'static,
    KT: 'static,
    VT: 'static,
{
    fn create_task(&self) -> Box<dyn MapTask<KI, VI, KT, VT> + Send> {
        Box::new(FnMapInstance {
            f: Arc::clone(&self.f),
        })
    }
}

/// Stateless reduce function as a task factory.
pub struct FnReduceTask<F> {
    f: Arc<F>,
}

impl<F> FnReduceTask<F> {
    pub fn new(f: F) -> Self {
        Self { f: Arc::new(f) }
    }
}

struct FnReduceInstance<F> {
    f: Arc<F>,
}

impl<KT, VT, KO, VO, F> ReduceTask<KT, VT, KO, VO> for FnReduceInstance<F>
where
    KO: SizeEstimate,
    VO: SizeEstimate,
    F: Fn(&KT, ValuesIter<'_, VT>, &mut Emitter<KO, VO>, &Counters),
{
    fn reduce(
        &mut self,
        key: &KT,
        values: ValuesIter<'_, VT>,
        out: &mut Emitter<KO, VO>,
        counters: &Counters,
    ) {
        (self.f)(key, values, out, counters)
    }
}

impl<KT, VT, KO, VO, F> ReduceTaskFactory<KT, VT, KO, VO> for FnReduceTask<F>
where
    KO: SizeEstimate,
    VO: SizeEstimate,
    F: Fn(&KT, ValuesIter<'_, VT>, &mut Emitter<KO, VO>, &Counters) + Send + Sync + 'static,
    KT: 'static,
    VT: 'static,
    KO: 'static,
    VO: 'static,
{
    fn create_task(&self) -> Box<dyn ReduceTask<KT, VT, KO, VO> + Send> {
        Box::new(FnReduceInstance {
            f: Arc::clone(&self.f),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emitter_counts_bytes() {
        let mut e: Emitter<String, String> = Emitter::new();
        e.emit("ab".into(), "cdef".into());
        assert_eq!(e.len(), 1);
        assert_eq!(e.bytes(), (2 + 4 + 4 + 4) as u64);
    }

    #[test]
    fn values_iter_is_forward_only_and_counts() {
        let consumed = AtomicU64::new(0);
        let vals = vec![1u32, 2, 3];
        let mut it = ValuesIter::new(&vals, &consumed);
        assert_eq!(it.group_len(), 3);
        assert_eq!(it.next(), Some(&1));
        assert_eq!(it.next(), Some(&2));
        assert_eq!(it.next(), Some(&3));
        assert_eq!(it.next(), None);
        assert_eq!(consumed.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn hash_partitioner_in_range() {
        let p = HashPartitioner::new(|k: &u64| *k);
        for k in 0..100u64 {
            let idx = p.partition(&k, 7);
            assert!(idx < 7);
        }
    }

    #[test]
    fn size_estimates_compose() {
        let v: Vec<(String, u32)> = vec![("a".into(), 1), ("bc".into(), 2)];
        assert_eq!(v.size_bytes(), 4 + (1 + 4 + 4) + (2 + 4 + 4));
    }
}
