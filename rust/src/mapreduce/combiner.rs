//! Map-side combiners (Hadoop's `setCombinerClass`).
//!
//! A combiner pre-reduces each map task's sorted runs before the shuffle,
//! shrinking intermediate data for associative aggregations.  Wired into
//! the engine through
//! [`run_job_with_combiner`](crate::mapreduce::engine::run_job_with_combiner),
//! which applies [`combine_sorted_bucket`] to every sealed sorted run
//! before the shuffle transpose hands it to a reducer.  The SN jobs
//! themselves cannot use one (their reduce is not a per-key aggregation),
//! but (a) it is part of the Hadoop semantics the paper assumes,
//! (b) auxiliary jobs — key histograms for the Manual partitioner, corpus
//! statistics — are classic combiner material, and the A2 ablation
//! (`benches/engine_ablation.rs`) measures exactly that saving.

use std::sync::Arc;

use super::counters::Counters;
use super::types::SizeEstimate;

/// A combiner: fold all values of one key (within one map task's bucket)
/// into fewer values.  Must be associative and produce output of the same
/// type as its input (Hadoop's constraint).
pub trait Combiner<K, V>: Send + Sync {
    fn combine(&self, key: &K, values: Vec<V>, counters: &Counters) -> Vec<V>;
}

/// Closure adapter.
pub struct FnCombiner<F> {
    f: Arc<F>,
}

impl<F> FnCombiner<F> {
    pub fn new(f: F) -> Self {
        Self { f: Arc::new(f) }
    }
}

impl<K, V, F> Combiner<K, V> for FnCombiner<F>
where
    F: Fn(&K, Vec<V>, &Counters) -> Vec<V> + Send + Sync,
{
    fn combine(&self, key: &K, values: Vec<V>, counters: &Counters) -> Vec<V> {
        (self.f)(key, values, counters)
    }
}

/// Apply a combiner to one *sorted* bucket in place.
///
/// Consecutive equal keys are folded; the bucket stays sorted.  Returns
/// `(records_in, records_out)` for the spill counters.
pub fn combine_sorted_bucket<K, V>(
    bucket: &mut Vec<(K, V)>,
    combiner: &dyn Combiner<K, V>,
    counters: &Counters,
) -> (u64, u64)
where
    K: Ord + Clone + SizeEstimate,
    V: SizeEstimate,
{
    let records_in = bucket.len() as u64;
    if bucket.is_empty() {
        return (0, 0);
    }
    let mut out: Vec<(K, V)> = Vec::with_capacity(bucket.len());
    let mut group_key: Option<K> = None;
    let mut group_vals: Vec<V> = Vec::new();
    for (k, v) in bucket.drain(..) {
        match &group_key {
            Some(gk) if *gk == k => group_vals.push(v),
            _ => {
                if let Some(gk) = group_key.take() {
                    for cv in combiner.combine(&gk, std::mem::take(&mut group_vals), counters) {
                        out.push((gk.clone(), cv));
                    }
                }
                group_key = Some(k);
                group_vals.push(v);
            }
        }
    }
    if let Some(gk) = group_key.take() {
        for cv in combiner.combine(&gk, group_vals, counters) {
            out.push((gk.clone(), cv));
        }
    }
    let records_out = out.len() as u64;
    *bucket = out;
    (records_in, records_out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_combiner() -> FnCombiner<impl Fn(&String, Vec<u64>, &Counters) -> Vec<u64>> {
        FnCombiner::new(|_k: &String, vals: Vec<u64>, _c: &Counters| {
            vec![vals.into_iter().sum()]
        })
    }

    #[test]
    fn folds_consecutive_keys() {
        let mut bucket: Vec<(String, u64)> = vec![
            ("a".into(), 1),
            ("a".into(), 2),
            ("b".into(), 3),
            ("c".into(), 4),
            ("c".into(), 5),
            ("c".into(), 6),
        ];
        let counters = Counters::new();
        let (inn, out) = combine_sorted_bucket(&mut bucket, &sum_combiner(), &counters);
        assert_eq!((inn, out), (6, 3));
        assert_eq!(
            bucket,
            vec![("a".into(), 3), ("b".into(), 3), ("c".into(), 15)]
        );
    }

    #[test]
    fn empty_and_singleton() {
        let counters = Counters::new();
        let mut empty: Vec<(String, u64)> = vec![];
        assert_eq!(
            combine_sorted_bucket(&mut empty, &sum_combiner(), &counters),
            (0, 0)
        );
        let mut single: Vec<(String, u64)> = vec![("x".into(), 7)];
        combine_sorted_bucket(&mut single, &sum_combiner(), &counters);
        assert_eq!(single, vec![("x".into(), 7)]);
    }

    #[test]
    fn identity_combiner_preserves_order_and_content() {
        let ident = FnCombiner::new(|_k: &String, vals: Vec<u64>, _c: &Counters| vals);
        let mut bucket: Vec<(String, u64)> =
            vec![("a".into(), 2), ("a".into(), 1), ("b".into(), 9)];
        let counters = Counters::new();
        let before = bucket.clone();
        combine_sorted_bucket(&mut bucket, &ident, &counters);
        assert_eq!(bucket, before);
    }

    #[test]
    fn combiner_shrinks_wordcount_shuffle() {
        // the A2 measurement in miniature: many repeats of few keys
        let mut bucket: Vec<(String, u64)> = Vec::new();
        for _ in 0..1000 {
            bucket.push(("hot".into(), 1));
        }
        bucket.push(("rare".into(), 1));
        bucket.sort();
        let counters = Counters::new();
        let (inn, out) = combine_sorted_bucket(&mut bucket, &sum_combiner(), &counters);
        assert_eq!(inn, 1001);
        assert_eq!(out, 2);
        assert_eq!(bucket[0], ("hot".into(), 1000));
    }
}
