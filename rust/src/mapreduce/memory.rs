//! Process-wide memory pool: one byte budget shared by every job on a
//! scheduler, with per-consumer reservations, a fair spill policy, and
//! backpressure for the push shuffle.
//!
//! `JobConfig::sort_buffer_records` bounds one sorter's *record count*;
//! nothing bounds what N concurrent jobs collectively hold.  The
//! [`MemoryPool`] closes that gap the way datafusion's memory manager
//! does: consumers register, reserve bytes before holding them, and the
//! pool arbitrates when the sum would exceed the budget.
//!
//! ## Reservation lifecycle
//!
//! A [`MemoryConsumer`] registers with the pool and receives a
//! [`MemoryReservation`] — the RAII handle that owns the consumer's
//! accounted bytes.  Growth comes in three strengths:
//!
//! * [`MemoryReservation::try_grow`] — the elastic decision point.  A
//!   denial means "find somewhere cheaper for these bytes": seal the
//!   sorted run early, divert the pushed run to disk.  Denials are
//!   counted and trigger the fair-spill policy.
//! * [`MemoryReservation::grow`] — unconditional, for bytes that are
//!   held regardless of the answer (a record already emitted into a
//!   buffer with nowhere else to go).  Keeps the accounting truthful
//!   even when the pool is over budget.
//! * [`MemoryReservation::park_grow`] — backpressure.  The caller
//!   blocks in bounded slices until the bytes fit, an abort is
//!   observed, or the wait budget expires (then the grow is granted as
//!   a counted *overdraft* so no configuration can deadlock).
//!
//! [`MemoryReservation::shrink`]/[`free`](MemoryReservation::free)
//! return bytes and wake every parked grower and queued admission.
//! Dropping a reservation frees whatever it still holds.
//!
//! ## Fairness rule
//!
//! When a `try_grow` is denied, the pool flags the **largest spillable
//! consumer** (preferring consumers other than the requester) with a
//! spill request.  Elastic consumers poll
//! [`MemoryReservation::take_spill_request`] at their next decision
//! point and respond by sealing/spilling, which shrinks their
//! reservation and unparks waiters — so the consumer holding the most
//! elastic memory pays first, not whoever asked last.
//!
//! ## Admission control
//!
//! [`MemoryPool::admit`] reserves a job's minimum working set in one
//! atomic step, blocking (queueing) while the pool is too full to
//! grant it — a job never starts tasks it cannot feed.  The admission
//! reservation is held for the job's lifetime as its floor.
//!
//! The pool is `Option`-threaded like trace/metrics/faults: `None`
//! means no accounting at all, and an unlimited pool never denies, so
//! both are behaviorally identical to the unpooled engine.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Wait slice between re-checks while parked on a full pool; each slice
/// re-examines the abort flag, so aborts are observed promptly.
pub(crate) const PARK_SLICE: Duration = Duration::from_millis(2);

/// Bytes the scheduler reserves per concurrently-runnable task when
/// admitting a job — a deliberately small floor: admission exists to
/// keep a swarm of queued jobs from all starting at once on a saturated
/// pool, while the real working set is charged (and shed) dynamically by
/// the tasks themselves.
pub const ADMISSION_FLOOR_PER_TASK: u64 = 1024;

/// Default total wait before a parked grow is granted as an overdraft.
pub const DEFAULT_PARK_WAIT: Duration = Duration::from_secs(2);

/// Default wait before a queued admission is granted as an overdraft.
pub const DEFAULT_ADMIT_WAIT: Duration = Duration::from_secs(10);

#[derive(Default)]
struct Entry {
    name: String,
    spillable: bool,
    reserved: u64,
    spill_requested: bool,
}

#[derive(Default)]
struct PoolState {
    reserved: u64,
    next_id: u64,
    consumers: BTreeMap<u64, Entry>,
}

struct PoolShared {
    budget: u64,
    state: Mutex<PoolState>,
    cv: Condvar,
    // lock-free mirrors for gauges and post-run assertions
    reserved: AtomicU64,
    peak: AtomicU64,
    denied_grows: AtomicU64,
    spill_requests: AtomicU64,
    backpressure_waits: AtomicU64,
    overdrafts: AtomicU64,
    admission_waits: AtomicU64,
}

/// Shared handle to one byte-budgeted pool.  Cheap to clone; every
/// clone addresses the same budget and consumer table.
#[derive(Clone)]
pub struct MemoryPool {
    shared: Arc<PoolShared>,
}

impl fmt::Debug for MemoryPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MemoryPool")
            .field("budget", &self.shared.budget)
            .field("reserved", &self.reserved_bytes())
            .finish()
    }
}

impl MemoryPool {
    /// A pool with a hard byte budget.
    pub fn new(budget_bytes: u64) -> Self {
        Self {
            shared: Arc::new(PoolShared {
                budget: budget_bytes,
                state: Mutex::new(PoolState::default()),
                cv: Condvar::new(),
                reserved: AtomicU64::new(0),
                peak: AtomicU64::new(0),
                denied_grows: AtomicU64::new(0),
                spill_requests: AtomicU64::new(0),
                backpressure_waits: AtomicU64::new(0),
                overdrafts: AtomicU64::new(0),
                admission_waits: AtomicU64::new(0),
            }),
        }
    }

    /// A pool that accounts but never denies (`budget = u64::MAX`):
    /// behaviorally a strict no-op against the unpooled engine.
    pub fn unlimited() -> Self {
        Self::new(u64::MAX)
    }

    /// The configured budget in bytes.
    pub fn budget_bytes(&self) -> u64 {
        self.shared.budget
    }

    /// Bytes currently reserved across all consumers.
    pub fn reserved_bytes(&self) -> u64 {
        self.shared.reserved.load(Ordering::Relaxed)
    }

    /// High-water mark of reserved bytes over the pool's lifetime.
    pub fn peak_bytes(&self) -> u64 {
        self.shared.peak.load(Ordering::Relaxed)
    }

    /// Total `try_grow` denials.
    pub fn denied_grows(&self) -> u64 {
        self.shared.denied_grows.load(Ordering::Relaxed)
    }

    /// Times the fair-spill policy asked a consumer to spill (including
    /// denials answered by diverting a pushed run to disk).
    pub fn spill_requests(&self) -> u64 {
        self.shared.spill_requests.load(Ordering::Relaxed)
    }

    /// Times a grower parked waiting for bytes to come back.
    pub fn backpressure_waits(&self) -> u64 {
        self.shared.backpressure_waits.load(Ordering::Relaxed)
    }

    /// Grows granted past the budget after a bounded wait expired — the
    /// anti-deadlock escape hatch.  Zero in healthy configurations.
    pub fn overdrafts(&self) -> u64 {
        self.shared.overdrafts.load(Ordering::Relaxed)
    }

    /// Jobs that had to queue at admission before their floor fit.
    pub fn admission_waits(&self) -> u64 {
        self.shared.admission_waits.load(Ordering::Relaxed)
    }

    /// Live registered consumers.
    pub fn consumer_count(&self) -> usize {
        self.shared.state.lock().unwrap().consumers.len()
    }

    /// Two handles to the same underlying pool?
    pub fn same_pool(&self, other: &MemoryPool) -> bool {
        Arc::ptr_eq(&self.shared, &other.shared)
    }

    /// A non-owning handle for observers (the metrics sampler's pool
    /// probe): upgrading fails once every strong handle is gone, which
    /// is how a registered probe learns to prune itself.
    pub fn downgrade(&self) -> WeakMemoryPool {
        WeakMemoryPool {
            shared: Arc::downgrade(&self.shared),
        }
    }

    /// Reserve a job's minimum working set, queueing until it fits.
    ///
    /// The returned reservation is the job's admission floor: hold it
    /// for the job's lifetime, drop it when the job completes.  After
    /// `max_wait` of queueing the floor is granted as an overdraft so a
    /// mis-sized pool degrades instead of wedging.
    pub fn admit(&self, name: &str, min_bytes: u64, max_wait: Duration) -> MemoryReservation {
        let mut res = MemoryConsumer::new(name).register(self);
        if min_bytes == 0 {
            return res;
        }
        let mut state = self.shared.state.lock().unwrap();
        if min_bytes > self.shared.budget {
            // a floor larger than the whole pool can never fit — waiting
            // is pointless, so grant it as an immediate overdraft
            self.shared.overdrafts.fetch_add(1, Ordering::Relaxed);
            self.grant(&mut state, res.id, min_bytes);
            drop(state);
            res.size += min_bytes;
            return res;
        }
        if !self.fits(&state, min_bytes) {
            self.shared.admission_waits.fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            while !self.fits(&state, min_bytes) {
                if t0.elapsed() >= max_wait {
                    self.shared.overdrafts.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                let (s, _) = self.shared.cv.wait_timeout(state, PARK_SLICE).unwrap();
                state = s;
            }
        }
        self.grant(&mut state, res.id, min_bytes);
        drop(state);
        res.size += min_bytes;
        res
    }

    /// Park the calling thread until some reservation releases bytes or
    /// `timeout` passes — the push shuffle's backpressure loop waits in
    /// bounded slices between `try_grow` retries, holding no other lock
    /// across the wait (a parked pusher must never block the reducers
    /// whose drains free the bytes it is waiting for).
    pub(crate) fn wait_for_release(&self, timeout: Duration) {
        let state = self.shared.state.lock().unwrap();
        let _ = self.shared.cv.wait_timeout(state, timeout).unwrap();
    }

    /// Record one backpressure episode initiated outside
    /// [`MemoryReservation::park_grow`] (the push shuffle runs its own
    /// slice loop), so [`Self::backpressure_waits`] stays truthful.
    pub(crate) fn note_backpressure_wait(&self) {
        self.shared.backpressure_waits.fetch_add(1, Ordering::Relaxed);
    }

    fn fits(&self, state: &PoolState, additional: u64) -> bool {
        state.reserved.saturating_add(additional) <= self.shared.budget
    }

    /// Record a grant under the lock and refresh the gauge mirrors.
    fn grant(&self, state: &mut PoolState, id: u64, bytes: u64) {
        state.reserved += bytes;
        if let Some(e) = state.consumers.get_mut(&id) {
            e.reserved += bytes;
        }
        self.shared.reserved.store(state.reserved, Ordering::Relaxed);
        self.shared.peak.fetch_max(state.reserved, Ordering::Relaxed);
    }

    fn release(&self, state: &mut PoolState, id: u64, bytes: u64) {
        state.reserved = state.reserved.saturating_sub(bytes);
        if let Some(e) = state.consumers.get_mut(&id) {
            e.reserved = e.reserved.saturating_sub(bytes);
        }
        self.shared.reserved.store(state.reserved, Ordering::Relaxed);
        self.shared.cv.notify_all();
    }

    /// Fairness rule: on a denial, flag the largest spillable consumer
    /// (preferring one other than the requester) to spill.
    fn request_fair_spill(&self, state: &mut PoolState, requester: u64) {
        let victim = state
            .consumers
            .iter()
            .filter(|(id, e)| e.spillable && e.reserved > 0 && **id != requester)
            .max_by_key(|(id, e)| (e.reserved, std::cmp::Reverse(**id)))
            .map(|(id, _)| *id)
            .or_else(|| {
                state
                    .consumers
                    .get(&requester)
                    .filter(|e| e.spillable && e.reserved > 0)
                    .map(|_| requester)
            });
        if let Some(v) = victim {
            let e = state.consumers.get_mut(&v).unwrap();
            if !e.spill_requested {
                e.spill_requested = true;
                self.shared.spill_requests.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Non-owning counterpart of [`MemoryPool`] (see
/// [`MemoryPool::downgrade`]).
#[derive(Clone)]
pub struct WeakMemoryPool {
    shared: std::sync::Weak<PoolShared>,
}

impl WeakMemoryPool {
    /// The pool, if any strong handle is still alive.
    pub fn upgrade(&self) -> Option<MemoryPool> {
        self.shared.upgrade().map(|shared| MemoryPool { shared })
    }
}

/// A named party that wants accounted memory.  Mark it spillable if it
/// can shed bytes on request (sealing runs to disk, diverting pushes);
/// only spillable consumers are asked to by the fairness rule.
pub struct MemoryConsumer {
    name: String,
    spillable: bool,
}

impl MemoryConsumer {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            spillable: false,
        }
    }

    /// Declare that this consumer can release memory when asked.
    pub fn with_can_spill(mut self, can: bool) -> Self {
        self.spillable = can;
        self
    }

    /// Register with a pool, producing the reservation handle.
    pub fn register(self, pool: &MemoryPool) -> MemoryReservation {
        let mut state = pool.shared.state.lock().unwrap();
        let id = state.next_id;
        state.next_id += 1;
        state.consumers.insert(
            id,
            Entry {
                name: self.name,
                spillable: self.spillable,
                reserved: 0,
                spill_requested: false,
            },
        );
        drop(state);
        MemoryReservation {
            pool: pool.clone(),
            id,
            size: 0,
        }
    }
}

/// Outcome of a [`MemoryReservation::park_grow`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParkOutcome {
    /// The bytes fit (immediately or after waiting).
    Granted,
    /// The wait budget expired; the grow was granted past the budget.
    Overdraft,
    /// The abort probe fired while parked; nothing was reserved.
    Aborted,
}

/// RAII handle to one consumer's accounted bytes.  Dropping it frees
/// whatever it still holds and deregisters the consumer.
pub struct MemoryReservation {
    pool: MemoryPool,
    id: u64,
    size: u64,
}

impl fmt::Debug for MemoryReservation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MemoryReservation")
            .field("id", &self.id)
            .field("size", &self.size)
            .finish()
    }
}

impl MemoryReservation {
    /// Bytes this reservation currently holds.
    pub fn size(&self) -> u64 {
        self.size
    }

    /// The pool this reservation draws from.
    pub fn pool(&self) -> &MemoryPool {
        &self.pool
    }

    /// Try to reserve `bytes` more.  On denial the fair-spill policy
    /// flags the largest spillable consumer and `false` is returned —
    /// the caller should shed bytes (seal/spill/divert) and retry, or
    /// fall back to [`grow`](Self::grow)/[`park_grow`](Self::park_grow).
    pub fn try_grow(&mut self, bytes: u64) -> bool {
        if bytes == 0 {
            return true;
        }
        let shared = &self.pool.shared;
        let mut state = shared.state.lock().unwrap();
        if self.pool.fits(&state, bytes) {
            self.pool.grant(&mut state, self.id, bytes);
            drop(state);
            self.size += bytes;
            true
        } else {
            shared.denied_grows.fetch_add(1, Ordering::Relaxed);
            self.pool.request_fair_spill(&mut state, self.id);
            false
        }
    }

    /// Reserve unconditionally — for bytes held regardless of budget.
    /// Never denies, never blocks; keeps the accounting truthful.
    pub fn grow(&mut self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let mut state = self.pool.shared.state.lock().unwrap();
        self.pool.grant(&mut state, self.id, bytes);
        drop(state);
        self.size += bytes;
    }

    /// Backpressure: block in bounded slices until `bytes` fit, the
    /// `aborted` probe fires, or `max_wait` expires (then the grow is
    /// granted as a counted overdraft so no configuration deadlocks).
    pub fn park_grow(
        &mut self,
        bytes: u64,
        max_wait: Duration,
        aborted: &dyn Fn() -> bool,
    ) -> ParkOutcome {
        if bytes == 0 {
            return ParkOutcome::Granted;
        }
        let shared = &self.pool.shared;
        let mut state = shared.state.lock().unwrap();
        if self.pool.fits(&state, bytes) {
            self.pool.grant(&mut state, self.id, bytes);
            drop(state);
            self.size += bytes;
            return ParkOutcome::Granted;
        }
        shared.denied_grows.fetch_add(1, Ordering::Relaxed);
        shared.backpressure_waits.fetch_add(1, Ordering::Relaxed);
        self.pool.request_fair_spill(&mut state, self.id);
        let t0 = Instant::now();
        loop {
            if aborted() {
                return ParkOutcome::Aborted;
            }
            if self.pool.fits(&state, bytes) {
                self.pool.grant(&mut state, self.id, bytes);
                drop(state);
                self.size += bytes;
                return ParkOutcome::Granted;
            }
            if t0.elapsed() >= max_wait {
                shared.overdrafts.fetch_add(1, Ordering::Relaxed);
                self.pool.grant(&mut state, self.id, bytes);
                drop(state);
                self.size += bytes;
                return ParkOutcome::Overdraft;
            }
            let (s, _) = shared.cv.wait_timeout(state, PARK_SLICE).unwrap();
            state = s;
        }
    }

    /// Return `bytes` to the pool (clamped to the held size) and wake
    /// parked growers and queued admissions.
    pub fn shrink(&mut self, bytes: u64) {
        let bytes = bytes.min(self.size);
        if bytes == 0 {
            return;
        }
        let mut state = self.pool.shared.state.lock().unwrap();
        self.pool.release(&mut state, self.id, bytes);
        drop(state);
        self.size -= bytes;
    }

    /// Return everything.
    pub fn free(&mut self) {
        let held = self.size;
        self.shrink(held);
    }

    /// Resize to exactly `bytes` (grow unconditionally or shrink).
    pub fn resize(&mut self, bytes: u64) {
        if bytes > self.size {
            self.grow(bytes - self.size);
        } else {
            self.shrink(self.size - bytes);
        }
    }

    /// Consume a pending fair-spill request, if one was flagged for
    /// this consumer.  Returns `true` at most once per request; the
    /// caller responds by shedding bytes.
    pub fn take_spill_request(&mut self) -> bool {
        let mut state = self.pool.shared.state.lock().unwrap();
        match state.consumers.get_mut(&self.id) {
            Some(e) if e.spill_requested => {
                e.spill_requested = false;
                true
            }
            _ => false,
        }
    }

    /// The registered consumer name (for diagnostics).
    pub fn consumer_name(&self) -> String {
        let state = self.pool.shared.state.lock().unwrap();
        state
            .consumers
            .get(&self.id)
            .map(|e| e.name.clone())
            .unwrap_or_default()
    }
}

impl Drop for MemoryReservation {
    fn drop(&mut self) {
        let mut state = self.pool.shared.state.lock().unwrap();
        let held = self.size;
        if held > 0 {
            self.pool.release(&mut state, self.id, held);
        }
        state.consumers.remove(&self.id);
        drop(state);
        self.pool.shared.cv.notify_all();
        self.size = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::thread;

    #[test]
    fn grow_shrink_free_roundtrip() {
        let pool = MemoryPool::new(1000);
        let mut r = MemoryConsumer::new("a").register(&pool);
        assert!(r.try_grow(400));
        assert_eq!(pool.reserved_bytes(), 400);
        r.shrink(150);
        assert_eq!(pool.reserved_bytes(), 250);
        assert_eq!(r.size(), 250);
        r.free();
        assert_eq!(pool.reserved_bytes(), 0);
        assert_eq!(pool.peak_bytes(), 400);
        assert_eq!(pool.denied_grows(), 0);
    }

    #[test]
    fn try_grow_denies_past_budget_and_flags_largest_spillable() {
        let pool = MemoryPool::new(1000);
        let mut big = MemoryConsumer::new("big").with_can_spill(true).register(&pool);
        let mut small = MemoryConsumer::new("small")
            .with_can_spill(true)
            .register(&pool);
        assert!(big.try_grow(700));
        assert!(small.try_grow(200));
        let mut asker = MemoryConsumer::new("asker").register(&pool);
        assert!(!asker.try_grow(200));
        assert_eq!(pool.denied_grows(), 1);
        assert_eq!(pool.spill_requests(), 1);
        // the *largest* spillable consumer got the request
        assert!(big.take_spill_request());
        assert!(!small.take_spill_request());
        // the flag is one-shot
        assert!(!big.take_spill_request());
    }

    #[test]
    fn unlimited_pool_never_denies() {
        let pool = MemoryPool::unlimited();
        let mut r = MemoryConsumer::new("x").register(&pool);
        assert!(r.try_grow(u64::MAX / 2));
        assert_eq!(pool.denied_grows(), 0);
    }

    #[test]
    fn drop_frees_and_deregisters() {
        let pool = MemoryPool::new(100);
        {
            let mut r = MemoryConsumer::new("t").register(&pool);
            r.grow(80);
            assert_eq!(pool.consumer_count(), 1);
        }
        assert_eq!(pool.reserved_bytes(), 0);
        assert_eq!(pool.consumer_count(), 0);
    }

    #[test]
    fn park_grow_unblocks_on_shrink() {
        let pool = MemoryPool::new(100);
        let mut holder = MemoryConsumer::new("holder").register(&pool);
        holder.grow(90);
        let pool2 = pool.clone();
        let t = thread::spawn(move || {
            let mut waiter = MemoryConsumer::new("waiter").register(&pool2);
            let out = waiter.park_grow(50, Duration::from_secs(10), &|| false);
            (out, waiter.size())
        });
        thread::sleep(Duration::from_millis(20));
        holder.shrink(60);
        let (out, size) = t.join().unwrap();
        assert_eq!(out, ParkOutcome::Granted);
        assert_eq!(size, 50);
        assert_eq!(pool.backpressure_waits(), 1);
        assert_eq!(pool.overdrafts(), 0);
    }

    #[test]
    fn park_grow_observes_abort() {
        let pool = MemoryPool::new(10);
        let mut holder = MemoryConsumer::new("holder").register(&pool);
        holder.grow(10);
        let aborted = Arc::new(AtomicBool::new(false));
        let a2 = Arc::clone(&aborted);
        let pool2 = pool.clone();
        let t = thread::spawn(move || {
            let mut w = MemoryConsumer::new("w").register(&pool2);
            w.park_grow(5, Duration::from_secs(30), &|| a2.load(Ordering::Relaxed))
        });
        thread::sleep(Duration::from_millis(10));
        aborted.store(true, Ordering::Relaxed);
        assert_eq!(t.join().unwrap(), ParkOutcome::Aborted);
    }

    #[test]
    fn park_grow_overdrafts_after_wait_budget() {
        let pool = MemoryPool::new(10);
        let mut holder = MemoryConsumer::new("holder").register(&pool);
        holder.grow(10);
        let mut w = MemoryConsumer::new("w").register(&pool);
        let out = w.park_grow(5, Duration::from_millis(10), &|| false);
        assert_eq!(out, ParkOutcome::Overdraft);
        assert_eq!(pool.overdrafts(), 1);
        assert!(pool.reserved_bytes() > pool.budget_bytes());
    }

    #[test]
    fn admission_queues_until_floor_fits() {
        let pool = MemoryPool::new(100);
        let mut holder = MemoryConsumer::new("job-a").register(&pool);
        holder.grow(80);
        let pool2 = pool.clone();
        let t = thread::spawn(move || {
            let res = pool2.admit("job-b", 50, Duration::from_secs(10));
            res.size()
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(pool.admission_waits(), 1);
        holder.shrink(50);
        assert_eq!(t.join().unwrap(), 50);
    }

    #[test]
    fn admission_is_immediate_when_it_fits() {
        let pool = MemoryPool::new(100);
        let res = pool.admit("job", 40, Duration::from_secs(1));
        assert_eq!(res.size(), 40);
        assert_eq!(pool.admission_waits(), 0);
    }

    #[test]
    fn concurrent_growers_never_exceed_budget_without_overdraft() {
        let pool = MemoryPool::new(10_000);
        let mut handles = Vec::new();
        for i in 0..8 {
            let p = pool.clone();
            handles.push(thread::spawn(move || {
                let mut r = MemoryConsumer::new(format!("c{i}")).register(&p);
                for _ in 0..200 {
                    if r.try_grow(64) {
                        assert!(p.reserved_bytes() <= p.budget_bytes());
                        r.shrink(64);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.reserved_bytes(), 0);
        assert!(pool.peak_bytes() <= pool.budget_bytes());
        assert_eq!(pool.overdrafts(), 0);
    }
}
