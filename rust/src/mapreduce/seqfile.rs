//! Sequence files: binary `(String, Vec<String>)` record containers.
//!
//! The paper (§5.1) uses Hadoop's `SequenceFileOutputFormat` with block
//! compression so intermediate job outputs hold `(String, String[])` pairs
//! — "we could directly access the i-th attribute value of an entity during
//! matching" instead of splitting strings at runtime.  This is the same
//! container: length-prefixed binary records, optionally wrapped in a
//! DEFLATE stream (flate2 stands in for the paper's bzip2 codec, which is
//! not in the offline crate set; the ablation bench compares codec on/off
//! rather than codec choice).
//!
//! Format:
//! ```text
//! magic "SNSQ" | u8 version | u8 flags(bit0 = compressed)
//! payload (raw or DEFLATE):
//!   repeated records:
//!     u32 key_len | key utf8 | u32 nvals | nvals × (u32 len | utf8)
//! ```

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt, WriteBytesExt};
use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;

const MAGIC: &[u8; 4] = b"SNSQ";
const VERSION: u8 = 1;

/// One record: a key and its attribute values.
pub type Record = (String, Vec<String>);

/// Serialize records to bytes.
pub fn write_records(records: &[Record], compressed: bool) -> Result<Vec<u8>> {
    let mut payload = Vec::new();
    for (key, vals) in records {
        payload.write_u32::<LittleEndian>(key.len() as u32)?;
        payload.write_all(key.as_bytes())?;
        payload.write_u32::<LittleEndian>(vals.len() as u32)?;
        for v in vals {
            payload.write_u32::<LittleEndian>(v.len() as u32)?;
            payload.write_all(v.as_bytes())?;
        }
    }
    let mut out = Vec::with_capacity(payload.len() / 2 + 16);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(u8::from(compressed));
    if compressed {
        let mut enc = DeflateEncoder::new(&mut out, Compression::fast());
        enc.write_all(&payload)?;
        enc.finish()?;
    } else {
        out.extend_from_slice(&payload);
    }
    Ok(out)
}

/// Deserialize records from bytes.
pub fn read_records(bytes: &[u8]) -> Result<Vec<Record>> {
    if bytes.len() < 6 || &bytes[..4] != MAGIC {
        bail!("not a sequence file (bad magic)");
    }
    if bytes[4] != VERSION {
        bail!("unsupported sequence file version {}", bytes[4]);
    }
    let compressed = bytes[5] & 1 == 1;
    let payload: Vec<u8> = if compressed {
        let mut dec = DeflateDecoder::new(&bytes[6..]);
        let mut p = Vec::new();
        dec.read_to_end(&mut p).context("deflate payload")?;
        p
    } else {
        bytes[6..].to_vec()
    };

    let mut records = Vec::new();
    let mut cur = &payload[..];
    while !cur.is_empty() {
        let klen = cur.read_u32::<LittleEndian>()? as usize;
        let key = take_str(&mut cur, klen)?;
        let nvals = cur.read_u32::<LittleEndian>()? as usize;
        let mut vals = Vec::with_capacity(nvals);
        for _ in 0..nvals {
            let len = cur.read_u32::<LittleEndian>()? as usize;
            vals.push(take_str(&mut cur, len)?);
        }
        records.push((key, vals));
    }
    Ok(records)
}

fn take_str(cur: &mut &[u8], len: usize) -> Result<String> {
    if cur.len() < len {
        bail!("truncated sequence file");
    }
    let (head, rest) = cur.split_at(len);
    *cur = rest;
    Ok(std::str::from_utf8(head)
        .context("invalid utf8 in sequence file")?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Record> {
        vec![
            ("k1".into(), vec!["title one".into(), "abstract one".into()]),
            ("k2".into(), vec![]),
            ("".into(), vec!["only value".into()]),
            ("unicode ü".into(), vec!["véls".into(), "x".into()]),
        ]
    }

    #[test]
    fn roundtrip_uncompressed() {
        let bytes = write_records(&sample(), false).unwrap();
        assert_eq!(read_records(&bytes).unwrap(), sample());
    }

    #[test]
    fn roundtrip_compressed() {
        let bytes = write_records(&sample(), true).unwrap();
        assert_eq!(read_records(&bytes).unwrap(), sample());
    }

    #[test]
    fn compression_shrinks_redundant_data() {
        let records: Vec<Record> = (0..500)
            .map(|i| {
                (
                    format!("key{i}"),
                    vec!["the same repeated abstract text ".repeat(8)],
                )
            })
            .collect();
        let raw = write_records(&records, false).unwrap();
        let comp = write_records(&records, true).unwrap();
        assert!(
            comp.len() * 4 < raw.len(),
            "compressed {} vs raw {}",
            comp.len(),
            raw.len()
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_records(b"nope").is_err());
        assert!(read_records(b"SNSQ\x09\x00rest").is_err());
        // truncated payload
        let mut bytes = write_records(&sample(), false).unwrap();
        bytes.truncate(bytes.len() - 3);
        assert!(read_records(&bytes).is_err());
    }

    #[test]
    fn empty_file_roundtrip() {
        let bytes = write_records(&[], true).unwrap();
        assert!(read_records(&bytes).unwrap().is_empty());
    }
}
