//! Cluster timing simulator — turns *measured* per-task costs into
//! multi-node makespans.
//!
//! Why it exists: the paper's Figure 8 plots execution time and speedup on
//! a 4-node × 2-core Hadoop cluster.  This testbed has a single core, so
//! physical re-execution cannot exhibit >1× parallel speedup.  Instead the
//! engine measures honest per-task wall times (with `workers = 1`, i.e. no
//! interference) and byte counts, and this module schedules those measured
//! tasks onto a simulated cluster with Hadoop's slot semantics:
//!
//! * `nodes × map_slots_per_node` map slots, FIFO task assignment,
//! * map wave → shuffle (network-bound) → reduce wave (same slot logic),
//! * a per-job setup/teardown charge (the overhead that makes JobSN pay
//!   for its second job),
//! * intermediate materialization charged at disk bandwidth (the paper
//!   attributes its sub-linear speedup to exactly this materialization).
//!
//! The simulator is deliberately *not* calibrated to the paper's absolute
//! numbers — DESIGN.md §3 explains the substitution; EXPERIMENTS.md
//! compares the *shapes* (who wins, crossover points).

/// Simulated cluster description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: usize,
    /// Concurrent map tasks per node (paper: 2).
    pub map_slots_per_node: usize,
    /// Concurrent reduce tasks per node (paper: 2).
    pub reduce_slots_per_node: usize,
    /// Per-job fixed setup+teardown seconds (Hadoop 0.20 job scheduling
    /// overhead; the JobSN-vs-RepSN differentiator).
    pub job_setup_s: f64,
    /// Aggregate network bandwidth per node for shuffle, bytes/s.
    pub net_bytes_per_s: f64,
    /// Disk bandwidth per node for intermediate materialization, bytes/s.
    pub disk_bytes_per_s: f64,
}

impl ClusterSpec {
    /// A cluster like the paper's: `cores` total cores, 2 cores per node,
    /// 2 map + 2 reduce slots per node, GbE network, one SATA disk.
    pub fn paper_like(cores: usize) -> Self {
        let nodes = cores.div_ceil(2).max(1);
        let slots = if cores == 1 { 1 } else { 2 };
        Self {
            nodes,
            map_slots_per_node: slots,
            reduce_slots_per_node: slots,
            job_setup_s: 6.0,
            net_bytes_per_s: 110e6,  // ~GbE effective
            disk_bytes_per_s: 80e6,  // 2007-era SATA sequential
        }
    }

    pub fn map_slots(&self) -> usize {
        self.nodes * self.map_slots_per_node
    }

    pub fn reduce_slots(&self) -> usize {
        self.nodes * self.reduce_slots_per_node
    }
}

/// Measured inputs for one job (taken from `JobStats` of a `workers = 1`
/// engine run, so task times are interference-free).
#[derive(Debug, Clone)]
pub struct JobProfile {
    pub map_task_secs: Vec<f64>,
    pub reduce_task_secs: Vec<f64>,
    pub shuffle_bytes_per_reducer: Vec<u64>,
    /// Total map-output bytes (materialized to local disk before shuffle).
    pub map_output_bytes: u64,
}

impl JobProfile {
    pub fn from_stats(stats: &crate::mapreduce::engine::JobStats, map_output_bytes: u64) -> Self {
        Self {
            map_task_secs: stats.map_task_secs.clone(),
            reduce_task_secs: stats.reduce_task_secs.clone(),
            shuffle_bytes_per_reducer: stats.shuffle_bytes_per_reducer.clone(),
            map_output_bytes,
        }
    }
}

/// Per-phase simulated times.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimBreakdown {
    pub setup_s: f64,
    pub map_s: f64,
    pub materialize_s: f64,
    pub shuffle_s: f64,
    pub reduce_s: f64,
}

impl SimBreakdown {
    pub fn total(&self) -> f64 {
        self.setup_s + self.map_s + self.materialize_s + self.shuffle_s + self.reduce_s
    }
}

/// FIFO list scheduling: assign tasks in index order to the earliest-free
/// slot; returns the makespan.  This is Hadoop's FIFO scheduler with
/// speculative execution off (as configured in §5.1).
pub fn list_schedule_makespan(durations: &[f64], slots: usize) -> f64 {
    assert!(slots >= 1);
    if durations.is_empty() {
        return 0.0;
    }
    let mut free_at = vec![0.0f64; slots.min(durations.len())];
    for &d in durations {
        // earliest-free slot
        let (idx, _) = free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        free_at[idx] += d;
    }
    free_at.iter().cloned().fold(0.0, f64::max)
}

/// Simulate one MapReduce job on a cluster.
pub fn simulate_job(profile: &JobProfile, spec: &ClusterSpec) -> SimBreakdown {
    let map_s = list_schedule_makespan(&profile.map_task_secs, spec.map_slots());
    // map outputs written to local disk once (sort spill), read once at
    // shuffle: 2 passes over the bytes at aggregate disk bandwidth
    let disk_agg = spec.disk_bytes_per_s * spec.nodes as f64;
    let materialize_s = 2.0 * profile.map_output_bytes as f64 / disk_agg;
    // shuffle: every reducer pulls its bytes over its node's NIC; reducers
    // run spread over nodes, so the bottleneck is the max per-node inflow
    let reduce_slots = spec.reduce_slots().max(1);
    let mut per_node_bytes = vec![0u64; spec.nodes];
    for (j, &b) in profile.shuffle_bytes_per_reducer.iter().enumerate() {
        per_node_bytes[(j % reduce_slots) % spec.nodes] += b;
    }
    let shuffle_s = per_node_bytes
        .iter()
        .map(|&b| b as f64 / spec.net_bytes_per_s)
        .fold(0.0, f64::max);
    let reduce_s = list_schedule_makespan(&profile.reduce_task_secs, reduce_slots);
    SimBreakdown {
        setup_s: spec.job_setup_s,
        map_s,
        materialize_s,
        shuffle_s,
        reduce_s,
    }
}

/// Simulate a chain of jobs run back-to-back (JobSN = 2 jobs; each pays
/// setup).
pub fn simulate_job_chain(profiles: &[JobProfile], spec: &ClusterSpec) -> (Vec<SimBreakdown>, f64) {
    let parts: Vec<SimBreakdown> = profiles.iter().map(|p| simulate_job(p, spec)).collect();
    let total = parts.iter().map(|p| p.total()).sum();
    (parts, total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn list_schedule_single_slot_is_sum() {
        let d = vec![1.0, 2.0, 3.0];
        assert!((list_schedule_makespan(&d, 1) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn list_schedule_parallel_perfect_split() {
        let d = vec![1.0; 8];
        assert!((list_schedule_makespan(&d, 4) - 2.0).abs() < 1e-9);
        assert!((list_schedule_makespan(&d, 8) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn list_schedule_straggler_dominates() {
        // one huge task: adding slots can't beat it — the skew story of §5.3
        let d = vec![10.0, 1.0, 1.0, 1.0];
        let m = list_schedule_makespan(&d, 4);
        assert!((m - 10.0).abs() < 1e-9);
    }

    #[test]
    fn paper_like_cluster_shapes() {
        let c1 = ClusterSpec::paper_like(1);
        assert_eq!(c1.map_slots(), 1);
        let c8 = ClusterSpec::paper_like(8);
        assert_eq!(c8.nodes, 4);
        assert_eq!(c8.map_slots(), 8);
    }

    #[test]
    fn simulate_speedup_scales_with_cores() {
        // 8 equal map tasks, 8 equal reduce tasks, tiny shuffle
        let profile = JobProfile {
            map_task_secs: vec![10.0; 8],
            reduce_task_secs: vec![10.0; 8],
            shuffle_bytes_per_reducer: vec![1_000_000; 8],
            map_output_bytes: 8_000_000,
        };
        let t1 = simulate_job(&profile, &ClusterSpec::paper_like(1)).total();
        let t8 = simulate_job(&profile, &ClusterSpec::paper_like(8)).total();
        let speedup = t1 / t8;
        assert!(speedup > 4.0, "speedup={speedup}");
        assert!(speedup < 8.0, "setup+shuffle must keep it sub-linear");
    }

    #[test]
    fn second_job_costs_extra_setup() {
        let p = JobProfile {
            map_task_secs: vec![1.0],
            reduce_task_secs: vec![1.0],
            shuffle_bytes_per_reducer: vec![0],
            map_output_bytes: 0,
        };
        let spec = ClusterSpec::paper_like(2);
        let (_, one) = simulate_job_chain(std::slice::from_ref(&p), &spec);
        let (_, two) = simulate_job_chain(&[p.clone(), p], &spec);
        assert!((two - 2.0 * one).abs() < 1e-9);
        assert!(two > one + spec.job_setup_s - 1e-9);
    }

    #[test]
    fn empty_profile_is_setup_only() {
        let p = JobProfile {
            map_task_secs: vec![],
            reduce_task_secs: vec![],
            shuffle_bytes_per_reducer: vec![],
            map_output_bytes: 0,
        };
        let spec = ClusterSpec::paper_like(4);
        let b = simulate_job(&p, &spec);
        assert!((b.total() - spec.job_setup_s).abs() < 1e-9);
    }
}
