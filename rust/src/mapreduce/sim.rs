//! Cluster timing simulator — turns *measured* per-task costs into
//! multi-node makespans.
//!
//! Why it exists: the paper's Figure 8 plots execution time and speedup on
//! a 4-node × 2-core Hadoop cluster.  This testbed has a single core, so
//! physical re-execution cannot exhibit >1× parallel speedup.  Instead the
//! engine measures honest per-task wall times (with `workers = 1`, i.e. no
//! interference) and byte counts, and this module schedules those measured
//! tasks onto a simulated cluster with Hadoop's slot semantics:
//!
//! * `nodes × map_slots_per_node` map slots, FIFO task assignment,
//! * map wave → shuffle (network-bound) → reduce wave (same slot logic),
//! * a per-job setup/teardown charge (the overhead that makes JobSN pay
//!   for its second job),
//! * intermediate materialization charged at disk bandwidth (the paper
//!   attributes its sub-linear speedup to exactly this materialization),
//! * optional **speculative execution** ([`ClusterSpec::speculative`]) and
//!   degraded nodes ([`ClusterSpec::with_slow_nodes`]): the paper turns
//!   speculation off in §5.1, but the engine's
//!   [`scheduler`](crate::mapreduce::scheduler) now implements it, so the
//!   simulator models the same straggler-cloning rule ([`wave_schedule`])
//!   to keep simulated and measured makespans comparable.
//!
//! The simulator is deliberately *not* calibrated to the paper's absolute
//! numbers — DESIGN.md §3 explains the substitution; EXPERIMENTS.md
//! compares the *shapes* (who wins, crossover points).

/// Simulated cluster description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: usize,
    /// Concurrent map tasks per node (paper: 2).
    pub map_slots_per_node: usize,
    /// Concurrent reduce tasks per node (paper: 2).
    pub reduce_slots_per_node: usize,
    /// Per-job fixed setup+teardown seconds (Hadoop 0.20 job scheduling
    /// overhead; the JobSN-vs-RepSN differentiator).
    pub job_setup_s: f64,
    /// Aggregate network bandwidth per node for shuffle, bytes/s.
    pub net_bytes_per_s: f64,
    /// Disk bandwidth per node for intermediate materialization, bytes/s.
    pub disk_bytes_per_s: f64,
    /// Speculative execution (the paper disables it in §5.1; the engine's
    /// [`scheduler`](crate::mapreduce::scheduler) implements it for real —
    /// this is the matching simulator knob).  Stragglers are cloned onto
    /// slots that have drained their primary queue; the earlier completion
    /// wins.  See [`wave_schedule`].
    pub speculative: bool,
    /// Number of degraded nodes (machine skew, the failure mode
    /// speculation actually fixes — as opposed to data skew, which it
    /// cannot; that contrast is the point of the Fig. 9 speculation
    /// sweep).  0 = homogeneous cluster, the paper's setup.
    pub slow_nodes: usize,
    /// Runtime multiplier for tasks placed on a slow node (≥ 1).
    pub slow_node_factor: f64,
    /// Fraction of task attempts that crash and are re-executed — the
    /// simulator's charge for the engine's bounded-retry fault tolerance
    /// ([`JobConfig::max_task_retries`](super::JobConfig::max_task_retries)).
    /// Deterministic: every `⌊1/rate⌋`-th task of a wave runs twice (the
    /// failed attempt is paid in full before the rerun, Hadoop's
    /// worst-case re-execution).  `0.0` (the paper's implicit setup —
    /// no failures during the measured runs) charges nothing.
    pub task_failure_rate: f64,
    /// Calibrated map-task rate: measured map-task durations are stretched
    /// by this factor before scheduling, absorbing the per-task overhead
    /// (scheduling, spill writes, push bookkeeping) that sits in the
    /// measured map *phase* wall time but outside the task timers.  `1.0`
    /// (the default) reproduces the uncalibrated model exactly; fitted by
    /// [`ClusterSpec::fit_from_stats`].
    pub map_secs_scale: f64,
    /// Calibrated reduce-task rate (see [`ClusterSpec::map_secs_scale`]).
    pub reduce_secs_scale: f64,
    /// Calibrated (de)compression CPU rate: multiplier on the profile's
    /// DEFLATE charges.  The shuffle-row fit scales CPU and bandwidth by
    /// the same factor — one observable per job cannot separate them, so
    /// the fit preserves the row's CPU-vs-bytes mix.  `1.0` by default.
    pub shuffle_cpu_scale: f64,
    /// Per-executor network links for the distributed control plane
    /// ([`DistScheduler`](crate::mapreduce::scheduler::DistScheduler)):
    /// when > 0, reducer `j`'s shuffle bytes flow over link `j % links`
    /// (matching the dist scheduler's round-robin reduce placement) and
    /// the shuffle bottleneck is the most-loaded *link* rather than the
    /// most-loaded node NIC.  `0` keeps the legacy per-node model so
    /// existing calibrations stay bit-identical.
    pub executor_links: usize,
    /// Memory-pool byte budget per job, the simulator counterpart of
    /// [`MemoryPool`](crate::mapreduce::memory::MemoryPool): when the
    /// job's in-memory working set (its map-output bytes) exceeds this
    /// budget, the overflow is forced through disk — written once when a
    /// reservation is denied and read back at reduce — and charged as
    /// extra spill volume on the materialize row.  Runs that already
    /// spill everything ([`JobProfile::spill_bytes_written`] > 0) pay
    /// nothing extra: their intermediates are on disk regardless of the
    /// pool.  `0` (the default) models an unlimited pool and is strictly
    /// zero-cost — every breakdown stays bit-identical.
    pub memory_pool_bytes: u64,
}

impl ClusterSpec {
    /// A cluster like the paper's: `cores` total cores, 2 cores per node,
    /// 2 map + 2 reduce slots per node, GbE network, one SATA disk,
    /// speculation off (§5.1), no degraded nodes.
    pub fn paper_like(cores: usize) -> Self {
        let nodes = cores.div_ceil(2).max(1);
        let slots = if cores == 1 { 1 } else { 2 };
        Self {
            nodes,
            map_slots_per_node: slots,
            reduce_slots_per_node: slots,
            job_setup_s: 6.0,
            net_bytes_per_s: 110e6,  // ~GbE effective
            disk_bytes_per_s: 80e6,  // 2007-era SATA sequential
            speculative: false,
            slow_nodes: 0,
            slow_node_factor: 1.0,
            task_failure_rate: 0.0,
            map_secs_scale: 1.0,
            reduce_secs_scale: 1.0,
            shuffle_cpu_scale: 1.0,
            executor_links: 0,
            memory_pool_bytes: 0,
        }
    }

    /// Model `n` distributed executors, each with its own network link
    /// (see [`ClusterSpec::executor_links`]); `0` restores the legacy
    /// per-node shuffle model.
    pub fn with_executor_links(mut self, n: usize) -> Self {
        self.executor_links = n;
        self
    }

    /// Cap the modeled in-memory working set at `bytes` (see
    /// [`ClusterSpec::memory_pool_bytes`]); `0` restores the unlimited
    /// (zero-cost) model.
    pub fn with_memory_pool_bytes(mut self, bytes: u64) -> Self {
        self.memory_pool_bytes = bytes;
        self
    }

    /// Toggle speculative execution.
    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculative = on;
        self
    }

    /// Degrade `n` nodes to run their tasks `factor`× slower.
    pub fn with_slow_nodes(mut self, n: usize, factor: f64) -> Self {
        assert!(factor >= 1.0, "slow nodes cannot be faster");
        self.slow_nodes = n.min(self.nodes);
        self.slow_node_factor = factor;
        self
    }

    /// Crash-and-reexecute `rate` of all task attempts (see
    /// [`ClusterSpec::task_failure_rate`]).
    pub fn with_failures(mut self, rate: f64) -> Self {
        assert!((0.0..1.0).contains(&rate), "failure rate must be in [0, 1)");
        self.task_failure_rate = rate;
        self
    }

    pub fn map_slots(&self) -> usize {
        self.nodes * self.map_slots_per_node
    }

    pub fn reduce_slots(&self) -> usize {
        self.nodes * self.reduce_slots_per_node
    }

    /// Calibrate a single-node spec against measured jobs — the
    /// generalization of [`fit_secs_per_pair`] from one per-pair cost to
    /// the full phase cost model.  Starting from
    /// [`ClusterSpec::paper_like`]`(1)` (the testbed the engine measures
    /// on), three groups of rates are fitted so [`drift_report`] on the
    /// returned spec tracks the measured phases instead of the 2007-era
    /// defaults:
    ///
    /// * **map / reduce task rates** ([`ClusterSpec::map_secs_scale`] /
    ///   [`ClusterSpec::reduce_secs_scale`]): measured phase wall seconds
    ///   over the summed task seconds.  When a job carries no per-task
    ///   vector the task-duration *histograms*
    ///   ([`JobStats::map_task_us_hist`]) stand in — `mean × count`, the
    ///   same total, which is all the ratio needs.
    /// * **shuffle bandwidth + compression CPU**: the default-spec
    ///   shuffle row (materialize + compress + network + decompress) is
    ///   compared against the measured shuffle wall stamps, and the
    ///   single common factor `λ = measured / simulated` is applied to
    ///   the CPU charges ([`ClusterSpec::shuffle_cpu_scale`]) while the
    ///   disk and network bandwidths divide by it — one observable per
    ///   job cannot separate CPU from byte movement, so the fit
    ///   preserves the row's internal mix.
    ///
    /// Phases that measured zero (or have no work) keep their default
    /// rates, and every fitted factor is clamped to `[1e-3, 1e3]` so a
    /// degenerate sample cannot produce a nonsensical cluster.  Fitting
    /// over several jobs pools their totals (volume-weighted, like
    /// [`fit_secs_per_pair`]).
    ///
    /// [`JobStats::map_task_us_hist`]:
    ///     crate::mapreduce::engine::JobStats::map_task_us_hist
    pub fn fit_from_stats(stats: &[crate::mapreduce::engine::JobStats]) -> ClusterSpec {
        let mut spec = ClusterSpec::paper_like(1);
        if stats.is_empty() {
            return spec;
        }
        let clamp = |v: f64| v.clamp(1e-3, 1e3);
        // Summed task seconds, falling back to the duration histogram
        // (µs) when the per-task vector is absent.
        fn task_sum(secs: &[f64], hist: &crate::metrics::histogram::Histogram) -> f64 {
            if secs.is_empty() && hist.count() > 0 {
                hist.mean() * hist.count() as f64 / 1e6
            } else {
                secs.iter().sum()
            }
        }
        let (mut map_tasks, mut map_meas) = (0.0f64, 0.0f64);
        let (mut red_tasks, mut red_meas) = (0.0f64, 0.0f64);
        for s in stats {
            map_tasks += task_sum(&s.map_task_secs, &s.map_task_us_hist);
            map_meas += s.map_phase_secs;
            red_tasks += task_sum(&s.reduce_task_secs, &s.reduce_task_us_hist);
            red_meas += s.reduce_phase_secs;
        }
        if map_tasks > 0.0 && map_meas > 0.0 {
            spec.map_secs_scale = clamp(map_meas / map_tasks);
        }
        if red_tasks > 0.0 && red_meas > 0.0 {
            spec.reduce_secs_scale = clamp(red_meas / red_tasks);
        }
        // Shuffle row: simulate each job's row on the *pristine* default
        // spec and scale the whole row onto the measured wall stamps.
        let pristine = ClusterSpec::paper_like(1);
        let (mut row_sim, mut row_meas) = (0.0f64, 0.0f64);
        for s in stats {
            // in-process runs don't report a separate map-output volume;
            // the shuffled bytes are the same records, so they stand in
            let bytes: u64 = s.shuffle_bytes_per_reducer.iter().sum();
            let profile = JobProfile::from_stats(s, bytes);
            let sim = simulate_job_mode(&profile, &pristine, SimShuffleMode::TwoWave);
            row_sim += sim.materialize_s + sim.compress_s + sim.shuffle_s + sim.decompress_s;
            row_meas += s.shuffle_phase_secs;
        }
        if row_sim > 0.0 && row_meas > 0.0 {
            let lambda = clamp(row_meas / row_sim);
            spec.shuffle_cpu_scale = lambda;
            spec.disk_bytes_per_s /= lambda;
            spec.net_bytes_per_s /= lambda;
        }
        spec
    }
}

/// DEFLATE (`Compression::fast`) throughput on 2007-era cluster cores,
/// as seconds per raw megabyte — the CPU price the simulator charges for
/// compressed intermediates ([`JobProfile::compress_secs_per_mb`]).
/// Compression is the expensive side; inflate runs ~3× faster.
pub const DEFLATE_COMPRESS_SECS_PER_MB: f64 = 1.0 / 90.0;
pub const DEFLATE_DECOMPRESS_SECS_PER_MB: f64 = 1.0 / 250.0;

/// Measured inputs for one job (taken from `JobStats` of a `workers = 1`
/// engine run, so task times are interference-free).
#[derive(Debug, Clone, Default)]
pub struct JobProfile {
    pub map_task_secs: Vec<f64>,
    pub reduce_task_secs: Vec<f64>,
    /// Per-reducer intermediate bytes as shuffled over the network —
    /// compressed bytes when the engine ran with a compressing spill spec
    /// (the paper's cluster config reports compressed volumes too).
    pub shuffle_bytes_per_reducer: Vec<u64>,
    /// Total map-output bytes (materialized to local disk before shuffle).
    pub map_output_bytes: u64,
    /// Bytes the engine actually wrote to spill run files (0 when the run
    /// kept its intermediates in memory).  When set, this — the measured
    /// on-disk volume, compressed or not — is the materialization basis
    /// instead of the `map_output_bytes` estimate.
    pub spill_bytes_written: u64,
    /// Pre-compression intermediate bytes — the volume the (de)compression
    /// CPU charges apply to.  0 disables both charges.
    pub shuffle_bytes_raw: u64,
    /// CPU seconds per raw MB spent compressing map-side (0 = uncompressed
    /// intermediates).
    pub compress_secs_per_mb: f64,
    /// CPU seconds per raw MB spent inflating reduce-side.
    pub decompress_secs_per_mb: f64,
}

impl JobProfile {
    /// Build from measured engine stats.  When the run spilled compressed
    /// intermediates, the DEFLATE rate constants are charged; the
    /// CPU-vs-network trade (smaller `shuffle_bytes_per_reducer`, added
    /// compress/decompress seconds) is then visible in [`simulate_job`].
    pub fn from_stats(stats: &crate::mapreduce::engine::JobStats, map_output_bytes: u64) -> Self {
        let (compress, decompress) = if stats.intermediate_compressed {
            (DEFLATE_COMPRESS_SECS_PER_MB, DEFLATE_DECOMPRESS_SECS_PER_MB)
        } else {
            (0.0, 0.0)
        };
        Self {
            map_task_secs: stats.map_task_secs.clone(),
            reduce_task_secs: stats.reduce_task_secs.clone(),
            shuffle_bytes_per_reducer: stats.shuffle_bytes_per_reducer.clone(),
            map_output_bytes,
            spill_bytes_written: stats.spill_bytes_written,
            shuffle_bytes_raw: stats.shuffle_bytes_raw,
            compress_secs_per_mb: compress,
            decompress_secs_per_mb: decompress,
        }
    }
}

/// Per-phase simulated times.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimBreakdown {
    pub setup_s: f64,
    pub map_s: f64,
    pub materialize_s: f64,
    /// Map-side DEFLATE CPU over the raw intermediate volume, spread over
    /// the map slots (0 for uncompressed intermediates).
    pub compress_s: f64,
    pub shuffle_s: f64,
    /// Reduce-side inflate CPU, spread over the reduce slots.
    pub decompress_s: f64,
    pub reduce_s: f64,
    /// Speculative clones launched / won across both waves (0 with the
    /// `speculative` knob off).
    pub speculative_launched: u64,
    pub speculative_won: u64,
}

impl SimBreakdown {
    pub fn total(&self) -> f64 {
        self.setup_s
            + self.map_s
            + self.materialize_s
            + self.compress_s
            + self.shuffle_s
            + self.decompress_s
            + self.reduce_s
    }
}

/// FIFO list scheduling: assign tasks in index order to the earliest-free
/// slot; returns the makespan.  This is Hadoop's FIFO scheduler on a
/// homogeneous cluster with speculative execution off — the exact §5.1
/// configuration.  [`wave_schedule`] generalizes it with the
/// [`ClusterSpec::speculative`] and slow-node knobs.
pub fn list_schedule_makespan(durations: &[f64], slots: usize) -> f64 {
    assert!(slots >= 1);
    if durations.is_empty() {
        return 0.0;
    }
    let mut free_at = vec![0.0f64; slots.min(durations.len())];
    for &d in durations {
        // earliest-free slot
        let (idx, _) = free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        free_at[idx] += d;
    }
    free_at.iter().cloned().fold(0.0, f64::max)
}

/// Straggler thresholds, matching the runtime scheduler's
/// [`SpecPolicy`](crate::mapreduce::scheduler::SpecPolicy) defaults so
/// simulated and measured speculation behave alike.
pub const SPEC_SLOWDOWN: f64 = 1.5;
pub const SPEC_MIN_SECS: f64 = 0.02;

/// One scheduled wave's outcome.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WaveOutcome {
    pub makespan: f64,
    /// When the wave's *first* task completed — the earliest moment a
    /// push-based shuffle has a run to hand a reducer
    /// ([`simulate_job_overlap`] releases the reduce wave here).
    pub first_completion: f64,
    pub speculative_launched: u64,
    pub speculative_won: u64,
}

/// Slot scheduling with the full cluster model.
///
/// Primary assignment is FIFO to the earliest-free slot (identical to
/// [`list_schedule_makespan`]); slot `s` lives on node `s % nodes`, and
/// slots on the first [`ClusterSpec::slow_nodes`] nodes stretch their
/// tasks by [`ClusterSpec::slow_node_factor`].  With
/// [`ClusterSpec::speculative`] on, whenever a slot has drained its
/// primary queue it clones the longest-remaining running task whose
/// elapsed time exceeds `max(SPEC_MIN_SECS, SPEC_SLOWDOWN × running
/// median of completed task durations)` — the same rule as the runtime
/// detector; the clone re-runs the task from scratch at the idle slot's
/// speed and the earlier completion wins — which is why speculation
/// rescues *machine*-skew stragglers (slow node, fast clone elsewhere)
/// but cannot beat *data*-skew stragglers (the clone re-processes the
/// same oversized partition).  Each task is cloned at most once,
/// mirroring the runtime scheduler.
pub fn wave_schedule(durations: &[f64], slots: usize, spec: &ClusterSpec) -> WaveOutcome {
    assert!(slots >= 1);
    if durations.is_empty() {
        return WaveOutcome::default();
    }
    let nodes = spec.nodes.max(1);
    let speed = |s: usize| {
        if (s % nodes) < spec.slow_nodes {
            spec.slow_node_factor.max(1.0)
        } else {
            1.0
        }
    };
    let argmin = |free_at: &[f64]| -> (usize, f64) {
        let (idx, t) = free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        (idx, *t)
    };
    struct Run {
        start: f64,
        dur: f64,
        end: f64,
        cloned: bool,
    }
    // Deterministic failure charge: every `period`-th task's attempt
    // crashes at the end of its run and is re-executed from scratch on
    // the same slot, doubling its occupancy (the engine's bounded-retry
    // recovery, at its worst-case cost).
    let fail_period = (spec.task_failure_rate > 0.0)
        .then(|| ((1.0 / spec.task_failure_rate).round() as usize).max(1));
    let mut free_at = vec![0.0f64; slots.min(durations.len())];
    let mut runs: Vec<Run> = Vec::with_capacity(durations.len());
    for (i, &d) in durations.iter().enumerate() {
        let d = match fail_period {
            Some(p) if (i + 1) % p == 0 => d * 2.0,
            _ => d,
        };
        let (s, t) = argmin(&free_at);
        let end = t + d * speed(s);
        free_at[s] = end;
        runs.push(Run {
            start: t,
            dur: d,
            end,
            cloned: false,
        });
    }
    let mut launched = 0u64;
    let mut won = 0u64;
    if spec.speculative {
        loop {
            let makespan = runs.iter().fold(0.0f64, |m, r| m.max(r.end));
            let (s, now) = argmin(&free_at);
            if now >= makespan {
                break; // every slot is busy until the wave ends
            }
            // The runtime detector thresholds against the *running* median
            // of completed task durations, not the full-wave median (which
            // would let a majority of stragglers raise the bar above their
            // own runtimes) — recompute it at every scheduling decision.
            let mut done: Vec<f64> = runs
                .iter()
                .filter(|r| r.end <= now)
                .map(|r| r.end - r.start)
                .collect();
            if done.is_empty() {
                // no baseline yet: idle until the first completion
                let next_done = runs
                    .iter()
                    .filter(|r| r.end > now)
                    .map(|r| r.end)
                    .fold(f64::INFINITY, f64::min);
                if next_done.is_finite() && next_done < makespan {
                    free_at[s] = next_done;
                    continue;
                }
                break;
            }
            done.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = done[done.len() / 2];
            let threshold = SPEC_MIN_SECS.max(SPEC_SLOWDOWN * median);
            // longest-remaining straggler already eligible at `now`, plus
            // the earliest future time any task becomes eligible (under
            // the current threshold; it is re-derived next iteration)
            let mut best: Option<usize> = None;
            let mut next_eligible = f64::INFINITY;
            for (i, r) in runs.iter().enumerate() {
                if r.cloned || r.end <= now {
                    continue;
                }
                let eligible_at = r.start + threshold;
                if eligible_at >= r.end {
                    continue; // finishes before ever qualifying
                }
                if eligible_at <= now {
                    let longer = match best {
                        None => true,
                        Some(b) => runs[b].end < r.end,
                    };
                    if longer {
                        best = Some(i);
                    }
                } else {
                    next_eligible = next_eligible.min(eligible_at);
                }
            }
            match best {
                Some(i) => {
                    let clone_end = now + runs[i].dur * speed(s);
                    runs[i].cloned = true;
                    launched += 1;
                    if clone_end < runs[i].end {
                        runs[i].end = clone_end;
                        won += 1;
                    }
                    // the slot is held until the task is decided (the
                    // losing attempt is killed at that point)
                    free_at[s] = runs[i].end;
                }
                None => {
                    if next_eligible.is_finite() && next_eligible < makespan {
                        free_at[s] = next_eligible; // idle until one qualifies
                    } else {
                        break;
                    }
                }
            }
        }
    }
    WaveOutcome {
        makespan: runs.iter().fold(0.0f64, |m, r| m.max(r.end)),
        first_completion: runs.iter().fold(f64::INFINITY, |m, r| m.min(r.end)),
        speculative_launched: launched,
        speculative_won: won,
    }
}

/// Per-pair reduce cost model, matching the `sn::loadbalance` strategies'
/// planning unit: a reduce task's runtime is `pairs × secs_per_pair`, so a
/// repartitioning plan's per-task pair counts (or a measured job's
/// `JobStats::reduce_task_output_records`) induce predicted task
/// durations that [`wave_schedule`] can turn into a makespan — before the
/// balanced job ever runs, and with the *same* cost model the simulator
/// charges the measured run, so simulated and predicted makespans stay
/// comparable.
pub fn reduce_secs_from_pairs(pairs_per_task: &[u64], secs_per_pair: f64) -> Vec<f64> {
    pairs_per_task
        .iter()
        .map(|&p| p as f64 * secs_per_pair)
        .collect()
}

/// Calibrate the per-pair cost from a measured job: total reduce seconds
/// over total pairs (0 when no pairs were produced).
pub fn fit_secs_per_pair(reduce_task_secs: &[f64], pairs_per_task: &[u64]) -> f64 {
    let total: u64 = pairs_per_task.iter().sum();
    if total == 0 {
        return 0.0;
    }
    reduce_task_secs.iter().sum::<f64>() / total as f64
}

/// Phase-structure mode for [`simulate_job_mode`]: the paper's two-wave
/// barrier (kept as the calibration reference) or the push-based overlap
/// model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimShuffleMode {
    /// Reduce wave starts after the whole map wave (Hadoop 0.20).
    #[default]
    TwoWave,
    /// Push-based shuffle: the reduce wave is *released* at the first
    /// map-task completion and overlaps the map wave; no reduce task can
    /// complete before the map wave ends (its last inputs arrive then).
    /// Structurally never slower than [`SimShuffleMode::TwoWave`] on the
    /// same profile.
    Overlap,
}

/// Simulate one MapReduce job on a cluster.
///
/// With a compressed-intermediates profile
/// ([`JobProfile::compress_secs_per_mb`] > 0) the model exposes the
/// CPU-vs-network trade: `shuffle_bytes_per_reducer` are already the
/// smaller compressed volumes, and the raw volume is charged once at the
/// compress rate across the map slots and once at the decompress rate
/// across the reduce slots.
pub fn simulate_job(profile: &JobProfile, spec: &ClusterSpec) -> SimBreakdown {
    simulate_job_mode(profile, spec, SimShuffleMode::TwoWave)
}

/// As [`simulate_job`] with the push-based shuffle's overlapped phase
/// structure ([`SimShuffleMode::Overlap`]): `map_s` is unchanged and
/// `reduce_s` becomes the reduce wave's *tail* past the map wave, so
/// `total()` directly compares against the barrier total.
pub fn simulate_job_overlap(profile: &JobProfile, spec: &ClusterSpec) -> SimBreakdown {
    simulate_job_mode(profile, spec, SimShuffleMode::Overlap)
}

/// Stretch measured task durations by a calibrated rate; borrows when the
/// rate is the identity so the uncalibrated path stays allocation-free.
fn scaled_secs(secs: &[f64], scale: f64) -> std::borrow::Cow<'_, [f64]> {
    if scale == 1.0 {
        std::borrow::Cow::Borrowed(secs)
    } else {
        std::borrow::Cow::Owned(secs.iter().map(|s| s * scale).collect())
    }
}

/// The mode-parameterized simulator core behind [`simulate_job`] /
/// [`simulate_job_overlap`].
pub fn simulate_job_mode(
    profile: &JobProfile,
    spec: &ClusterSpec,
    mode: SimShuffleMode,
) -> SimBreakdown {
    let map_secs = scaled_secs(&profile.map_task_secs, spec.map_secs_scale);
    let map_wave = wave_schedule(&map_secs, spec.map_slots().max(1), spec);
    // map outputs written to local disk once (sort spill), read once at
    // shuffle: 2 passes over the bytes at aggregate disk bandwidth.  A
    // disk-backed run reports the bytes it *actually* wrote (compressed
    // or not); otherwise the size estimate stands in.
    let disk_agg = spec.disk_bytes_per_s * spec.nodes as f64;
    let materialized_bytes = if profile.spill_bytes_written > 0 {
        profile.spill_bytes_written
    } else {
        profile.map_output_bytes
    };
    // A finite memory pool forces the working-set overflow through disk:
    // denied reservations divert runs that would otherwise stay resident
    // (one write when denied, one read-back at reduce).  Fully spilled
    // runs already pay the materialize row for every byte; pool = 0 is
    // the unlimited model and charges nothing.
    let pool_overflow_bytes = if spec.memory_pool_bytes > 0 && profile.spill_bytes_written == 0 {
        profile.map_output_bytes.saturating_sub(spec.memory_pool_bytes)
    } else {
        0
    };
    let materialize_s = 2.0 * (materialized_bytes + pool_overflow_bytes) as f64 / disk_agg;
    // (de)compression CPU: DEFLATE runs on the same cores as the tasks,
    // parallel across slots, so the wall charge is volume / slots
    let raw_mb = profile.shuffle_bytes_raw as f64 / 1e6;
    let compress_s = raw_mb * profile.compress_secs_per_mb * spec.shuffle_cpu_scale
        / spec.map_slots().max(1) as f64;
    // shuffle: every reducer pulls its bytes over its node's NIC; reducers
    // run spread over nodes, so the bottleneck is the max per-node inflow.
    // With executor_links > 0 the topology is the dist scheduler's
    // instead: reducer j lands on executor j % links (its round-robin
    // placement) and the bottleneck is the most-loaded executor link.
    let reduce_slots = spec.reduce_slots().max(1);
    let shuffle_s = if spec.executor_links > 0 {
        let links = spec.executor_links;
        let mut per_link_bytes = vec![0u64; links];
        for (j, &b) in profile.shuffle_bytes_per_reducer.iter().enumerate() {
            per_link_bytes[j % links] += b;
        }
        per_link_bytes
            .iter()
            .map(|&b| b as f64 / spec.net_bytes_per_s)
            .fold(0.0, f64::max)
    } else {
        let mut per_node_bytes = vec![0u64; spec.nodes];
        for (j, &b) in profile.shuffle_bytes_per_reducer.iter().enumerate() {
            per_node_bytes[(j % reduce_slots) % spec.nodes] += b;
        }
        per_node_bytes
            .iter()
            .map(|&b| b as f64 / spec.net_bytes_per_s)
            .fold(0.0, f64::max)
    };
    let decompress_s =
        raw_mb * profile.decompress_secs_per_mb * spec.shuffle_cpu_scale / reduce_slots as f64;
    let reduce_secs = scaled_secs(&profile.reduce_task_secs, spec.reduce_secs_scale);
    let reduce_wave = wave_schedule(&reduce_secs, reduce_slots, spec);
    let reduce_s = match mode {
        SimShuffleMode::TwoWave => reduce_wave.makespan,
        SimShuffleMode::Overlap => {
            // the reduce wave runs from the first map completion onward,
            // but its last task cannot finish before the map wave does —
            // the tail past the map wave is what the job still pays.
            // release ≤ map makespan ⇒ tail ≤ the two-wave reduce_s.
            let release = if profile.map_task_secs.is_empty() {
                0.0
            } else {
                map_wave.first_completion
            };
            let combined = (release + reduce_wave.makespan).max(map_wave.makespan);
            combined - map_wave.makespan
        }
    };
    SimBreakdown {
        setup_s: spec.job_setup_s,
        map_s: map_wave.makespan,
        materialize_s,
        compress_s,
        shuffle_s,
        decompress_s,
        reduce_s,
        speculative_launched: map_wave.speculative_launched + reduce_wave.speculative_launched,
        speculative_won: map_wave.speculative_won + reduce_wave.speculative_won,
    }
}

/// Simulate a chain of jobs run back-to-back (JobSN = 2 jobs; each pays
/// setup).
pub fn simulate_job_chain(profiles: &[JobProfile], spec: &ClusterSpec) -> (Vec<SimBreakdown>, f64) {
    let parts: Vec<SimBreakdown> = profiles.iter().map(|p| simulate_job(p, spec)).collect();
    let total = parts.iter().map(|p| p.total()).sum();
    (parts, total)
}

/// One phase's simulated-vs-measured comparison inside a [`DriftReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct WaveDrift {
    /// Phase label: `"map"`, `"shuffle"`, or `"reduce"`.
    pub wave: &'static str,
    /// Wall seconds the engine measured for the phase.
    pub measured_s: f64,
    /// Wall seconds the simulator predicts for the same phase on `spec`.
    pub simulated_s: f64,
}

impl WaveDrift {
    /// Signed prediction error, `simulated - measured` seconds.
    pub fn delta_s(&self) -> f64 {
        self.simulated_s - self.measured_s
    }

    /// Relative drift `|simulated - measured| / measured`; 0 when the
    /// phase measured 0 s (nothing to be wrong about).
    pub fn drift_frac(&self) -> f64 {
        if self.measured_s <= 0.0 {
            0.0
        } else {
            (self.simulated_s - self.measured_s).abs() / self.measured_s
        }
    }
}

/// Simulated-vs-measured drift for one job: the engine's measured
/// [`JobStats`](crate::mapreduce::engine::JobStats) phase timings next to
/// what [`simulate_job_mode`] predicts when the *same* per-task profile is
/// scheduled on `spec` — per-wave deltas plus totals.  Built by
/// [`drift_report`]; serialized into `BENCH_engine.json` by the engine
/// ablation bench and rendered by `examples/skew_study`.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftReport {
    /// Phase structure the simulator used — [`SimShuffleMode::Overlap`]
    /// when the measured job overlapped its waves (push shuffle),
    /// [`SimShuffleMode::TwoWave`] otherwise.
    pub mode: SimShuffleMode,
    /// Per-phase rows, in `map`, `shuffle`, `reduce` order.
    pub waves: Vec<WaveDrift>,
    /// Measured end-to-end seconds ([`JobStats::total_secs`]).
    ///
    /// [`JobStats::total_secs`]: crate::mapreduce::engine::JobStats::total_secs
    pub measured_total_s: f64,
    /// Simulated end-to-end seconds, **excluding** the cluster's
    /// [`ClusterSpec::job_setup_s`] charge — the in-process engine pays no
    /// job-scheduling overhead, so including it would be pure bias.
    pub simulated_total_s: f64,
}

impl DriftReport {
    /// The worst per-wave relative drift — the headline number the bench
    /// gate tracks.
    pub fn max_drift_frac(&self) -> f64 {
        self.waves.iter().map(WaveDrift::drift_frac).fold(0.0, f64::max)
    }

    /// Mean absolute per-wave prediction error in *seconds* — the
    /// calibration objective [`ClusterSpec::fit_from_stats`] minimizes.
    /// Unlike [`DriftReport::max_drift_frac`] it stays meaningful for
    /// phases that measured ~0 s (where any prediction yields a 0 or
    /// huge *fraction*), which is exactly where the uncalibrated spec's
    /// disk/network charges show up.
    pub fn mean_abs_delta_s(&self) -> f64 {
        if self.waves.is_empty() {
            return 0.0;
        }
        self.waves.iter().map(|w| w.delta_s().abs()).sum::<f64>() / self.waves.len() as f64
    }

    /// Compact JSON object for bench artifacts.
    pub fn to_json(&self) -> String {
        let mode = match self.mode {
            SimShuffleMode::TwoWave => "two_wave",
            SimShuffleMode::Overlap => "overlap",
        };
        let waves: Vec<String> = self
            .waves
            .iter()
            .map(|w| {
                format!(
                    "{{\"wave\":\"{}\",\"measured_s\":{:.6},\"simulated_s\":{:.6},\"delta_s\":{:.6},\"drift_frac\":{:.6}}}",
                    w.wave,
                    w.measured_s,
                    w.simulated_s,
                    w.delta_s(),
                    w.drift_frac()
                )
            })
            .collect();
        format!(
            "{{\"mode\":\"{}\",\"measured_total_s\":{:.6},\"simulated_total_s\":{:.6},\"max_drift_frac\":{:.6},\"mean_abs_delta_s\":{:.6},\"waves\":[{}]}}",
            mode,
            self.measured_total_s,
            self.simulated_total_s,
            self.max_drift_frac(),
            self.mean_abs_delta_s(),
            waves.join(",")
        )
    }

    /// Human-readable drift table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "sim-vs-measured drift ({})\n",
            match self.mode {
                SimShuffleMode::TwoWave => "two-wave",
                SimShuffleMode::Overlap => "overlap",
            }
        ));
        out.push_str("  wave     measured    simulated   delta       drift\n");
        for w in &self.waves {
            out.push_str(&format!(
                "  {:<8} {:>9.4}s {:>9.4}s {:>+9.4}s {:>6.1}%\n",
                w.wave,
                w.measured_s,
                w.simulated_s,
                w.delta_s(),
                w.drift_frac() * 100.0
            ));
        }
        out.push_str(&format!(
            "  total    {:>9.4}s {:>9.4}s {:>+9.4}s\n",
            self.measured_total_s,
            self.simulated_total_s,
            self.simulated_total_s - self.measured_total_s
        ));
        out
    }
}

/// Run the simulator over a *measured* job and report per-wave drift.
///
/// The profile is taken from `stats` ([`JobProfile::from_stats`]) and
/// scheduled on `spec` in the phase-structure mode the measured job
/// actually ran: [`SimShuffleMode::Overlap`] when
/// `stats.overlap_secs > 0` (push shuffle), the two-wave barrier
/// otherwise.  For the drift to mean anything, `spec`'s slot counts
/// should match the engine's worker count the stats were measured with —
/// drift then isolates the simulator's *cost model* error rather than a
/// parallelism mismatch.
pub fn drift_report(
    stats: &crate::mapreduce::engine::JobStats,
    map_output_bytes: u64,
    spec: &ClusterSpec,
) -> DriftReport {
    let profile = JobProfile::from_stats(stats, map_output_bytes);
    let mode = if stats.overlap_secs > 0.0 {
        SimShuffleMode::Overlap
    } else {
        SimShuffleMode::TwoWave
    };
    let sim = simulate_job_mode(&profile, spec, mode);
    let waves = vec![
        WaveDrift {
            wave: "map",
            measured_s: stats.map_phase_secs,
            simulated_s: sim.map_s,
        },
        WaveDrift {
            wave: "shuffle",
            measured_s: stats.shuffle_phase_secs,
            simulated_s: sim.materialize_s + sim.compress_s + sim.shuffle_s + sim.decompress_s,
        },
        WaveDrift {
            wave: "reduce",
            measured_s: stats.reduce_phase_secs,
            simulated_s: sim.reduce_s,
        },
    ];
    DriftReport {
        mode,
        waves,
        measured_total_s: stats.total_secs,
        simulated_total_s: sim.total() - sim.setup_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn list_schedule_single_slot_is_sum() {
        let d = vec![1.0, 2.0, 3.0];
        assert!((list_schedule_makespan(&d, 1) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn list_schedule_parallel_perfect_split() {
        let d = vec![1.0; 8];
        assert!((list_schedule_makespan(&d, 4) - 2.0).abs() < 1e-9);
        assert!((list_schedule_makespan(&d, 8) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn list_schedule_straggler_dominates() {
        // one huge task: adding slots can't beat it — the skew story of §5.3
        let d = vec![10.0, 1.0, 1.0, 1.0];
        let m = list_schedule_makespan(&d, 4);
        assert!((m - 10.0).abs() < 1e-9);
    }

    #[test]
    fn paper_like_cluster_shapes() {
        let c1 = ClusterSpec::paper_like(1);
        assert_eq!(c1.map_slots(), 1);
        let c8 = ClusterSpec::paper_like(8);
        assert_eq!(c8.nodes, 4);
        assert_eq!(c8.map_slots(), 8);
    }

    #[test]
    fn simulate_speedup_scales_with_cores() {
        // 8 equal map tasks, 8 equal reduce tasks, tiny shuffle
        let profile = JobProfile {
            map_task_secs: vec![10.0; 8],
            reduce_task_secs: vec![10.0; 8],
            shuffle_bytes_per_reducer: vec![1_000_000; 8],
            map_output_bytes: 8_000_000,
            ..Default::default()
        };
        let t1 = simulate_job(&profile, &ClusterSpec::paper_like(1)).total();
        let t8 = simulate_job(&profile, &ClusterSpec::paper_like(8)).total();
        let speedup = t1 / t8;
        assert!(speedup > 4.0, "speedup={speedup}");
        assert!(speedup < 8.0, "setup+shuffle must keep it sub-linear");
    }

    #[test]
    fn second_job_costs_extra_setup() {
        let p = JobProfile {
            map_task_secs: vec![1.0],
            reduce_task_secs: vec![1.0],
            shuffle_bytes_per_reducer: vec![0],
            ..Default::default()
        };
        let spec = ClusterSpec::paper_like(2);
        let (_, one) = simulate_job_chain(std::slice::from_ref(&p), &spec);
        let (_, two) = simulate_job_chain(&[p.clone(), p], &spec);
        assert!((two - 2.0 * one).abs() < 1e-9);
        assert!(two > one + spec.job_setup_s - 1e-9);
    }

    /// The sim's straggler thresholds must track the runtime scheduler's
    /// defaults, or "simulated and measured makespans stay comparable"
    /// silently stops being true.
    #[test]
    fn sim_thresholds_match_runtime_policy() {
        let p = crate::mapreduce::scheduler::SpecPolicy::default();
        assert!((SPEC_SLOWDOWN - p.slowdown).abs() < 1e-12);
        assert!((SPEC_MIN_SECS - p.min_secs).abs() < 1e-12);
    }

    #[test]
    fn wave_schedule_matches_list_schedule_without_knobs() {
        let spec = ClusterSpec::paper_like(8);
        for durations in [
            vec![1.0, 2.0, 3.0],
            vec![1.0; 8],
            vec![10.0, 1.0, 1.0, 1.0],
            vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0, 5.0, 3.0, 5.0],
        ] {
            for slots in [1usize, 2, 4, 8] {
                let w = wave_schedule(&durations, slots, &spec);
                let l = list_schedule_makespan(&durations, slots);
                assert!(
                    (w.makespan - l).abs() < 1e-9,
                    "wave {} != list {l} (slots={slots})",
                    w.makespan
                );
                assert_eq!(w.speculative_launched, 0);
            }
        }
    }

    #[test]
    fn failure_rate_zero_charges_nothing() {
        let durations = vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let spec = ClusterSpec::paper_like(8);
        let clean = wave_schedule(&durations, spec.map_slots(), &spec);
        let zero = wave_schedule(
            &durations,
            spec.map_slots(),
            &spec.clone().with_failures(0.0),
        );
        assert!((clean.makespan - zero.makespan).abs() < 1e-12);
    }

    #[test]
    fn failure_rate_lengthens_makespan_deterministically() {
        let durations = vec![4.0; 8];
        let spec = ClusterSpec::paper_like(4); // 4 map slots, 2 waves
        let clean = wave_schedule(&durations, spec.map_slots(), &spec);
        let faulty = ClusterSpec::paper_like(4).with_failures(0.25);
        let a = wave_schedule(&durations, faulty.map_slots(), &faulty);
        let b = wave_schedule(&durations, faulty.map_slots(), &faulty);
        // every 4th task re-executes: tasks 3 and 7 run 8s instead of 4s,
        // and the charge is reproducible run to run
        assert!(a.makespan > clean.makespan + 1e-9, "a={a:?} clean={clean:?}");
        assert!((a.makespan - b.makespan).abs() < 1e-12);
        // task 7 (doubled to 8s) starts at the 8s mark of its second wave
        assert!((a.makespan - 16.0).abs() < 1e-9, "got {}", a.makespan);
    }

    #[test]
    fn speculation_rescues_machine_skew_stragglers() {
        // 9 equal tasks on 8 slots; node 0 (slots 0 and 4) is 4× slow.
        // Without speculation the slow-slot tasks run 16s; with it, idle
        // fast slots clone them once eligible (1.5 × 4s median = 6s) and
        // finish by ~10s.
        let durations = vec![4.0; 9];
        let base = ClusterSpec::paper_like(8).with_slow_nodes(1, 4.0);
        let off = wave_schedule(&durations, base.map_slots(), &base);
        let on = wave_schedule(
            &durations,
            base.map_slots(),
            &base.clone().with_speculation(true),
        );
        assert!(off.makespan > 15.9, "slow node must straggle: {off:?}");
        assert!(
            on.makespan < off.makespan - 1.0,
            "speculation should rescue machine skew: on={on:?} off={off:?}"
        );
        assert!(on.speculative_launched >= 1);
        assert!(on.speculative_won >= 1);
    }

    /// A full-wave median (12) would put the threshold above the
    /// stragglers' own runtimes and never clone; the running median of
    /// *completed* tasks (1) — the runtime detector's rule — clones all
    /// three.  (They still cannot win on a homogeneous cluster.)
    #[test]
    fn running_median_speculates_despite_straggler_majority() {
        let durations = vec![1.0, 1.0, 1.0, 12.0, 12.0, 12.0];
        let spec = ClusterSpec::paper_like(8).with_speculation(true);
        let w = wave_schedule(&durations, spec.map_slots(), &spec);
        assert_eq!(
            w.speculative_launched, 3,
            "every straggler should be cloned: {w:?}"
        );
        assert_eq!(w.speculative_won, 0);
        assert!((w.makespan - 12.0).abs() < 1e-9);
    }

    #[test]
    fn speculation_cannot_fix_data_skew() {
        // one giant task on a homogeneous cluster (the Fig. 9 story): a
        // clone re-runs the same oversized partition and never wins
        let durations = vec![10.0, 1.0, 1.0, 1.0];
        let spec = ClusterSpec::paper_like(8);
        let off = wave_schedule(&durations, spec.map_slots(), &spec);
        let on = wave_schedule(
            &durations,
            spec.map_slots(),
            &spec.clone().with_speculation(true),
        );
        assert!((on.makespan - off.makespan).abs() < 1e-9);
        assert_eq!(on.speculative_won, 0);
    }

    #[test]
    fn simulate_job_reports_speculation() {
        let profile = JobProfile {
            map_task_secs: vec![4.0; 9],
            reduce_task_secs: vec![1.0; 4],
            shuffle_bytes_per_reducer: vec![0; 4],
            ..Default::default()
        };
        let spec = ClusterSpec::paper_like(8)
            .with_slow_nodes(1, 4.0)
            .with_speculation(true);
        let b = simulate_job(&profile, &spec);
        assert!(b.speculative_launched >= 1);
        let off = simulate_job(&profile, &spec.clone().with_speculation(false));
        assert_eq!(off.speculative_launched, 0);
        assert!(b.map_s < off.map_s);
    }

    /// The pair cost model: a balanced plan's modeled reduce wave beats an
    /// unbalanced one with the same pair total — and speculation does not
    /// help the unbalanced wave (data skew), which is the whole argument
    /// for computing the partitioning instead of cloning stragglers.
    #[test]
    fn pair_cost_model_prefers_balanced_plans() {
        let secs_per_pair = 1e-4;
        let unbalanced = [70_000u64, 5_000, 5_000, 5_000, 5_000, 5_000, 2_500, 2_500];
        let balanced = [12_500u64; 8];
        assert_eq!(
            unbalanced.iter().sum::<u64>(),
            balanced.iter().sum::<u64>()
        );
        let spec = ClusterSpec::paper_like(8);
        let t_unb = wave_schedule(
            &reduce_secs_from_pairs(&unbalanced, secs_per_pair),
            spec.reduce_slots(),
            &spec,
        );
        let t_bal = wave_schedule(
            &reduce_secs_from_pairs(&balanced, secs_per_pair),
            spec.reduce_slots(),
            &spec,
        );
        assert!(
            t_bal.makespan * 2.0 < t_unb.makespan,
            "balanced {:.2}s vs unbalanced {:.2}s",
            t_bal.makespan,
            t_unb.makespan
        );
        let t_spec = wave_schedule(
            &reduce_secs_from_pairs(&unbalanced, secs_per_pair),
            spec.reduce_slots(),
            &spec.clone().with_speculation(true),
        );
        assert!((t_spec.makespan - t_unb.makespan).abs() < 1e-9);
        assert_eq!(t_spec.speculative_won, 0);
    }

    /// The CPU-vs-network trade: compressed intermediates shrink the
    /// shuffle but pay (de)compression CPU.  On a slow network the trade
    /// wins; the CPU charges are visible either way.
    #[test]
    fn compression_trades_cpu_for_network() {
        let raw_bytes = 800_000_000u64; // 100 MB per reducer, raw
        let mk = |compressed: bool| {
            let per_reducer = if compressed {
                raw_bytes / 8 / 4 // 4:1 DEFLATE ratio
            } else {
                raw_bytes / 8
            };
            JobProfile {
                map_task_secs: vec![10.0; 8],
                reduce_task_secs: vec![10.0; 8],
                shuffle_bytes_per_reducer: vec![per_reducer; 8],
                map_output_bytes: raw_bytes,
                spill_bytes_written: if compressed { per_reducer * 8 } else { 0 },
                shuffle_bytes_raw: raw_bytes,
                compress_secs_per_mb: if compressed {
                    DEFLATE_COMPRESS_SECS_PER_MB
                } else {
                    0.0
                },
                decompress_secs_per_mb: if compressed {
                    DEFLATE_DECOMPRESS_SECS_PER_MB
                } else {
                    0.0
                },
            }
        };
        let spec = ClusterSpec::paper_like(8);
        let raw = simulate_job(&mk(false), &spec);
        let comp = simulate_job(&mk(true), &spec);
        assert_eq!(raw.compress_s, 0.0);
        assert_eq!(raw.decompress_s, 0.0);
        assert!(comp.compress_s > 0.0 && comp.decompress_s > 0.0);
        assert!(
            comp.shuffle_s < raw.shuffle_s / 3.0,
            "compressed shuffle must move ~4x fewer bytes"
        );
        // on the paper's GbE cluster the saved network time beats the
        // DEFLATE CPU for a 4:1 corpus
        assert!(
            comp.total() < raw.total(),
            "compression should win on GbE: {:.2} vs {:.2}",
            comp.total(),
            raw.total()
        );
        // compress charge halves when map slots double (it runs in the
        // task slots, not on a global core)
        let comp16 = simulate_job(&mk(true), &ClusterSpec::paper_like(16));
        assert!(comp16.compress_s < comp.compress_s);
    }

    /// The overlap (push-shuffle) mode: structurally never slower than
    /// the two-wave barrier on the same profile, and identical when
    /// there is no reduce work to overlap.
    #[test]
    fn overlap_mode_never_exceeds_two_wave() {
        let profiles = [
            JobProfile {
                map_task_secs: vec![10.0; 16],
                reduce_task_secs: vec![3.0; 8],
                shuffle_bytes_per_reducer: vec![1_000_000; 8],
                map_output_bytes: 8_000_000,
                ..Default::default()
            },
            JobProfile {
                map_task_secs: vec![2.0; 3],
                reduce_task_secs: vec![40.0, 1.0, 1.0],
                shuffle_bytes_per_reducer: vec![0; 3],
                ..Default::default()
            },
            JobProfile {
                map_task_secs: vec![5.0; 8],
                reduce_task_secs: Vec::new(),
                ..Default::default()
            },
            JobProfile::default(),
        ];
        for (i, p) in profiles.iter().enumerate() {
            for cores in [1usize, 4, 8] {
                let spec = ClusterSpec::paper_like(cores);
                let barrier = simulate_job(p, &spec).total();
                let push = simulate_job_overlap(p, &spec).total();
                assert!(
                    push <= barrier + 1e-9,
                    "profile {i}, cores {cores}: push {push} > barrier {barrier}"
                );
            }
        }
        // no reduce tasks → nothing to overlap → identical breakdowns
        let spec = ClusterSpec::paper_like(8);
        assert_eq!(
            simulate_job(&profiles[2], &spec),
            simulate_job_overlap(&profiles[2], &spec)
        );
    }

    /// A long multi-wave map phase fully hides a short reduce wave that
    /// was released at the first map completion — the overlap the
    /// barrier model cannot express.
    #[test]
    fn overlap_mode_hides_reduce_behind_map_wave() {
        // 16 × 10s map tasks on 8 slots → first completion 10s, done 20s;
        // 8 × 1s reduce tasks released at 10s finish long before 20s
        let profile = JobProfile {
            map_task_secs: vec![10.0; 16],
            reduce_task_secs: vec![1.0; 8],
            shuffle_bytes_per_reducer: vec![0; 8],
            ..Default::default()
        };
        let spec = ClusterSpec::paper_like(8);
        let barrier = simulate_job(&profile, &spec);
        let push = simulate_job_overlap(&profile, &spec);
        assert!((barrier.reduce_s - 1.0).abs() < 1e-9);
        assert!(
            push.reduce_s.abs() < 1e-9,
            "reduce tail should vanish: {push:?}"
        );
        assert_eq!(push.map_s, barrier.map_s);
        assert!((barrier.total() - push.total() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn wave_outcome_reports_first_completion() {
        let spec = ClusterSpec::paper_like(8);
        let w = wave_schedule(&[10.0; 16], spec.map_slots(), &spec);
        assert!((w.first_completion - 10.0).abs() < 1e-9);
        assert!((w.makespan - 20.0).abs() < 1e-9);
    }

    #[test]
    fn fit_secs_per_pair_round_trips() {
        let pairs = [100u64, 300, 50];
        let secs = reduce_secs_from_pairs(&pairs, 2e-3);
        let fitted = fit_secs_per_pair(&secs, &pairs);
        assert!((fitted - 2e-3).abs() < 1e-12);
        assert_eq!(fit_secs_per_pair(&[], &[]), 0.0);
    }

    #[test]
    fn empty_profile_is_setup_only() {
        let p = JobProfile::default();
        let spec = ClusterSpec::paper_like(4);
        let b = simulate_job(&p, &spec);
        assert!((b.total() - spec.job_setup_s).abs() < 1e-9);
    }

    fn drift_stats() -> crate::mapreduce::engine::JobStats {
        crate::mapreduce::engine::JobStats {
            map_task_secs: vec![1.0, 2.0],
            reduce_task_secs: vec![3.0],
            shuffle_bytes_per_reducer: vec![1_000_000],
            map_phase_secs: 3.0,
            shuffle_phase_secs: 0.1,
            reduce_phase_secs: 3.0,
            total_secs: 6.1,
            ..Default::default()
        }
    }

    #[test]
    fn drift_report_picks_mode_from_overlap() {
        let spec = ClusterSpec::paper_like(1);
        let stats = drift_stats();
        assert_eq!(
            drift_report(&stats, 1_000_000, &spec).mode,
            SimShuffleMode::TwoWave
        );
        let mut pushed = drift_stats();
        pushed.overlap_secs = 0.5;
        assert_eq!(
            drift_report(&pushed, 1_000_000, &spec).mode,
            SimShuffleMode::Overlap
        );
    }

    #[test]
    fn drift_report_excludes_setup_and_names_three_waves() {
        let spec = ClusterSpec::paper_like(1);
        let rep = drift_report(&drift_stats(), 1_000_000, &spec);
        let names: Vec<&str> = rep.waves.iter().map(|w| w.wave).collect();
        assert_eq!(names, vec!["map", "shuffle", "reduce"]);
        // single slot, no setup: simulated map wave is the serial sum and
        // matches the measured phase exactly → zero drift on that row
        assert!((rep.waves[0].simulated_s - 3.0).abs() < 1e-9);
        assert!(rep.waves[0].drift_frac() < 1e-9);
        let sim_sum: f64 = rep.waves.iter().map(|w| w.simulated_s).sum();
        assert!((rep.simulated_total_s - sim_sum).abs() < 1e-9);
    }

    #[test]
    fn drift_report_json_and_render_carry_the_rows() {
        let spec = ClusterSpec::paper_like(1);
        let rep = drift_report(&drift_stats(), 1_000_000, &spec);
        let json = rep.to_json();
        for key in [
            "\"mode\":\"two_wave\"",
            "\"max_drift_frac\":",
            "\"wave\":\"map\"",
            "\"wave\":\"shuffle\"",
            "\"wave\":\"reduce\"",
            "\"measured_total_s\":",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        let text = rep.render();
        assert!(text.contains("sim-vs-measured drift"));
        assert!(text.contains("reduce"));
    }

    /// Measured stats with per-task overhead the task timers miss (the
    /// usual shape of a real run): wall phases run longer than the task
    /// sums, and the in-process shuffle is far cheaper than 2007 disk +
    /// GbE.  The calibrated spec must track all three rows better than
    /// the default — the acceptance criterion the engine bench asserts.
    fn overheady_stats() -> crate::mapreduce::engine::JobStats {
        crate::mapreduce::engine::JobStats {
            map_task_secs: vec![1.0, 2.0, 1.5],
            reduce_task_secs: vec![2.0, 1.0],
            shuffle_bytes_per_reducer: vec![4_000_000, 4_000_000],
            map_phase_secs: 5.4, // 1.2× the 4.5s task sum
            shuffle_phase_secs: 0.004,
            reduce_phase_secs: 3.45, // 1.15× the 3.0s task sum
            total_secs: 8.854,
            ..Default::default()
        }
    }

    #[test]
    fn fit_from_stats_beats_default_spec() {
        let stats = overheady_stats();
        let bytes: u64 = stats.shuffle_bytes_per_reducer.iter().sum();
        let default = ClusterSpec::paper_like(1);
        let cal = ClusterSpec::fit_from_stats(std::slice::from_ref(&stats));
        let d_def = drift_report(&stats, bytes, &default);
        let d_cal = drift_report(&stats, bytes, &cal);
        assert!(
            d_cal.mean_abs_delta_s() < d_def.mean_abs_delta_s(),
            "calibrated {:.6}s must beat default {:.6}s",
            d_cal.mean_abs_delta_s(),
            d_def.mean_abs_delta_s()
        );
        // the fitted rates reproduce the measured rows almost exactly
        for w in &d_cal.waves {
            assert!(
                w.delta_s().abs() < 1e-6,
                "calibrated row {} off by {:.9}s",
                w.wave,
                w.delta_s()
            );
        }
        assert!((cal.map_secs_scale - 1.2).abs() < 1e-9);
        assert!((cal.reduce_secs_scale - 1.15).abs() < 1e-9);
    }

    #[test]
    fn fit_from_stats_uses_histograms_when_task_vectors_are_absent() {
        let mut stats = overheady_stats();
        // same totals, carried only by the µs histograms
        for s in std::mem::take(&mut stats.map_task_secs) {
            stats.map_task_us_hist.record((s * 1e6) as u64);
        }
        for s in std::mem::take(&mut stats.reduce_task_secs) {
            stats.reduce_task_us_hist.record((s * 1e6) as u64);
        }
        let cal = ClusterSpec::fit_from_stats(std::slice::from_ref(&stats));
        assert!((cal.map_secs_scale - 1.2).abs() < 1e-6);
        assert!((cal.reduce_secs_scale - 1.15).abs() < 1e-6);
    }

    #[test]
    fn fit_from_stats_empty_or_zero_keeps_defaults() {
        let cal = ClusterSpec::fit_from_stats(&[]);
        assert_eq!(cal.map_secs_scale, 1.0);
        assert_eq!(cal.reduce_secs_scale, 1.0);
        assert_eq!(cal.shuffle_cpu_scale, 1.0);
        // zero-measured phases must not fit a degenerate rate
        let cal = ClusterSpec::fit_from_stats(&[crate::mapreduce::engine::JobStats::default()]);
        assert_eq!(cal.map_secs_scale, 1.0);
        assert_eq!(cal.shuffle_cpu_scale, 1.0);
    }

    #[test]
    fn calibration_scales_apply_in_simulation() {
        let profile = JobProfile {
            map_task_secs: vec![2.0; 4],
            reduce_task_secs: vec![1.0; 2],
            shuffle_bytes_per_reducer: vec![0; 2],
            ..Default::default()
        };
        let base = ClusterSpec::paper_like(1);
        let mut scaled = base.clone();
        scaled.map_secs_scale = 2.0;
        scaled.reduce_secs_scale = 3.0;
        let b = simulate_job(&profile, &base);
        let s = simulate_job(&profile, &scaled);
        assert!((s.map_s - 2.0 * b.map_s).abs() < 1e-9);
        assert!((s.reduce_s - 3.0 * b.reduce_s).abs() < 1e-9);
    }

    #[test]
    fn mean_abs_delta_is_published_in_json() {
        let spec = ClusterSpec::paper_like(1);
        let rep = drift_report(&drift_stats(), 1_000_000, &spec);
        assert!(rep.to_json().contains("\"mean_abs_delta_s\":"));
        assert!(rep.mean_abs_delta_s() >= 0.0);
    }

    #[test]
    fn wave_drift_zero_measured_is_zero_drift() {
        let w = WaveDrift {
            wave: "shuffle",
            measured_s: 0.0,
            simulated_s: 0.5,
        };
        assert_eq!(w.drift_frac(), 0.0);
        assert!((w.delta_s() - 0.5).abs() < 1e-12);
    }

    /// The memory-pool knob: 0 is bit-identical to the legacy model, a
    /// pool below the working set charges the overflow as extra spill
    /// volume, and an already-spilled profile pays nothing extra.
    #[test]
    fn memory_pool_charges_only_the_overflow() {
        let profile = JobProfile {
            map_task_secs: vec![10.0; 8],
            reduce_task_secs: vec![5.0; 8],
            shuffle_bytes_per_reducer: vec![1_000_000; 8],
            map_output_bytes: 8_000_000,
            ..Default::default()
        };
        let base = ClusterSpec::paper_like(8);
        let unlimited = simulate_job(&profile, &base.clone().with_memory_pool_bytes(0));
        let plain = simulate_job(&profile, &base);
        assert_eq!(unlimited, plain, "pool = 0 must be strictly zero-cost");

        // pool at half the working set: 4 MB overflow, 2 disk passes
        let tight = simulate_job(&profile, &base.clone().with_memory_pool_bytes(4_000_000));
        let disk_agg = base.disk_bytes_per_s * base.nodes as f64;
        let expect = 2.0 * 4_000_000.0 / disk_agg;
        assert!((tight.materialize_s - plain.materialize_s - expect).abs() < 1e-9);
        assert!(tight.total() > plain.total());

        // a pool above the working set never charges
        let roomy = simulate_job(&profile, &base.clone().with_memory_pool_bytes(64_000_000));
        assert_eq!(roomy, plain);

        // a fully spilled profile already pays disk for every byte; the
        // pool adds nothing on top
        let spilled = JobProfile { spill_bytes_written: 8_000_000, ..profile };
        let sp_plain = simulate_job(&spilled, &base);
        let sp_tight = simulate_job(&spilled, &base.clone().with_memory_pool_bytes(1));
        assert_eq!(sp_tight, sp_plain);
    }
}
