//! Simulated distributed file system (the HDFS stand-in).
//!
//! Models what the paper's setup depends on: files stored as fixed-size
//! blocks (§5.1 sets 128 MB), placed on simulated datanodes with a
//! replication factor, with enough metadata to account for data locality
//! (map tasks "read their (preferably) local data").  Payloads live in
//! memory; an optional spill directory persists files to disk for the CLI
//! pipeline (`snmr generate` → `snmr run`).

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

/// DFS configuration.
#[derive(Debug, Clone)]
pub struct DfsConfig {
    /// Block size in bytes (paper: 128 MB; tests use small values).
    pub block_size: usize,
    /// Replication factor.
    pub replication: usize,
    /// Number of simulated datanodes.
    pub nodes: usize,
    /// If set, files are also persisted under this directory.
    pub spill_dir: Option<PathBuf>,
}

impl Default for DfsConfig {
    fn default() -> Self {
        Self {
            block_size: 128 * 1024 * 1024,
            replication: 1,
            nodes: 4,
            spill_dir: None,
        }
    }
}

/// Placement of one block: which nodes hold a replica.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockInfo {
    pub index: usize,
    pub len: usize,
    pub replicas: Vec<usize>,
}

#[derive(Debug, Default)]
struct FileEntry {
    data: Vec<u8>,
    blocks: Vec<BlockInfo>,
}

/// The simulated DFS namespace.
#[derive(Debug)]
pub struct Dfs {
    config: DfsConfig,
    files: BTreeMap<String, FileEntry>,
    /// Bytes stored per node (replicas counted), for balance reporting.
    node_bytes: Vec<u64>,
    /// Round-robin placement cursor (HDFS default placement is
    /// locality-driven; round-robin gives the same balance property).
    cursor: usize,
}

impl Dfs {
    pub fn new(config: DfsConfig) -> Self {
        assert!(config.nodes >= 1 && config.replication >= 1);
        assert!(config.replication <= config.nodes);
        assert!(config.block_size > 0);
        let nodes = config.nodes;
        Self {
            config,
            files: BTreeMap::new(),
            node_bytes: vec![0; nodes],
            cursor: 0,
        }
    }

    /// Write (or overwrite) a file; splits into blocks and places replicas.
    pub fn write(&mut self, path: &str, data: Vec<u8>) -> Result<()> {
        if path.is_empty() {
            bail!("empty path");
        }
        if let Some(old) = self.files.remove(path) {
            self.release(&old);
        }
        let mut blocks = Vec::new();
        let n = data.len();
        let bs = self.config.block_size;
        let nblocks = n.div_ceil(bs).max(1);
        for i in 0..nblocks {
            let len = if i + 1 == nblocks && n > 0 {
                n - i * bs
            } else if n == 0 {
                0
            } else {
                bs
            };
            let mut replicas = Vec::with_capacity(self.config.replication);
            for rep in 0..self.config.replication {
                let node = (self.cursor + rep) % self.config.nodes;
                replicas.push(node);
                self.node_bytes[node] += len as u64;
            }
            self.cursor = (self.cursor + 1) % self.config.nodes;
            blocks.push(BlockInfo {
                index: i,
                len,
                replicas,
            });
        }
        if let Some(dir) = &self.config.spill_dir {
            let full = dir.join(path.trim_start_matches('/'));
            if let Some(parent) = full.parent() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("mkdir {}", parent.display()))?;
            }
            std::fs::write(&full, &data).with_context(|| format!("spill {}", full.display()))?;
        }
        self.files.insert(path.to_string(), FileEntry { data, blocks });
        Ok(())
    }

    /// Read a whole file.
    pub fn read(&self, path: &str) -> Result<&[u8]> {
        match self.files.get(path) {
            Some(f) => Ok(&f.data),
            None => {
                // fall back to spill dir (cross-process pipeline)
                bail!("no such file in DFS: {path}")
            }
        }
    }

    /// Read from the spill directory when the in-memory namespace doesn't
    /// have the file (e.g. a fresh process after `snmr generate`).
    pub fn read_or_spill(&self, path: &str) -> Result<Vec<u8>> {
        if let Ok(d) = self.read(path) {
            return Ok(d.to_vec());
        }
        if let Some(dir) = &self.config.spill_dir {
            let full = dir.join(path.trim_start_matches('/'));
            return std::fs::read(&full).with_context(|| format!("read {}", full.display()));
        }
        bail!("no such file: {path}")
    }

    /// Delete a file.
    pub fn remove(&mut self, path: &str) -> Result<()> {
        match self.files.remove(path) {
            Some(f) => {
                self.release(&f);
                Ok(())
            }
            None => bail!("no such file: {path}"),
        }
    }

    fn release(&mut self, f: &FileEntry) {
        for b in &f.blocks {
            for &n in &b.replicas {
                self.node_bytes[n] -= b.len as u64;
            }
        }
    }

    /// List files under a prefix.
    pub fn list(&self, prefix: &str) -> Vec<String> {
        self.files
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect()
    }

    /// Block placement of a file.
    pub fn blocks(&self, path: &str) -> Result<&[BlockInfo]> {
        self.files
            .get(path)
            .map(|f| f.blocks.as_slice())
            .ok_or_else(|| anyhow::anyhow!("no such file: {path}"))
    }

    /// Bytes stored per node (replicas counted).
    pub fn node_bytes(&self) -> &[u64] {
        &self.node_bytes
    }

    /// Fraction of a hypothetical `tasks`-way scan that can be scheduled
    /// node-locally if tasks are placed greedily on replica nodes.
    pub fn locality_fraction(&self, path: &str, tasks: usize) -> Result<f64> {
        let blocks = self.blocks(path)?;
        if blocks.is_empty() || tasks == 0 {
            return Ok(1.0);
        }
        // greedy: a task on node n reads blocks with a replica on n
        let mut local = 0usize;
        for (i, b) in blocks.iter().enumerate() {
            let task_node = i % tasks % self.config.nodes;
            if b.replicas.contains(&task_node) {
                local += 1;
            }
        }
        Ok(local as f64 / blocks.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Dfs {
        Dfs::new(DfsConfig {
            block_size: 10,
            replication: 2,
            nodes: 4,
            spill_dir: None,
        })
    }

    #[test]
    fn write_read_roundtrip() {
        let mut dfs = small();
        dfs.write("/data/a.bin", vec![7u8; 25]).unwrap();
        assert_eq!(dfs.read("/data/a.bin").unwrap(), &vec![7u8; 25][..]);
    }

    #[test]
    fn splits_into_blocks() {
        let mut dfs = small();
        dfs.write("/x", vec![0u8; 25]).unwrap();
        let blocks = dfs.blocks("/x").unwrap();
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[0].len, 10);
        assert_eq!(blocks[2].len, 5);
        for b in blocks {
            assert_eq!(b.replicas.len(), 2);
        }
    }

    #[test]
    fn replication_counts_bytes() {
        let mut dfs = small();
        dfs.write("/x", vec![0u8; 20]).unwrap();
        let total: u64 = dfs.node_bytes().iter().sum();
        assert_eq!(total, 40); // 20 bytes × replication 2
        dfs.remove("/x").unwrap();
        assert_eq!(dfs.node_bytes().iter().sum::<u64>(), 0);
    }

    #[test]
    fn overwrite_releases_old_blocks() {
        let mut dfs = small();
        dfs.write("/x", vec![0u8; 20]).unwrap();
        dfs.write("/x", vec![0u8; 5]).unwrap();
        assert_eq!(dfs.node_bytes().iter().sum::<u64>(), 10);
    }

    #[test]
    fn list_by_prefix() {
        let mut dfs = small();
        dfs.write("/a/1", vec![1]).unwrap();
        dfs.write("/a/2", vec![2]).unwrap();
        dfs.write("/b/3", vec![3]).unwrap();
        assert_eq!(dfs.list("/a/"), vec!["/a/1".to_string(), "/a/2".to_string()]);
    }

    #[test]
    fn missing_file_errors() {
        let dfs = small();
        assert!(dfs.read("/nope").is_err());
    }

    #[test]
    fn empty_file_has_one_empty_block() {
        let mut dfs = small();
        dfs.write("/e", vec![]).unwrap();
        assert_eq!(dfs.blocks("/e").unwrap().len(), 1);
        assert_eq!(dfs.read("/e").unwrap().len(), 0);
    }

    #[test]
    fn spill_dir_persists() {
        let dir = std::env::temp_dir().join(format!("snmr_dfs_test_{}", std::process::id()));
        let mut dfs = Dfs::new(DfsConfig {
            block_size: 10,
            replication: 1,
            nodes: 2,
            spill_dir: Some(dir.clone()),
        });
        dfs.write("/out/f.bin", b"hello".to_vec()).unwrap();
        let fresh = Dfs::new(DfsConfig {
            spill_dir: Some(dir.clone()),
            ..DfsConfig::default()
        });
        assert_eq!(fresh.read_or_spill("/out/f.bin").unwrap(), b"hello");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn locality_fraction_bounds() {
        let mut dfs = small();
        dfs.write("/x", vec![0u8; 100]).unwrap();
        let f = dfs.locality_fraction("/x", 4).unwrap();
        assert!((0.0..=1.0).contains(&f));
    }
}
