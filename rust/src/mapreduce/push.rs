//! Push-based shuffle service: run-granular data flow between the map
//! and reduce waves of one job.
//!
//! The barrier engine ships intermediate runs in one step: the driver
//! transposes run ownership *after* the whole map wave, so reduce slots
//! sit idle for the entire map phase (the Hadoop 0.20 model the paper
//! runs on).  This module replaces that barrier with a mailbox per
//! reduce partition:
//!
//! * map tasks **push** every sealed [`Run`] the moment it exists —
//!   mid-task when a sort budget seals chunks early, at task end
//!   otherwise — through a [`PushAttempt`] handle;
//! * the scheduler's dispatcher submits a reduce task to the shared
//!   reduce slots as soon as its mailbox sees the **first run**, not
//!   when the map wave ends;
//! * the reduce task folds arrived runs into a growing pre-merged prefix
//!   while the map wave is still running, then k-way-merges the
//!   late-arriving remainder in one final catch-up pass.
//!
//! ## Determinism: the committed-prefix rule
//!
//! The engine's merge contract orders equal keys by run position —
//! `(map task, seal sequence)` — so a reducer may only pre-merge a
//! *contiguous committed prefix* of that order: runs of task `t` are
//! foldable once every task `< t` is complete, because no run that sorts
//! before them can still arrive.  Everything behind the prefix waits for
//! the final catch-up merge.  This is what makes push output
//! byte-identical to the barrier path (`tests/prop_push.rs` pins it
//! across every SN variant).
//!
//! ## Speculation safety
//!
//! With speculative execution on, one task may run as several attempts.
//! Runs pushed by an attempt are **staged** per attempt and only
//! committed to the mailboxes when that attempt wins its task
//! ([`PushAttempt::finish`], first-commit-wins); a losing attempt's
//! staged runs are dropped — their spill files are deleted by the
//! [`Run`] handles — and never counted in
//! [`names::PUSHED_RUNS`].  Without speculation there is exactly one
//! attempt per task, so pushes commit (and become visible to reducers)
//! immediately, mid-task.
//!
//! The service's commit race is independent of the scheduler's
//! result-slot race ([`OnceSlots::try_put`]); the two may crown
//! different attempts of the same task.  That is sound for the same
//! reason speculation itself is: attempts are deterministic functions of
//! the task input, so both attempts push identical run contents.
//!
//! ## Relation to the distributed push path
//!
//! This mailbox service is the **in-process** push implementation: runs
//! move by shared-memory handoff into per-partition mailboxes.  The
//! [`DistScheduler`](super::scheduler::DistScheduler) implements the
//! same phase structure with **location-addressed** flow instead: map
//! completions stream `(executor, run ids)` *sources* to
//! already-launched reduce tasks, which fetch the run bytes from the
//! owning executor over the transport and seal on the wave stamp.  Both
//! obey the committed-prefix rule above, so both are byte-identical to
//! the barrier reference.  The distributed form is the first slice of
//! *push across chained jobs*: a source is just an address, so a
//! downstream job's reducers could fetch an upstream job's output
//! without a materialization barrier between them.
//!
//! [`OnceSlots::try_put`]: crate::util::threadpool::OnceSlots::try_put

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::counters::{names, Counters};
use super::memory::{MemoryConsumer, MemoryPool, MemoryReservation, DEFAULT_PARK_WAIT, PARK_SLICE};
use super::shuffle::MergeIter;
use super::sortspill::{ResolvedSpill, Run};
use super::trace::{JobTraceCtx, TraceEvent, TracePhase};
use super::types::SizeEstimate;
use crate::metrics::registry::MailboxStats;

/// Mailbox position of one committed run: `(map task) << 32 | seal seq`,
/// the engine's global run order for a reduce partition.
fn run_key(task: usize, seq: u64) -> u64 {
    ((task as u64) << 32) | seq
}

struct StagedAttempt<T> {
    task: usize,
    /// The scheduler's attempt ordinal for this execution — stamped on
    /// the [`TraceEvent::RunPushed`]/[`TraceEvent::RunRetracted`] records
    /// this attempt's runs produce.
    wave_attempt: u32,
    runs: Vec<(usize, Run<T>)>,
}

struct State<T> {
    /// Committed runs per reduce partition, sorted by [`run_key`].  Each
    /// run is taken exactly once by its partition's reduce task.
    committed: Vec<Vec<(u64, Option<Run<T>>)>>,
    /// Next seal sequence per map task.
    next_seq: Vec<u64>,
    /// Per-attempt staging (speculative mode only).
    staged: HashMap<u64, StagedAttempt<T>>,
    task_done: Vec<bool>,
    /// Number of leading complete tasks — the committed-prefix frontier.
    done_prefix: usize,
    sealed: bool,
    /// The map wave failed: drain without submitting anything new.
    aborted: bool,
    /// Partition has at least one committed run (dispatcher trigger).
    arrivals: Vec<bool>,
    next_attempt: u64,
}

/// Per-job push shuffle state: one mailbox per reduce partition, shared
/// by every map attempt (writers) and reduce task (readers) of the job.
pub struct ShuffleService<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    /// Stage pushes per attempt and commit on win (speculative mode); or
    /// commit every push immediately (single-attempt mode).
    staged_mode: bool,
    /// Hand out *clones* of committed runs instead of taking them, so a
    /// panicked reduce attempt can be retried against the same mailbox
    /// (the scheduler's fault-tolerance path).  Cloning a spilled run is
    /// cheap — handles share the file — and a committed reduce task
    /// releases its mailbox explicitly ([`Self::release_partition`]).
    retain_runs: bool,
    counters: Arc<Counters>,
    /// Job trace context, when tracing is on: run commits and
    /// retractions emit [`TraceEvent::RunPushed`] /
    /// [`TraceEvent::RunRetracted`] stamped with the pushing map task's
    /// coordinates.
    trace: Option<JobTraceCtx>,
    /// Pool accounting for mailbox residency, when a memory pool is
    /// configured ([`Self::with_memory`]); `None` keeps the service
    /// entirely accounting-free.
    memory: Option<MailboxMemory>,
    /// Where a denied push diverts its run to disk instead of parking.
    /// Dormant when the job already spills map runs (they arrive as
    /// [`Run::Spilled`] with zero resident cost and are never denied).
    divert: Option<ResolvedSpill<T>>,
    num_partitions: usize,
}

/// The mailbox reservation: one pool consumer covering every resident
/// byte parked in the service (committed and staged in-memory runs).
/// The reservation sits behind its own mutex — acquired only for quick
/// grow/shrink calls, never held across a wait — and the pool handle
/// drives the bounded-slice backpressure waits.
struct MailboxMemory {
    res: Mutex<MemoryReservation>,
    pool: MemoryPool,
}

impl<T> ShuffleService<T> {
    /// A service for `num_tasks` map tasks feeding `num_partitions`
    /// reduce mailboxes.  `staged_mode` must be true whenever more than
    /// one attempt per task can exist (speculative execution).
    /// Committed-run counts go to `counters` as [`names::PUSHED_RUNS`].
    pub fn new(
        num_tasks: usize,
        num_partitions: usize,
        staged_mode: bool,
        counters: Arc<Counters>,
    ) -> Self {
        Self {
            state: Mutex::new(State {
                committed: (0..num_partitions).map(|_| Vec::new()).collect(),
                next_seq: vec![0; num_tasks],
                staged: HashMap::new(),
                task_done: vec![false; num_tasks],
                done_prefix: 0,
                sealed: false,
                aborted: false,
                arrivals: vec![false; num_partitions],
                next_attempt: 0,
            }),
            cv: Condvar::new(),
            staged_mode,
            retain_runs: false,
            counters,
            trace: None,
            memory: None,
            divert: None,
            num_partitions,
        }
    }

    /// Account mailbox residency under `pool` (registering a
    /// non-spillable "mailboxes" consumer — the mailboxes cannot shed
    /// bytes themselves; relief comes from reducers draining or from
    /// pushers diverting).  With a `divert` spec, a denied push writes
    /// its run to disk instead of parking; without one it backpressures
    /// (see [`Self::push_run`]).  `None` pool keeps the service free of
    /// any accounting work.
    pub(crate) fn with_memory(
        mut self,
        pool: Option<&MemoryPool>,
        divert: Option<ResolvedSpill<T>>,
    ) -> Self {
        self.memory = pool.map(|p| MailboxMemory {
            res: Mutex::new(MemoryConsumer::new("mailboxes").register(p)),
            pool: p.clone(),
        });
        self.divert = divert;
        self
    }

    /// Keep committed runs in the mailboxes after they are handed to a
    /// reduce task, so a retried attempt can re-read them.  Must be set
    /// whenever reduce-side retry or fault injection is active.
    pub fn with_retained_runs(mut self, on: bool) -> Self {
        self.retain_runs = on;
        self
    }

    /// Attach a job trace context so run commits and retractions land in
    /// the event stream ([`TraceEvent::RunPushed`] /
    /// [`TraceEvent::RunRetracted`]).  `None` keeps the service silent.
    pub(crate) fn with_trace(mut self, trace: Option<JobTraceCtx>) -> Self {
        self.trace = trace;
        self
    }

    /// Emit `event` stamped with map task `task` / attempt
    /// `wave_attempt`, when tracing is on.
    fn emit(&self, task: usize, wave_attempt: u32, event: TraceEvent) {
        if let Some(j) = &self.trace {
            j.task(TracePhase::Map, task, wave_attempt).emit(event);
        }
    }

    pub fn num_partitions(&self) -> usize {
        self.num_partitions
    }

    /// Open a new attempt of `task`.  Every execution of a map task body
    /// gets its own attempt handle; with speculation a task may open
    /// several concurrently.
    pub fn begin_attempt(svc: &Arc<ShuffleService<T>>, task: usize) -> PushAttempt<T> {
        Self::begin_attempt_traced(svc, task, 0)
    }

    /// [`Self::begin_attempt`] carrying the scheduler's attempt ordinal,
    /// so the trace records this handle's runs produce are stamped with
    /// the same attempt number as the task's lifecycle events.
    pub fn begin_attempt_traced(
        svc: &Arc<ShuffleService<T>>,
        task: usize,
        wave_attempt: u32,
    ) -> PushAttempt<T> {
        let id = {
            let mut st = svc.state.lock().unwrap();
            let id = st.next_attempt;
            st.next_attempt += 1;
            if svc.staged_mode {
                st.staged.insert(
                    id,
                    StagedAttempt {
                        task,
                        wave_attempt,
                        runs: Vec::new(),
                    },
                );
            }
            id
        };
        PushAttempt {
            svc: Arc::clone(svc),
            id,
            task,
            wave_attempt,
        }
    }

    /// Charge `run`'s resident bytes to the mailbox reservation *before*
    /// the state lock is taken — a pusher waiting for pool space must
    /// never hold it, because the reducers draining the mailboxes (and
    /// thereby freeing those bytes) need it.  On a denied grow, the run
    /// is diverted to disk when a divert spec exists (resident cost
    /// drops to ~0, no reservation needed); otherwise the push
    /// backpressures: bounded-slice waits between retries, an
    /// unconditional grow after [`DEFAULT_PARK_WAIT`] so a mis-sized
    /// pool degrades instead of wedging, and the run is dropped
    /// (returning `None`) if the wave aborts while parked.  Returns the
    /// possibly-diverted run plus the bytes now charged for it.
    fn charge_for(
        &self,
        task: usize,
        wave_attempt: u32,
        partition: usize,
        run: Run<T>,
    ) -> Option<(Run<T>, u64)>
    where
        T: SizeEstimate,
    {
        let Some(mem) = &self.memory else {
            return Some((run, 0));
        };
        let bytes = run.pool_bytes();
        if bytes == 0 {
            return Some((run, 0));
        }
        if mem.res.lock().unwrap().try_grow(bytes) {
            return Some((run, bytes));
        }
        self.counters.inc(names::POOL_DENIED_GROWS);
        self.emit(
            task,
            wave_attempt,
            TraceEvent::ReservationDenied { requested: bytes },
        );
        if let Some(sp) = &self.divert {
            let Run::Mem(v) = run else {
                unreachable!("spilled runs have zero pool cost")
            };
            let rf = sp
                .write_run(&v)
                .unwrap_or_else(|e| panic!("divert push run: {e:#}"));
            self.counters.inc(names::POOL_SPILL_REQUESTS);
            self.emit(
                task,
                wave_attempt,
                TraceEvent::SpillWritten {
                    partition,
                    records: rf.records(),
                    file_bytes: rf.file_bytes(),
                },
            );
            return Some((Run::Spilled(rf), 0));
        }
        self.counters.inc(names::POOL_BACKPRESSURE_WAITS);
        mem.pool.note_backpressure_wait();
        self.emit(task, wave_attempt, TraceEvent::BackpressureApplied { bytes });
        let deadline = Instant::now() + DEFAULT_PARK_WAIT;
        loop {
            if self.state.lock().unwrap().aborted {
                // the wave is unwinding: drop the run instead of feeding
                // mailboxes nobody will drain
                return None;
            }
            if mem.res.lock().unwrap().try_grow(bytes) {
                return Some((run, bytes));
            }
            if Instant::now() >= deadline {
                // bounded wait expired — take the bytes unconditionally
                mem.res.lock().unwrap().grow(bytes);
                return Some((run, bytes));
            }
            mem.pool.wait_for_release(PARK_SLICE);
        }
    }

    /// Return `bytes` of mailbox residency to the pool (runs handed out,
    /// retracted, or released).  Callers must not hold the state lock.
    fn uncharge(&self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        if let Some(mem) = &self.memory {
            mem.res.lock().unwrap().shrink(bytes);
        }
    }

    fn push_run(&self, attempt: u64, task: usize, wave_attempt: u32, partition: usize, run: Run<T>)
    where
        T: SizeEstimate,
    {
        assert!(partition < self.num_partitions, "partition out of range");
        let Some((run, charged)) = self.charge_for(task, wave_attempt, partition, run) else {
            return;
        };
        let mut st = self.state.lock().unwrap();
        if st.task_done[task] {
            // a loser still running after its task was decided: drop the
            // run (spill files are deleted when the handle drops)
            drop(st);
            self.uncharge(charged);
            self.emit(task, wave_attempt, TraceEvent::RunRetracted { partition });
            return;
        }
        if self.staged_mode {
            if let Some(staged) = st.staged.get_mut(&attempt) {
                staged.runs.push((partition, run));
            }
            return;
        }
        // single-attempt mode: the push is final — commit immediately so
        // reducers (and the dispatcher) see mid-task spills
        let seq = st.next_seq[task];
        st.next_seq[task] = seq + 1;
        let records = run.len() as u64;
        Self::insert_committed(&mut st, task, seq, partition, run);
        self.counters.inc(names::PUSHED_RUNS);
        self.cv.notify_all();
        drop(st);
        self.emit(task, wave_attempt, TraceEvent::RunPushed { partition, records });
    }

    fn insert_committed(st: &mut State<T>, task: usize, seq: u64, partition: usize, run: Run<T>) {
        let key = run_key(task, seq);
        let mailbox = &mut st.committed[partition];
        let pos = mailbox.partition_point(|(k, _)| *k < key);
        mailbox.insert(pos, (key, Some(run)));
        st.arrivals[partition] = true;
    }

    /// Decide `task` in favor of `attempt` (first commit wins).  In
    /// staged mode the winner's staged runs move into the mailboxes and
    /// every other staged attempt of the task is retracted.  Returns
    /// whether this attempt won.
    fn commit_task(&self, task: usize, attempt: u64) -> bool
    where
        T: SizeEstimate,
    {
        // (wave_attempt, event) pairs emitted after the state lock drops
        let mut emits: Vec<(u32, TraceEvent)> = Vec::new();
        // resident bytes of retracted staged runs, uncharged after the
        // state lock drops (never call into the pool while holding it)
        let track = self.memory.is_some();
        let mut retracted: u64 = 0;
        let mut st = self.state.lock().unwrap();
        if st.task_done[task] {
            // lost the commit race: retract this attempt's staged runs
            if let Some(staged) = st.staged.remove(&attempt) {
                for (partition, run) in &staged.runs {
                    if track {
                        retracted += run.pool_bytes();
                    }
                    emits.push((
                        staged.wave_attempt,
                        TraceEvent::RunRetracted { partition: *partition },
                    ));
                }
            }
            drop(st);
            self.uncharge(retracted);
            for (wa, ev) in emits {
                self.emit(task, wa, ev);
            }
            return false;
        }
        if self.staged_mode {
            let staged = st
                .staged
                .remove(&attempt)
                .expect("staged entry for live attempt");
            debug_assert_eq!(staged.task, task);
            let n = staged.runs.len() as u64;
            for (partition, run) in staged.runs {
                let seq = st.next_seq[task];
                st.next_seq[task] = seq + 1;
                let records = run.len() as u64;
                emits.push((
                    staged.wave_attempt,
                    TraceEvent::RunPushed { partition, records },
                ));
                Self::insert_committed(&mut st, task, seq, partition, run);
            }
            if n > 0 {
                self.counters.add(names::PUSHED_RUNS, n);
            }
            // retract any other attempt of this task that already staged
            for s in st.staged.values() {
                if s.task == task {
                    for (partition, run) in &s.runs {
                        if track {
                            retracted += run.pool_bytes();
                        }
                        emits.push((
                            s.wave_attempt,
                            TraceEvent::RunRetracted { partition: *partition },
                        ));
                    }
                }
            }
            st.staged.retain(|_, s| s.task != task);
        }
        st.task_done[task] = true;
        while st.done_prefix < st.task_done.len() && st.task_done[st.done_prefix] {
            st.done_prefix += 1;
        }
        self.cv.notify_all();
        drop(st);
        self.uncharge(retracted);
        for (wa, ev) in emits {
            self.emit(task, wa, ev);
        }
        true
    }

    /// Dead-letter `task`: the scheduler exhausted its retry budget and
    /// is completing the job without this task's output.  Any staged
    /// attempt is retracted (spill files delete with the run handles),
    /// the task is marked decided with **zero committed runs**, and the
    /// committed-prefix frontier advances past it — so reducers stop
    /// waiting on a task that will never push.
    pub(crate) fn fail_task(&self, task: usize)
    where
        T: SizeEstimate,
    {
        let mut emits: Vec<(u32, TraceEvent)> = Vec::new();
        let track = self.memory.is_some();
        let mut retracted: u64 = 0;
        let mut st = self.state.lock().unwrap();
        if st.task_done[task] {
            return;
        }
        for s in st.staged.values() {
            if s.task == task {
                for (partition, run) in &s.runs {
                    if track {
                        retracted += run.pool_bytes();
                    }
                    emits.push((
                        s.wave_attempt,
                        TraceEvent::RunRetracted { partition: *partition },
                    ));
                }
            }
        }
        st.staged.retain(|_, s| s.task != task);
        st.task_done[task] = true;
        while st.done_prefix < st.task_done.len() && st.task_done[st.done_prefix] {
            st.done_prefix += 1;
        }
        self.cv.notify_all();
        drop(st);
        self.uncharge(retracted);
        for (wa, ev) in emits {
            self.emit(task, wa, ev);
        }
    }

    /// Mark the map wave complete: every run is now committed, every
    /// mailbox's remainder becomes the reducers' final catch-up batch.
    pub fn seal(&self) {
        let mut st = self.state.lock().unwrap();
        assert!(
            st.task_done.iter().all(|d| *d),
            "seal before every map task was decided"
        );
        st.sealed = true;
        self.cv.notify_all();
    }

    /// Seal without the all-tasks-done invariant: the failure path when
    /// the map wave panicked.  Already-parked reducers wake and drain
    /// (their results are discarded by the unwinding driver) — without
    /// this, panicking push jobs would park reduce slots forever — and
    /// the dispatcher exits *without* submitting not-yet-started
    /// partitions, so no user reduce code runs for a job that failed
    /// before feeding it.
    pub(crate) fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.sealed = true;
        st.aborted = true;
        self.cv.notify_all();
    }

    /// Dispatcher wait: block until some unsubmitted partition has a
    /// committed run (submit it now — its reduce task can start) or the
    /// service is sealed (submit everything left, even empty mailboxes —
    /// reduce tasks run their `configure`/`close` hooks regardless).
    /// Returns the partitions to submit plus the sealed flag; an empty
    /// list with the flag set means "stop submitting" (aborted wave).
    pub fn wait_ready(&self, submitted: &[bool]) -> (Vec<usize>, bool) {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return (Vec::new(), true);
            }
            let ready: Vec<usize> = (0..self.num_partitions)
                .filter(|&j| !submitted[j] && (st.arrivals[j] || st.sealed))
                .collect();
            if !ready.is_empty() || st.sealed {
                return (ready, st.sealed);
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Reduce-side wait: block until partition `j` has committed-prefix
    /// runs beyond the `taken` already consumed, or the service seals.
    /// Pre-seal batches (`sealed == false`) contain only prefix-safe runs
    /// — every earlier run position is final, so they may be pre-merged.
    /// Once the flag comes back true the batch is the final remainder
    /// (the catch-up work): nothing further will arrive.
    ///
    /// In [retained-runs](Self::with_retained_runs) mode each run is
    /// *cloned* out instead of moved, so a retried reduce attempt can
    /// restart from `taken == 0` against the intact mailbox.
    pub fn wait_more(&self, j: usize, taken: usize) -> (Vec<Run<T>>, bool)
    where
        T: Clone + SizeEstimate,
    {
        let mut st = self.state.lock().unwrap();
        let (runs, sealed) = loop {
            let limit = run_key(st.done_prefix + 1, 0);
            let eligible = st.committed[j].partition_point(|(k, _)| *k < limit);
            if eligible > taken {
                let runs = Self::hand_out(&mut st.committed[j][taken..eligible], self.retain_runs);
                // post-seal every run is eligible, so a sealed flag here
                // means this batch is already the final one
                break (runs, st.sealed);
            }
            if st.sealed {
                let total = st.committed[j].len();
                let runs = Self::hand_out(&mut st.committed[j][taken..total], self.retain_runs);
                break (runs, true);
            }
            st = self.cv.wait(st).unwrap();
        };
        drop(st);
        // in moving mode the handed-out runs left the mailbox: their
        // bytes return to the pool (this shrink is what unparks a
        // backpressured pusher).  In retained mode the mailbox keeps its
        // copy — release_partition settles the account at task commit.
        if !self.retain_runs && self.memory.is_some() {
            self.uncharge(runs.iter().map(Run::pool_bytes).sum());
        }
        (runs, sealed)
    }

    fn hand_out(slots: &mut [(u64, Option<Run<T>>)], retain: bool) -> Vec<Run<T>>
    where
        T: Clone,
    {
        slots
            .iter_mut()
            .map(|(_, r)| {
                if retain {
                    r.as_ref().expect("run taken twice").clone()
                } else {
                    r.take().expect("run taken twice")
                }
            })
            .collect()
    }

    /// Drop partition `j`'s retained runs after its reduce task
    /// committed: clones handed to the winner keep the data alive, and
    /// the mailbox's spill-file handles must release so run files are
    /// deleted with the job.  No-op in the default (moving) mode, where
    /// the hand-out already emptied the slots.
    pub(crate) fn release_partition(&self, j: usize)
    where
        T: SizeEstimate,
    {
        let mut st = self.state.lock().unwrap();
        let bytes = if self.memory.is_some() {
            st.committed[j]
                .iter()
                .filter_map(|(_, r)| r.as_ref())
                .map(Run::pool_bytes)
                .sum()
        } else {
            0
        };
        st.committed[j].clear();
        drop(st);
        self.uncharge(bytes);
    }

    /// How many runs have been committed into partition `j` so far — the
    /// dead-letter record for a failed reduce task (its lost input, in
    /// runs, at the moment it gave up).
    pub(crate) fn committed_len(&self, j: usize) -> usize {
        self.state.lock().unwrap().committed[j].len()
    }

    /// Live mailbox depth for the metrics sampler
    /// ([`MetricsSpec::register_mailbox_probe`]): committed runs still
    /// parked in mailboxes (not yet handed to a reduce task, or retained
    /// for retry) plus the byte volume staged by undecided attempts.
    /// One scan under the state lock — cheap at sampler cadence.
    ///
    /// [`MetricsSpec::register_mailbox_probe`]:
    ///     crate::metrics::registry::MetricsSpec
    pub(crate) fn depth_stats(&self) -> MailboxStats
    where
        T: SizeEstimate,
    {
        let st = self.state.lock().unwrap();
        let runs = st
            .committed
            .iter()
            .flat_map(|mailbox| mailbox.iter())
            .filter(|(_, run)| run.is_some())
            .count() as u64;
        let staged_bytes = st
            .staged
            .values()
            .flat_map(|s| s.runs.iter())
            .map(|(_, run)| run.estimate_bytes())
            .sum();
        MailboxStats { runs, staged_bytes }
    }
}

/// One map attempt's write handle into the service.
pub struct PushAttempt<T> {
    svc: Arc<ShuffleService<T>>,
    id: u64,
    task: usize,
    /// Scheduler attempt ordinal, stamped on this handle's trace records.
    wave_attempt: u32,
}

impl<T> PushAttempt<T> {
    /// Push one sealed (and combined, and possibly spilled) run for
    /// `partition`.  Visible to reducers immediately in single-attempt
    /// mode, on [`PushAttempt::finish`] in staged mode.  With a memory
    /// pool attached this may block (bounded) or divert the run to disk
    /// — see [`ShuffleService::with_memory`].
    pub fn push(&self, partition: usize, run: Run<T>)
    where
        T: SizeEstimate,
    {
        self.svc
            .push_run(self.id, self.task, self.wave_attempt, partition, run);
    }

    /// Close the attempt: first finisher wins the task, committing its
    /// staged runs; a loser's are retracted.  Returns whether this
    /// attempt won.
    pub fn finish(self) -> bool
    where
        T: SizeEstimate,
    {
        self.svc.commit_task(self.task, self.id)
    }
}

/// Drain partition `j`'s mailbox into ordered reduce sources, pre-merging
/// the committed prefix into a few large in-memory segments while the map
/// wave is still pushing (the overlap work), then appending the final
/// catch-up batch for the reduce task's k-way merge.
///
/// Pre-merging is size-tiered (timsort-style): adjacent segments are only
/// merged while the earlier one is not much larger than the later, which
/// keeps the segment sizes geometrically decreasing — total pre-merge
/// work stays `O(N log runs)` instead of re-copying the whole prefix per
/// batch.  Merging *adjacent* segments preserves the barrier merge order:
/// every record position in an earlier segment precedes every position in
/// a later one, so the stable run-index tie-break is unchanged.
///
/// Folding stops at the first spilled run: inflating run files into
/// memory-resident segments would undo the disk-backed memory bound, so
/// spilled runs (and everything ordered after them) stay as individual
/// sources for the streaming merge.
///
/// Returns `(sources in merge order, late runs, fold seconds)` — late
/// runs are the runs this reducer consumed only in its final catch-up
/// batch (after the wave sealed), reported as [`names::LATE_RUNS`]; fold
/// seconds are the active pre-merge work, excluded wait time, for honest
/// reduce-task timings.
pub(crate) fn collect_reduce_sources<K, V>(
    svc: &ShuffleService<(K, V)>,
    j: usize,
) -> (Vec<Run<(K, V)>>, u64, f64)
where
    K: Ord + Clone + SizeEstimate,
    V: Clone + SizeEstimate,
{
    let mut taken = 0usize;
    // pre-merged prefix segments, in run-position order
    let mut segments: Vec<Vec<(K, V)>> = Vec::new();
    let mut pending: Vec<Run<(K, V)>> = Vec::new();
    let late;
    let mut fold_secs = 0.0f64;
    loop {
        let (batch, sealed) = svc.wait_more(j, taken);
        taken += batch.len();
        if sealed {
            late = batch.len() as u64;
            pending.extend(batch);
            break;
        }
        let t0 = Instant::now();
        for run in batch {
            match run {
                // fold only while the prefix is unbroken by a spilled run
                Run::Mem(v) if pending.is_empty() => segments.push(v),
                other => pending.push(other),
            }
        }
        // tiered compaction: merge the two tail segments while they are
        // of comparable size, so each record is re-merged O(log) times
        while segments.len() >= 2 {
            let n = segments.len();
            if segments[n - 2].len() > 2 * segments[n - 1].len() {
                break;
            }
            let b = segments.pop().expect("tail segment");
            let a = segments.pop().expect("tail segment");
            segments.push(MergeIter::new(vec![a, b]).collect());
        }
        fold_secs += t0.elapsed().as_secs_f64();
    }
    let mut sources: Vec<Run<(K, V)>> = Vec::with_capacity(segments.len() + pending.len());
    sources.extend(segments.into_iter().map(Run::Mem));
    sources.extend(pending);
    (sources, late, fold_secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem(records: &[(u32, u32)]) -> Run<(u32, u32)> {
        Run::Mem(records.to_vec())
    }

    fn service(
        tasks: usize,
        parts: usize,
        staged: bool,
    ) -> (Arc<ShuffleService<(u32, u32)>>, Arc<Counters>) {
        let counters = Arc::new(Counters::new());
        (
            Arc::new(ShuffleService::new(tasks, parts, staged, Arc::clone(&counters))),
            counters,
        )
    }

    #[test]
    fn immediate_mode_pushes_are_visible_mid_task() {
        let (svc, counters) = service(2, 1, false);
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(1, 0)]));
        // visible before the task finishes
        let (batch, sealed) = svc.wait_more(0, 0);
        assert_eq!(batch.len(), 1);
        assert!(!sealed);
        assert_eq!(counters.get(names::PUSHED_RUNS), 1);
        assert!(a0.finish());
        let a1 = ShuffleService::begin_attempt(&svc, 1);
        a1.push(0, mem(&[(2, 0)]));
        assert!(a1.finish());
        svc.seal();
        let (batch, sealed) = svc.wait_more(0, 1);
        assert!(sealed);
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn prefix_holds_back_out_of_order_tasks() {
        let (svc, _) = service(2, 1, false);
        let a1 = ShuffleService::begin_attempt(&svc, 1);
        a1.push(0, mem(&[(9, 0)]));
        assert!(a1.finish());
        // task 0 is still open: task 1's run must not be prefix-eligible
        let probe = {
            let svc2 = Arc::clone(&svc);
            std::thread::spawn(move || svc2.wait_more(0, 0))
        };
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(1, 0)]));
        assert!(a0.finish());
        // now tasks 0 and 1 are both done: both runs eligible, in order
        let (batch, sealed) = probe.join().unwrap();
        assert!(!sealed);
        assert!(!batch.is_empty());
        let first = match &batch[0] {
            Run::Mem(v) => v[0].0,
            _ => unreachable!(),
        };
        assert_eq!(first, 1, "task 0's run must come first");
    }

    #[test]
    fn staged_mode_retracts_losing_attempt() {
        let (svc, counters) = service(1, 2, true);
        let winner = ShuffleService::begin_attempt(&svc, 0);
        let loser = ShuffleService::begin_attempt(&svc, 0);
        winner.push(0, mem(&[(1, 1)]));
        winner.push(1, mem(&[(2, 2)]));
        loser.push(0, mem(&[(1, 1)]));
        // nothing visible before a commit
        {
            let st = svc.state.lock().unwrap();
            assert!(st.committed.iter().all(|m| m.is_empty()));
        }
        assert!(winner.finish());
        assert_eq!(counters.get(names::PUSHED_RUNS), 2);
        // the loser's runs are gone and its late finish changes nothing
        assert!(!loser.finish());
        assert_eq!(counters.get(names::PUSHED_RUNS), 2);
        svc.seal();
        let (batch, sealed) = svc.wait_more(0, 0);
        assert_eq!(batch.len(), 1);
        // with the single task done pre-seal, the run was prefix-eligible
        assert!(!sealed || batch.len() == 1);
        let (rest, sealed) = svc.wait_more(0, 1);
        assert!(sealed);
        assert!(rest.is_empty());
    }

    #[test]
    fn wait_ready_triggers_on_first_run_then_seal() {
        let (svc, _) = service(2, 3, false);
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(1, mem(&[(5, 0)]));
        let (ready, sealed) = svc.wait_ready(&[false, false, false]);
        assert_eq!(ready, vec![1]);
        assert!(!sealed);
        assert!(a0.finish());
        let a1 = ShuffleService::begin_attempt(&svc, 1);
        assert!(a1.finish());
        svc.seal();
        // sealed: every remaining partition is submitted, even empty ones
        let (ready, sealed) = svc.wait_ready(&[false, true, false]);
        assert_eq!(ready, vec![0, 2]);
        assert!(sealed);
    }

    #[test]
    fn retained_runs_can_be_read_twice() {
        let counters = Arc::new(Counters::new());
        let svc = Arc::new(
            ShuffleService::new(1, 1, true, Arc::clone(&counters)).with_retained_runs(true),
        );
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(1, 0), (2, 0)]));
        assert!(a0.finish());
        svc.seal();
        // first read (a reduce attempt that will "panic")
        let (batch, sealed) = svc.wait_more(0, 0);
        assert!(sealed);
        assert_eq!(batch.len(), 1);
        // second read from scratch (the retry) sees the same runs
        let (again, sealed) = svc.wait_more(0, 0);
        assert!(sealed);
        assert_eq!(again.len(), 1);
        assert_eq!(
            again.into_iter().flat_map(Run::into_records).collect::<Vec<_>>(),
            vec![(1, 0), (2, 0)]
        );
        svc.release_partition(0);
        let (empty, sealed) = svc.wait_more(0, 0);
        assert!(sealed);
        assert!(empty.is_empty(), "released mailbox must be empty");
    }

    #[test]
    fn depth_stats_track_staged_then_committed_volumes() {
        let (svc, _) = service(2, 2, true);
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(1, 1), (2, 2)]));
        let d = svc.depth_stats();
        assert_eq!(d.runs, 0, "staged runs are not committed yet");
        assert!(d.staged_bytes > 0, "staged attempt must have volume");
        assert!(a0.finish());
        let d = svc.depth_stats();
        assert_eq!(d.runs, 1);
        assert_eq!(d.staged_bytes, 0, "commit drains the staging area");
        let a1 = ShuffleService::begin_attempt(&svc, 1);
        assert!(a1.finish());
        svc.seal();
        // handing the run to its reducer empties the mailbox
        let _ = svc.wait_more(0, 0);
        assert_eq!(svc.depth_stats().runs, 0);
    }

    #[test]
    fn fail_task_advances_prefix_and_allows_seal() {
        let (svc, _) = service(2, 1, true);
        // task 0 dead-letters: its staged runs retract, prefix advances
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(9, 9)]));
        svc.fail_task(0);
        assert!(!a0.finish(), "a dead-lettered task's attempt cannot win");
        let a1 = ShuffleService::begin_attempt(&svc, 1);
        a1.push(0, mem(&[(1, 0)]));
        assert!(a1.finish());
        svc.seal(); // all tasks decided — must not panic
        let (batch, sealed) = svc.wait_more(0, 0);
        assert!(sealed);
        assert_eq!(batch.len(), 1, "only task 1's run is committed");
        assert_eq!(
            batch.into_iter().flat_map(Run::into_records).collect::<Vec<_>>(),
            vec![(1, 0)]
        );
    }

    #[test]
    fn collect_folds_prefix_and_reports_late_runs() {
        let (svc, _) = service(3, 1, false);
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(1, 0), (5, 0)]));
        a0.push(0, mem(&[(3, 0)]));
        assert!(a0.finish());
        let a1 = ShuffleService::begin_attempt(&svc, 1);
        a1.push(0, mem(&[(2, 0)]));
        assert!(a1.finish());
        // task 2 finishes only "after" the collector starts; run a
        // collector thread against a service we keep feeding
        let svc2 = Arc::clone(&svc);
        let collector = std::thread::spawn(move || collect_reduce_sources(&svc2, 0));
        let a2 = ShuffleService::begin_attempt(&svc, 2);
        a2.push(0, mem(&[(4, 0)]));
        assert!(a2.finish());
        svc.seal();
        let (sources, late, _fold_secs) = collector.join().unwrap();
        // whatever the fold/late split was (timing-dependent), the merged
        // stream must be the globally sorted record sequence
        let merged: Vec<(u32, u32)> =
            MergeIter::from_iters(sources.into_iter().map(Run::into_records).collect()).collect();
        assert_eq!(
            merged.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            vec![1, 2, 3, 4, 5]
        );
        assert!(late <= 1, "only task 2's run can be late, got {late}");
    }

    #[test]
    fn backpressured_push_unblocks_when_reducer_drains() {
        let counters = Arc::new(Counters::new());
        // each (u32, u32) record estimates 8 bytes: two 1-record runs
        // fill the pool exactly
        let pool = MemoryPool::new(16);
        let svc = Arc::new(
            ShuffleService::new(1, 1, false, Arc::clone(&counters)).with_memory(Some(&pool), None),
        );
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(1, 0)]));
        a0.push(0, mem(&[(2, 0)]));
        assert_eq!(pool.reserved_bytes(), 16);
        let pusher = std::thread::spawn(move || {
            // pool full: this push parks until the reducer drains
            a0.push(0, mem(&[(3, 0)]));
            assert!(a0.finish());
        });
        // wait until the pusher is provably parked before draining, so
        // the backpressure path (not a lucky early grant) is what this
        // test exercises
        while counters.get(names::POOL_BACKPRESSURE_WAITS) == 0 {
            std::thread::yield_now();
        }
        let (batch, _) = svc.wait_more(0, 0);
        assert_eq!(batch.len(), 2, "both committed runs drain");
        pusher.join().unwrap();
        svc.seal();
        let (rest, sealed) = svc.wait_more(0, 2);
        assert!(sealed);
        assert_eq!(rest.len(), 1, "the parked push landed after the drain");
        assert_eq!(pool.reserved_bytes(), 0, "drained mailboxes hold no bytes");
        assert!(pool.backpressure_waits() >= 1);
    }

    #[test]
    fn denied_push_diverts_run_to_disk_under_divert_spec() {
        use super::super::sortspill::{KeyValueCodec, TempSpillDir, U32Codec};
        let counters = Arc::new(Counters::new());
        let pool = MemoryPool::new(8);
        let tmp = TempSpillDir::new("push-divert").unwrap();
        let divert = ResolvedSpill {
            dir: tmp.path().to_path_buf(),
            compress: false,
            codec: Arc::new(KeyValueCodec::new(U32Codec, U32Codec)),
        };
        let svc = Arc::new(
            ShuffleService::new(1, 1, false, Arc::clone(&counters))
                .with_memory(Some(&pool), Some(divert)),
        );
        let a0 = ShuffleService::begin_attempt(&svc, 0);
        a0.push(0, mem(&[(1, 0)])); // fills the pool
        a0.push(0, mem(&[(2, 0), (3, 0)])); // denied → written to disk
        assert!(a0.finish());
        assert_eq!(counters.get(names::POOL_SPILL_REQUESTS), 1);
        assert_eq!(counters.get(names::POOL_DENIED_GROWS), 1);
        assert_eq!(pool.reserved_bytes(), 8, "a diverted run costs no pool bytes");
        svc.seal();
        let (batch, sealed) = svc.wait_more(0, 0);
        assert!(sealed);
        assert_eq!(batch.len(), 2);
        assert!(
            matches!(batch[1], Run::Spilled(_)),
            "the denied run must arrive as a run file"
        );
        let merged: Vec<(u32, u32)> = batch.into_iter().flat_map(Run::into_records).collect();
        assert_eq!(merged, vec![(1, 0), (2, 0), (3, 0)]);
    }
}
