//! An in-process MapReduce runtime with Hadoop-0.20 semantics.
//!
//! This is the substrate the paper runs on (Hadoop on a 4-node cluster);
//! we rebuild the parts of its execution model that the paper's algorithms
//! and experiments depend on:
//!
//! * fixed numbers of **map and reduce tasks** scheduled onto a bounded
//!   pool of worker **slots** ("at most two map and reduce tasks per
//!   node"),
//! * user code as `map` / `reduce` functions with **`configure`/`close`**
//!   task lifecycle hooks (RepSN's Algorithm 2 needs per-map-task state),
//! * a user-supplied **partitioner** deciding the reducer for each
//!   intermediate key,
//! * map-side **sort** of each partition bucket, reducer-side **merge**,
//!   so every reduce task sees its input **sorted by key** — the property
//!   SRP builds on,
//! * a **grouping comparator** separate from the sort key (Hadoop's
//!   `setOutputValueGroupingComparator`): JobSN/RepSN sort by the full
//!   composite key but group by its prefix,
//! * per-task **counters** and **phase timings**, which feed the cluster
//!   timing simulator ([`sim`]) used to reproduce the paper's multi-node
//!   speedup figures on this single-machine testbed,
//! * a simulated **DFS** ([`dfs`]) with 128 MB blocks and compressed
//!   sequence files ([`seqfile`]) for job input/output materialization.
//!
//! What we deliberately do **not** model: speculative execution (the paper
//! turns it off), task failure/retry, and rack topology.

pub mod combiner;
pub mod config;
pub mod counters;
pub mod dfs;
pub mod engine;
pub mod seqfile;
pub mod shuffle;
pub mod sim;
pub mod sortspill;
pub mod splits;
pub mod types;

pub use config::JobConfig;
pub use counters::Counters;
pub use engine::{run_job, JobResult, JobStats};
pub use types::{
    Emitter, FnMapTask, FnReduceTask, HashPartitioner, MapTask, MapTaskFactory, Partitioner,
    ReduceTask, ReduceTaskFactory, SizeEstimate, ValuesIter,
};
