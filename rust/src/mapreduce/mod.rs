//! An in-process MapReduce runtime with Hadoop-0.20 semantics and a
//! streaming shuffle pipeline.
//!
//! This is the substrate the paper runs on (Hadoop on a 4-node cluster);
//! we rebuild the parts of its execution model that the paper's algorithms
//! and experiments depend on:
//!
//! * fixed numbers of **map and reduce tasks** scheduled onto a bounded
//!   pool of worker **slots** ("at most two map and reduce tasks per
//!   node"),
//! * user code as `map` / `reduce` functions with **`configure`/`close`**
//!   task lifecycle hooks (RepSN's Algorithm 2 needs per-map-task state),
//! * a user-supplied **partitioner** deciding the reducer for each
//!   intermediate key,
//! * a **grouping comparator** separate from the sort key (Hadoop's
//!   `setOutputValueGroupingComparator`): JobSN/RepSN sort by the full
//!   composite key but group by its prefix,
//! * an optional map-side **combiner** ([`run_job_with_combiner`]) that
//!   pre-reduces sorted runs before the shuffle,
//! * per-task **counters** and **phase timings**, which feed the cluster
//!   timing simulator ([`sim`]) used to reproduce the paper's multi-node
//!   speedup figures on this single-machine testbed,
//! * a simulated **DFS** ([`dfs`]) with 128 MB blocks and compressed
//!   sequence files ([`seqfile`]) for job input/output materialization.
//!
//! ## The streaming intermediate data path
//!
//! The map→shuffle→reduce pipeline never materializes the merged
//! intermediate stream:
//!
//! 1. **Map-side sort & spill** — each map task drains its emitted
//!    records into per-partition [`sortspill::RunSorter`]s.  Without a
//!    sort budget ([`JobConfig::sort_buffer_records`] `= None`) that is
//!    one stable sort per bucket; with one, each bucket's records seal
//!    into bounded sorted runs so no single sort ever touches more than
//!    the budget — Hadoop's `io.sort.mb` spill mechanism.
//! 2. **Combine** — if the job registers a [`Combiner`], every sealed run
//!    is pre-reduced in place before shuffling, shrinking
//!    `SHUFFLE_BYTES` for associative aggregations.
//! 3. **Disk-backed, compressed runs** (optional) — with
//!    [`JobConfig::spill`] set, every sealed (and combined) run is
//!    serialized through a [`sortspill::Codec`] into a run file —
//!    *at seal time*, so runs can leave a still-running map task —
//!    whole-run DEFLATE-compressed by default (the paper's cluster
//!    compresses intermediates, §5.1).  The intermediate currency
//!    becomes the either/or [`sortspill::Run`]: owned in-memory records
//!    *or* a codec-serialized run file — both executors handle both
//!    forms identically.  Map-side memory is released before the
//!    shuffle; reduce-side, spilled records decode through a **chunked
//!    streaming window** ([`sortspill::SPILL_READ_CHUNK`] bytes at a
//!    time, pulled straight off the inflating reader), so peak reduce
//!    memory per run source is a buffer size — partitions larger than
//!    RAM stream end to end.  `SHUFFLE_BYTES` then reports the on-disk
//!    (compressed) volume; `SHUFFLE_BYTES_RAW`, `SPILL_BYTES_WRITTEN`
//!    and `SPILLED_RUNS` report the raw estimate and the spill I/O
//!    alongside.
//! 4. **Shuffle transpose** — the driver only reassigns run *ownership*
//!    (reducer `j` takes every map task's bucket-`j` runs — or their
//!    file handles — in map-task order).  `shuffle_phase_secs` measures
//!    exactly this, so it no longer hides a single-threaded merge stall
//!    between the two waves.
//! 5. **Streaming reduce-side merge** — each reduce task lazily k-way
//!    merges its runs with [`shuffle::MergeIter`] and walks
//!    grouping-comparator groups straight off the heap, buffering only
//!    the current group's values.  Spilled runs stream through the same
//!    merge via [`sortspill::RunRecords`].  The per-reducer merges
//!    therefore run in parallel on the worker pool, and reduce can
//!    start on the first group before the last run is fully consumed.
//!
//! ## Phase structure: barrier vs push
//!
//! Two phase structures execute the same job with byte-identical
//! output:
//!
//! * **Barrier** (the reference path, and the paper's Hadoop 0.20
//!   model): map wave → shuffle transpose → reduce wave, with a hard
//!   barrier between the waves — reduce slots idle for the whole map
//!   phase, which is exactly the structure Figures 8/9 measure.  Both
//!   [`run_job`] (private pools) and the [`scheduler`] (shared slots)
//!   run this flow through one shared driver, so their accounting
//!   cannot drift.
//! * **Push** ([`scheduler::PushMode::Push`] or [`JobConfig::push`], on
//!   the [`scheduler`] only): the [`push::ShuffleService`] replaces the
//!   barrier with per-partition mailboxes — map attempts push each run
//!   as it seals (mid-task under a sort budget), reduce tasks are
//!   submitted at their **first run's arrival** and pre-merge the
//!   committed prefix while maps still run, catching up on late runs
//!   after the wave seals.  [`JobStats::reduce_first_start_secs`] /
//!   [`JobStats::overlap_secs`] quantify the recovered overlap;
//!   `PUSHED_RUNS` / `LATE_RUNS` count the flow.  The simulator's
//!   [`sim::simulate_job_overlap`] models the same structure (release
//!   the reduce wave at the first map completion, never finish before
//!   the last), while the two-wave [`sim::simulate_job`] stays the
//!   calibration reference.
//!
//! The cluster simulator charges the matching costs: a compressed
//! profile shrinks the simulated shuffle and disk materialization but
//! pays DEFLATE CPU ([`sim::JobProfile::compress_secs_per_mb`] /
//! `decompress_secs_per_mb`) — the CPU-vs-network trade the paper's
//! cluster config makes.
//!
//! Task inputs and results are handed to the worker pool through atomic
//! index-owned slots ([`crate::util::threadpool::OnceSlots`]) — no shared
//! mutex on the scatter/gather path.
//!
//! **Per-phase accounting:** `map_phase_secs` covers map + sort + spill +
//! combine; `shuffle_phase_secs` covers the (cheap) transpose;
//! `reduce_phase_secs` and each `reduce_task_secs[j]` cover merge +
//! reduce, since the merge streams inside the reduce task.  The old
//! data path (materialize the full merge on the driver, then unzip) is
//! preserved behaviorally by [`shuffle::merge_sorted_runs`] and checked
//! byte-identical by `tests/prop_shuffle.rs`.
//!
//! ## Architecture: control plane and data plane
//!
//! The engine is layered so that "distributed" is a property of the
//! wiring, not of the algorithms:
//!
//! 1. **Scheduler** ([`scheduler::DistScheduler`] and the in-process
//!    [`JobScheduler`]) — owns the job and task **state machines**:
//!    which attempt of which task is where, retry budgets, speculation
//!    arbitration, loss detection, wave stamps.  The distributed
//!    scheduler is a single event loop that never touches user data; it
//!    only sends and receives typed control messages.
//! 2. **Executors** ([`scheduler::transport`]-connected workers) — own
//!    the **data**: they run `exec_map_task` / `exec_reduce_task` (the
//!    same functions every in-process path calls), hold sealed runs in
//!    a local run store, and serve them to peers.
//! 3. **Transport** ([`scheduler::Transport`], channel-backed today,
//!    socket-shaped by design) — typed control and data links with
//!    explicit failure ([`scheduler::LinkClosed`]) and injectable frame
//!    drops ([`scheduler::TransportFaults`]), so every recovery path is
//!    testable without a network.
//! 4. **Shuffle registry** — map outputs are **location-addressed**:
//!    a completed map registers `(executor, run ids)` per partition
//!    with the scheduler, and reduce tasks *fetch* the runs from the
//!    owning executor over the transport (retrying from the registry on
//!    dropped frames).  Nothing data-sized ever transits the scheduler.
//!
//! The in-process paths ([`run_job`], [`JobScheduler`]) are the
//! **byte-identity reference**: `tests/prop_exec.rs` pins every SN
//! variant's distributed output — across push, faults, executor loss
//! and dropped fetches — to the serial engine's bytes.
//!
//! ## Multi-job execution and speculation
//!
//! [`run_job`] models a cluster running exactly one job.  The
//! [`scheduler`] module models the cluster itself: a [`JobScheduler`]
//! owns one shared pool of map slots and one of reduce slots (the
//! [`sim::ClusterSpec`] slot accounting, made executable), any number of
//! jobs run concurrently against them, and **speculative execution** —
//! which the paper disables in §5.1, and which we previously did not
//! model — clones straggling tasks onto idle slots with
//! first-completion-wins semantics.  See the [`scheduler`] module docs
//! for the slot model, and [`sim::ClusterSpec::speculative`] for the
//! matching simulator knob.
//!
//! Speculation cannot fix *data* skew (a clone re-runs the same oversized
//! partition), so the engine also supports jobs whose **output
//! partitioning is computed by a prior job**: the
//! [`sn::loadbalance`](crate::sn::loadbalance) subsystem runs a Block
//! Distribution Matrix analysis job and uses it to route a second job's
//! reduce work by BlockSplit / PairRange (Kolb et al. 2012).  The engine
//! reports [`JobStats::reduce_task_output_records`](engine::JobStats)
//! per task so that reduce-side skew — and what those strategies do to
//! it — is directly measurable, and
//! [`sim::reduce_secs_from_pairs`]/[`sim::fit_secs_per_pair`] give the
//! simulator the matching per-pair reduce cost model.
//!
//! ## Fault tolerance: retry, dead-letter, checkpoint/resume
//!
//! MapReduce's defining operational property — "the framework re-executes
//! failed tasks" — is modeled end to end:
//!
//! * **Fault injection** ([`fault::FaultPlan`] via [`JobConfig::faults`])
//!   makes a chosen task attempt panic or stall, deterministically and
//!   seedably, so every recovery path below is testable.  The serial
//!   [`run_job`] stays the **fail-fast reference path**: an injected
//!   panic fails the job there, and its output is the byte-identity
//!   baseline the recovery paths are checked against.
//! * **Bounded retry** ([`JobConfig::max_task_retries`] /
//!   [`scheduler::SchedulerConfig::max_task_retries`]): on a scheduler, a
//!   panicked attempt is caught, its staged pushes and spill files
//!   retracted through the same per-attempt machinery that discards
//!   losing speculative clones, and the task resubmitted from its
//!   retained input — up to the budget.  `TASK_RETRIES` counts
//!   resubmissions.  Retry handles *crashed* attempts; *stalled* attempts
//!   are the speculation path's problem ([`scheduler::SpecPolicy`]), and
//!   the two compose: a task can be cloned for slowness and retried for a
//!   panic in the same wave, first-completion-wins arbitrating as usual.
//! * **Dead-lettering** ([`JobConfig::dead_letter`], off by default): a
//!   task that exhausts its retry budget moves its input-split descriptor
//!   into [`JobStats::dead_letters`](engine::JobStats::dead_letters)
//!   (`DEAD_LETTERED` counts them) and the job **completes** with partial
//!   output and [`JobOutcome::Degraded`](engine::JobOutcome) instead of
//!   panicking.  Fail-fast remains the default: without the opt-in, an
//!   exhausted task fails the job like the seed engine always did.
//! * **Checkpoint/resume** ([`checkpoint::CheckpointSpec`] via
//!   [`JobConfig::checkpoint`]): scheduler-executed barrier jobs write a
//!   JSON manifest next to the spill dir as tasks commit — sealed map-run
//!   files per map task, committed reduce partitions (codec permitting).
//!   Re-submitting the job restores manifest-covered tasks
//!   (`TASKS_RESUMED`) and re-runs only the rest; a clean finish deletes
//!   the manifest.  Commit hooks ride the same first-completion-wins
//!   arbiter as speculation, so a losing clone can never checkpoint.
//!
//! The simulator charges the matching cost:
//! [`sim::ClusterSpec::task_failure_rate`] deterministically re-executes
//! a fraction of simulated tasks, lengthening the makespan the way real
//! retries do.
//!
//! Still deliberately unmodeled: rack topology.
//!
//! ## Observability: counters vs stats vs trace
//!
//! Three layers, in increasing resolution — use the cheapest one that
//! answers the question:
//!
//! * **[`Counters`]** — named monotonic totals ("how much"), sharded
//!   atomics, always on.  The SN variants report replication / boundary /
//!   comparison volumes here, and the tests assert the paper's overhead
//!   formulas against them.  No time axis: a counter cannot say *when*
//!   bytes moved or which attempt moved them.
//! * **[`JobStats`](engine::JobStats)** — per-job phase aggregates ("how
//!   long"): wall-clock per phase, per-task seconds, wave metrics
//!   (`map_wave_done_secs`, `reduce_first_start_secs`, `overlap_secs`),
//!   plus per-task runtime/size
//!   [`Histogram`](crate::metrics::histogram::Histogram)s for skew
//!   analysis.  Always on, feeds the [`sim`]
//!   calibration loop.  One number per phase/task: retries, speculative
//!   clones, and retractions are invisible here.
//! * **[`trace`]** — the full story ("what happened, exactly, and
//!   when"): typed per-attempt lifecycle events (scheduled / started /
//!   finished / panicked / retried / cloned / won / lost), run seal /
//!   push / retract, spill I/O, checkpoint commit/restore, dead-letter —
//!   each stamped `(job, phase, task, attempt, wall-clock)`.  Opt-in via
//!   [`JobConfig::trace`]; `Option`-cheap when off.  Drain the spec after
//!   the run and hand the records to
//!   [`crate::metrics::timeline::JobTimeline`] for a per-slot wave Gantt,
//!   or serialize them as JSONL ([`trace::TraceSpec::to_jsonl`]) for
//!   external tooling.  The wave metrics above are *derivable* from the
//!   trace (and `tests/prop_trace.rs` pins the equality); the stats
//!   fields remain as the always-on summary.
//!
//! Rule of thumb: counters for volumes, stats for phase durations and
//! skew summaries, trace for per-attempt forensics and timelines.
//!
//! ## Memory management
//!
//! Concurrent jobs on one scheduler share a single byte budget through
//! the [`memory::MemoryPool`] (attach with
//! [`SchedulerConfig::with_memory_pool`](scheduler::SchedulerConfig::with_memory_pool)
//! or per-job via [`JobConfig::memory`]).  Three layers account under
//! it:
//!
//! * **Map-side sorters** — each map task registers a spillable
//!   consumer and `try_grow`s per emitted record.  A denied grow (or a
//!   fair-spill request) seals the current run *early* — before the
//!   record budget — and routes it through the normal seal path, so
//!   the bytes leave as a spill file or a pushed run.  Early sealing
//!   only changes run boundaries, never record order, so outputs stay
//!   byte-identical to the unpooled engine.
//! * **Push mailboxes** — [`push::ShuffleService`] reserves each
//!   committed/staged in-memory run's bytes.  A denied reservation
//!   either **diverts the run to disk** (when the job has a
//!   [`SpillSpec`] — the run enters the mailbox as a file, costing ~0
//!   pool bytes) or **backpressures the pusher**: the map thread parks
//!   in bounded slices until reducers drain the mailbox
//!   ([`MemoryReservation::park_grow`](memory::MemoryReservation::park_grow)),
//!   re-checking the service's abort flag each slice so a dying wave
//!   still unwinds.  Hand-outs and partition releases shrink the
//!   reservation and wake parked pushers.
//! * **Reduce merge windows** — each reduce task reserves its held
//!   in-memory run bytes plus the bounded streaming-read window
//!   (`max_buffer_bytes`) of every spilled run it merges.
//!
//! **Reservation lifecycle**: register a
//! [`memory::MemoryConsumer`] → receive a
//! [`memory::MemoryReservation`] → `try_grow`/`grow`/`park_grow` to
//! take bytes, `shrink`/`free` to return them; dropping the
//! reservation returns the remainder.  **Fairness rule**: a denial
//! flags the *largest spillable* consumer (preferring one other than
//! the requester) to spill first, so the heaviest elastic holder pays,
//! not whoever asked last.  **Backpressure vs divert-to-disk**: a
//! pusher with a spill codec diverts (cheap, latency-free for the map
//! thread); one without parks until memory returns, with a bounded
//! overdraft escape so no configuration can deadlock.  The scheduler
//! additionally **admission-controls** jobs: a job whose minimum
//! working-set floor cannot be reserved queues before starting tasks
//! ([`memory::MemoryPool::admit`]), and the distributed executors'
//! run stores account their held runs under the same pool.  A `None`
//! pool costs nothing; an unlimited pool never denies — both are
//! byte-identical (output *and* counters) to the unpooled engine.
//!
//! A fourth layer watches the engine itself, *while it runs*: the
//! **metrics registry** ([`crate::metrics::registry`]).  Attach a
//! [`MetricsSpec`](crate::metrics::registry::MetricsSpec) with
//! [`SchedulerConfig::with_metrics`](scheduler::SchedulerConfig::with_metrics)
//! and the scheduler updates typed gauges/counters in-line (queued /
//! running / retried tasks per job, dead letters) while a background
//! [`HealthSampler`](crate::metrics::registry::HealthSampler) snapshots
//! slot occupancy, push-mailbox depth, staged-run bytes and spill-dir
//! bytes on a fixed cadence into a ring of
//! [`EngineSnapshot`](crate::metrics::registry::EngineSnapshot)s —
//! exportable as JSONL, renderable as a text dashboard (the live
//! sibling of the trace-derived Gantt).  `Option`-cheap when off, like
//! trace.  The same layer closes the **calibration loop**: a finished
//! job's measured histograms and phase stamps feed
//! [`sim::ClusterSpec::fit_from_stats`], which fits the simulator's
//! map/reduce/shuffle rates so that [`sim::drift_report`] on the
//! calibrated spec beats the default spec (gated in
//! `benches/engine_ablation.rs`), and the trace-informed
//! [`scheduler::SpecMode::IdleGap`] speculation mode picks clone
//! targets from the live timeline instead of the running median.

pub mod checkpoint;
pub mod combiner;
pub mod config;
pub mod counters;
pub mod dfs;
mod driver;
pub mod engine;
pub mod fault;
pub mod memory;
pub mod push;
pub mod scheduler;
pub mod seqfile;
pub mod shuffle;
pub mod sim;
pub mod sortspill;
pub mod splits;
pub mod trace;
pub mod types;

pub use checkpoint::CheckpointSpec;
pub use combiner::{Combiner, FnCombiner};
pub use config::JobConfig;
pub use counters::Counters;
pub use engine::{run_job, run_job_with_combiner, DeadLetter, JobOutcome, JobResult, JobStats};
pub use fault::{FaultKind, FaultPlan, FaultSpec, TaskPhase};
pub use memory::{MemoryConsumer, MemoryPool, MemoryReservation, ParkOutcome};
pub use push::{PushAttempt, ShuffleService};
pub use scheduler::{
    ChannelTransport, DistConfig, DistScheduler, Exec, JobHandle, JobScheduler, KillPlan,
    LinkClass, LinkClosed, PushMode, SchedulerConfig, SpecMode, SpecPolicy, Transport,
    TransportFaults,
};
pub use shuffle::MergeIter;
pub use sortspill::{
    Codec, DeflateCodec, KeyValueCodec, SpillSpec, StringPairCodec, TempSpillDir,
};
pub use trace::{TraceEvent, TracePhase, TraceRecord, TraceSpec};
pub use types::{
    Emitter, FnMapTask, FnReduceTask, HashPartitioner, MapTask, MapTaskFactory, Partitioner,
    ReduceTask, ReduceTaskFactory, SizeEstimate, ValuesIter,
};
