//! Input splitting: divide the job input into `m` map splits.
//!
//! Mirrors HDFS/InputFormat behaviour at the level the paper depends on:
//! contiguous, near-equal splits, one map task per split, records never
//! straddle splits.  (Figure 3's example: 9 entities → 3 splits of 3.)

/// Split `n` records into `m` contiguous ranges whose sizes differ by at
/// most one.  Returns `(start, end)` half-open ranges; fewer than `m`
/// ranges when `n < m` (Hadoop never schedules an empty split).
pub fn even_splits(n: usize, m: usize) -> Vec<(usize, usize)> {
    assert!(m >= 1);
    if n == 0 {
        return vec![];
    }
    let m = m.min(n);
    let base = n / m;
    let extra = n % m;
    let mut out = Vec::with_capacity(m);
    let mut start = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Split a record count by *byte-budget* like HDFS block-based splitting:
/// greedily pack records (with their sizes) into splits of at most
/// `block_bytes`, never splitting a record.
pub fn byte_splits(sizes: &[usize], block_bytes: usize) -> Vec<(usize, usize)> {
    assert!(block_bytes > 0);
    let mut out = Vec::new();
    let mut start = 0;
    let mut acc = 0usize;
    for (i, &sz) in sizes.iter().enumerate() {
        if acc > 0 && acc + sz > block_bytes {
            out.push((start, i));
            start = i;
            acc = 0;
        }
        acc += sz;
    }
    if start < sizes.len() {
        out.push((start, sizes.len()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_9_into_3() {
        assert_eq!(even_splits(9, 3), vec![(0, 3), (3, 6), (6, 9)]);
    }

    #[test]
    fn uneven_split_distributes_remainder_front() {
        assert_eq!(even_splits(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
    }

    #[test]
    fn more_splits_than_records() {
        assert_eq!(even_splits(2, 5), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn empty_input() {
        assert!(even_splits(0, 4).is_empty());
    }

    #[test]
    fn splits_cover_everything_exactly() {
        for n in [1usize, 7, 100, 1441] {
            for m in [1usize, 2, 3, 8, 16] {
                let s = even_splits(n, m);
                assert_eq!(s.first().unwrap().0, 0);
                assert_eq!(s.last().unwrap().1, n);
                for w in s.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                    assert!(w[0].1 > w[0].0);
                }
                let max = s.iter().map(|(a, b)| b - a).max().unwrap();
                let min = s.iter().map(|(a, b)| b - a).min().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn byte_splits_respect_block_size() {
        let sizes = vec![10, 10, 10, 25, 5, 30, 10];
        let s = byte_splits(&sizes, 30);
        // greedy: [10,10,10][25,5][30][10]
        assert_eq!(s, vec![(0, 3), (3, 5), (5, 6), (6, 7)]);
    }

    #[test]
    fn byte_splits_single_oversized_record() {
        let s = byte_splits(&[100], 10);
        assert_eq!(s, vec![(0, 1)]);
    }
}
