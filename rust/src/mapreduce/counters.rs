//! Job counters (Hadoop-style), shared across tasks.
//!
//! Counters are the engine's observability primitive: every SN variant
//! reports its replication / boundary / comparison counts through them, and
//! the tests assert the paper's overhead formulas against them (e.g.
//! RepSN's replicated entities ≤ `m·(r-1)·(w-1)`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Registry shards: counter names hash onto independent locks so
/// unrelated counters never contend on registration lookups.
const SHARDS: usize = 8;

/// Thread-safe named counters.
///
/// Internally sharded atomics: each counter is an `AtomicU64` cell held in
/// one of [`SHARDS`] name-hashed registries.  An increment is a shared
/// (read) lock on the owning shard plus one `fetch_add` — the exclusive
/// lock is taken only the first time a name is seen.  Hot loops may still
/// accumulate locally and `add` once per task (the SN reducers do), but the
/// per-increment cost no longer serializes every worker through a single
/// mutex the way the original `Mutex<BTreeMap>` implementation did.
#[derive(Debug, Default)]
pub struct Counters {
    shards: [RwLock<BTreeMap<String, Arc<AtomicU64>>>; SHARDS],
}

/// Well-known counter names used by the engine itself.
pub mod names {
    pub const MAP_INPUT_RECORDS: &str = "engine.map_input_records";
    pub const MAP_OUTPUT_RECORDS: &str = "engine.map_output_records";
    pub const MAP_OUTPUT_BYTES: &str = "engine.map_output_bytes";
    /// Intermediate bytes handed to the shuffle.  On the in-memory path
    /// this is the size estimate of every run; with
    /// [`JobConfig::spill`](crate::mapreduce::JobConfig::spill) set it is
    /// the **on-disk run-file volume** — compressed when the spec
    /// compresses, matching the paper's cluster config where reported
    /// intermediate volumes are compressed bytes.
    pub const SHUFFLE_BYTES: &str = "engine.shuffle_bytes";
    /// Pre-compression estimate of the same intermediate bytes; equals
    /// `SHUFFLE_BYTES` on the in-memory path, exceeds it when spill
    /// compression is on (`SHUFFLE_BYTES / SHUFFLE_BYTES_RAW` is the
    /// compression ratio the benches report).
    pub const SHUFFLE_BYTES_RAW: &str = "engine.shuffle_bytes_raw";
    pub const REDUCE_GROUPS: &str = "engine.reduce_groups";
    pub const REDUCE_INPUT_RECORDS: &str = "engine.reduce_input_records";
    pub const REDUCE_OUTPUT_RECORDS: &str = "engine.reduce_output_records";
    pub const SPILLED_RECORDS: &str = "engine.spilled_records";
    /// Sorted runs sealed map-side (1 per bucket without a sort budget;
    /// one per sealed chunk with one).
    pub const MAP_SPILL_RUNS: &str = "engine.map_spill_runs";
    /// Run files written to disk (only present on spill-configured jobs).
    pub const SPILLED_RUNS: &str = "engine.spilled_runs";
    /// Bytes written to spill run files, post-compression (only present
    /// on spill-configured jobs).
    pub const SPILL_BYTES_WRITTEN: &str = "engine.spill_bytes_written";
    /// Records entering / leaving the map-side combiner (only present
    /// when the job registers one).
    pub const COMBINE_INPUT_RECORDS: &str = "engine.combine_input_records";
    pub const COMBINE_OUTPUT_RECORDS: &str = "engine.combine_output_records";
    /// Speculative task attempts cloned onto idle slots by the
    /// [`scheduler`](crate::mapreduce::scheduler)'s straggler detector
    /// (only present on scheduler-executed jobs with speculation enabled).
    pub const SPECULATIVE_LAUNCHED: &str = "engine.speculative_launched";
    /// Speculative attempts that finished before the original task
    /// (first-completion-wins).
    pub const SPECULATIVE_WON: &str = "engine.speculative_won";
    /// Intermediate runs committed through the push-based
    /// [`ShuffleService`](crate::mapreduce::push::ShuffleService) (only
    /// present on push-mode jobs).  Counts winning attempts' runs only:
    /// a retracted speculative attempt's pushes never appear here.
    pub const PUSHED_RUNS: &str = "engine.pushed_runs";
    /// Push-mode runs a reduce task consumed only in its final catch-up
    /// batch (delivered after the map wave sealed) rather than folding
    /// them into its pre-merged prefix while maps were still running.
    /// An upper bound on the truly-late runs: a reducer busy folding may
    /// pick up pre-seal commits in the catch-up batch too.
    pub const LATE_RUNS: &str = "engine.late_runs";
    /// Task attempts resubmitted after a panic, within the
    /// [`max_task_retries`](crate::mapreduce::JobConfig::max_task_retries)
    /// budget (only present on scheduler-executed jobs with retries on).
    pub const TASK_RETRIES: &str = "engine.task_retries";
    /// Tasks whose every attempt (primary + retries) panicked.  On the
    /// default fail-fast path the job dies with the first such task; with
    /// [`dead_letter`](crate::mapreduce::JobConfig::dead_letter) on the
    /// job completes [`Degraded`](crate::mapreduce::engine::JobOutcome).
    pub const TASKS_FAILED: &str = "engine.tasks_failed";
    /// Tasks moved to [`JobStats::dead_letters`]
    /// (crate::mapreduce::engine::JobStats::dead_letters) after
    /// exhausting their retry budget (dead-letter mode only).
    pub const DEAD_LETTERED: &str = "engine.dead_lettered";
    /// Tasks restored from a checkpoint manifest instead of re-executed
    /// (only present on resumed jobs — see
    /// [`JobConfig::checkpoint`](crate::mapreduce::JobConfig::checkpoint)).
    pub const TASKS_RESUMED: &str = "engine.tasks_resumed";
    /// Distributed shuffle: reduce-side source fetches satisfied from the
    /// executor's own run store (no transport round-trip).
    pub const DIST_LOCAL_FETCHES: &str = "engine.dist_local_fetches";
    /// Distributed shuffle: reduce-side source fetches served by a peer
    /// executor over the data plane.
    pub const DIST_REMOTE_FETCHES: &str = "engine.dist_remote_fetches";
    /// Distributed shuffle: fetch attempts re-sent after a timed-out or
    /// torn reply link (see `TransportFaults::drop_data_sends`).
    pub const DIST_FETCH_RETRIES: &str = "engine.dist_fetch_retries";
    /// Executors the distributed scheduler declared dead (failed control
    /// send or terminal fetch failure) and drained via resubmission.
    pub const EXECUTORS_LOST: &str = "engine.executors_lost";
    /// Memory-pool `try_grow` denials observed by this job's tasks (only
    /// present on pool-configured jobs under pressure).
    pub const POOL_DENIED_GROWS: &str = "engine.pool_denied_grows";
    /// Runs this job sealed or diverted to disk because the memory pool
    /// denied a grow or flagged a fair-spill request.
    pub const POOL_SPILL_REQUESTS: &str = "engine.pool_spill_requests";
    /// Pushes that parked (backpressure) waiting for pool bytes to come
    /// back from reducers draining the mailboxes.
    pub const POOL_BACKPRESSURE_WAITS: &str = "engine.pool_backpressure_waits";
}

/// FNV-1a — the crate's standard cheap string hash; picks the shard.
fn shard_of(name: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % SHARDS as u64) as usize
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    /// The atomic cell for `name`, creating it at 0 on first touch.  The
    /// fast path is a shared lock + map lookup; the exclusive lock runs
    /// once per distinct name per shard.
    fn cell(&self, name: &str) -> Arc<AtomicU64> {
        let shard = &self.shards[shard_of(name)];
        if let Some(c) = shard.read().unwrap().get(name) {
            return Arc::clone(c);
        }
        let mut m = shard.write().unwrap();
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Add `delta` to counter `name` (creates it at 0 first).
    pub fn add(&self, name: &str, delta: u64) {
        self.cell(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Current value (0 if never touched).
    pub fn get(&self, name: &str) -> u64 {
        let shard = &self.shards[shard_of(name)];
        shard
            .read()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Snapshot of all counters, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let mut all = BTreeMap::new();
        for shard in &self.shards {
            for (k, v) in shard.read().unwrap().iter() {
                all.insert(k.clone(), v.load(Ordering::Relaxed));
            }
        }
        all.into_iter().collect()
    }

    /// Merge another counter set into this one.  Zero-valued entries are
    /// carried over too, so the merged snapshot lists every name the
    /// source ever touched.
    pub fn merge(&self, other: &Counters) {
        for (k, v) in other.snapshot() {
            self.add(&k, v);
        }
    }

    /// Render as an aligned text table (for CLI / bench reports).
    pub fn render(&self) -> String {
        let snap = self.snapshot();
        let width = snap.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
        let mut s = String::new();
        for (k, v) in snap {
            s.push_str(&format!(
                "  {k:<width$}  {}\n",
                crate::util::humanize::commas(v)
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_get_inc() {
        let c = Counters::new();
        assert_eq!(c.get("x"), 0);
        c.add("x", 5);
        c.inc("x");
        assert_eq!(c.get("x"), 6);
    }

    #[test]
    fn concurrent_increments_are_exact() {
        let c = Arc::new(Counters::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc("n");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get("n"), 8000);
    }

    #[test]
    fn concurrent_distinct_names_land_in_shards_exactly() {
        let c = Arc::new(Counters::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let name = format!("counter.{t}");
                for _ in 0..500 {
                    c.inc(&name);
                    c.inc("shared");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..8 {
            assert_eq!(c.get(&format!("counter.{t}")), 500);
        }
        assert_eq!(c.get("shared"), 4000);
    }

    #[test]
    fn merge_sums() {
        let a = Counters::new();
        let b = Counters::new();
        a.add("x", 1);
        b.add("x", 2);
        b.add("y", 3);
        a.merge(&b);
        assert_eq!(a.get("x"), 3);
        assert_eq!(a.get("y"), 3);
    }

    #[test]
    fn merge_carries_zero_entries() {
        let a = Counters::new();
        let b = Counters::new();
        b.add("touched_at_zero", 0);
        a.merge(&b);
        let names: Vec<String> = a.snapshot().into_iter().map(|(k, _)| k).collect();
        assert_eq!(names, vec!["touched_at_zero".to_string()]);
        assert_eq!(a.get("touched_at_zero"), 0);
    }

    #[test]
    fn snapshot_sorted() {
        let c = Counters::new();
        c.add("z", 1);
        c.add("a", 2);
        c.add("m", 3);
        let snap = c.snapshot();
        let names: Vec<&str> = snap.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, vec!["a", "m", "z"]);
    }
}
