//! Multi-job slot scheduler: concurrent job execution on one shared
//! worker pool, with speculative execution.
//!
//! ## The slot model
//!
//! Hadoop schedules tasks onto a fixed number of per-node **map slots**
//! and **reduce slots** (§5.1: "each node was configured to run at most
//! two map and reduce tasks in parallel") that are shared by *every* job
//! in the cluster — submitting a second job does not buy more slots, it
//! contends for the same ones.  The serial [`run_job`] driver models a
//! cluster running exactly one job: it spins up a private pool per phase.
//! This module models the cluster itself:
//!
//! * a [`JobScheduler`] owns one map pool and one reduce pool (mirroring
//!   [`ClusterSpec::map_slots`]/[`ClusterSpec::reduce_slots`] accounting);
//! * any number of jobs run concurrently ([`JobScheduler::submit`] spawns
//!   a lightweight driver thread per job and returns a [`JobHandle`];
//!   [`JobScheduler::run`] drives a job inline on the caller's thread);
//! * map/reduce *tasks* of independent jobs interleave FIFO across the
//!   shared slots — job A's reduce wave can overlap job B's map wave,
//!   exactly as on a real cluster;
//! * each job still gets its own [`JobStats`] and [`Counters`], so
//!   per-job simulator profiles stay meaningful;
//! * a **DAG** of jobs is expressed with handles: join a prerequisite
//!   before submitting the dependent job (`sn::jobsn` chains two jobs
//!   this way; `sn::multipass` fans out independent per-key jobs).
//!
//! ## Speculative execution
//!
//! The paper disables speculation (§5.1), and its skew study (Fig. 9)
//! shows why that matters: stragglers dominate makespan.  With
//! `speculative = true` the scheduler clones any running task whose
//! elapsed time exceeds `slowdown ×` the running median of completed task
//! durations onto an *idle* slot; the first attempt to finish wins (an
//! atomic [`OnceSlots::try_put`](crate::util::threadpool::OnceSlots::try_put)
//! race), the loser's result and counters are discarded.  Task bodies are
//! deterministic functions of their input, so speculation never changes
//! job output — only, possibly, the makespan.  New counters
//! [`names::SPECULATIVE_LAUNCHED`] / [`names::SPECULATIVE_WON`] report
//! what it did; [`ClusterSpec::speculative`] is the matching simulator
//! knob, so simulated and measured makespans stay comparable.
//!
//! Both execution paths share the exact same task bodies
//! ([`engine::exec_map_task`](super::engine) / `exec_reduce_task`), which
//! makes "scheduler output == serial output" structural rather than
//! per-job luck; `tests/prop_sched.rs` asserts it property-style.
//!
//! ## Push-based shuffle
//!
//! With [`PushMode::Push`] (scheduler-wide) or
//! [`JobConfig::push`](crate::mapreduce::JobConfig::push) (per job), a
//! job's map→reduce barrier disappears: map attempts push each sealed
//! run into the job's [`ShuffleService`](super::push::ShuffleService)
//! mailboxes the moment it exists, a dispatcher thread submits each
//! reduce task to the shared reduce slots at its **first run's
//! arrival**, and reducers pre-merge the committed run prefix while the
//! map wave is still running (the overlap the two-wave model forfeits —
//! the communication/computation overlap Afrati et al. point to).
//! Output stays byte-identical to the barrier path, which remains the
//! reference baseline; see the [`push`](super::push) module docs for the
//! ordering and speculation-retraction rules, and
//! [`JobStats::overlap_secs`] for the measured effect.

mod speculate;

pub use speculate::SpecPolicy;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::combiner::{combine_sorted_bucket, Combiner};
use super::config::JobConfig;
use super::counters::{names, Counters};
use super::driver;
use super::engine::{
    exec_map_task, exec_reduce_task, record_reduce_wave, run_job, run_job_with_combiner,
    split_input, CombineFn, GroupFn, JobResult, JobStats, MapTaskOutput, ReduceTaskOutput,
};
use super::push::{self, ShuffleService};
use super::sim::ClusterSpec;
use super::sortspill::{ResolvedSpill, Run};
use super::types::{MapTaskFactory, Partitioner, ReduceTaskFactory, SizeEstimate};
use crate::util::threadpool::{OnceSlots, ThreadPool};

/// Whether jobs on this scheduler ship intermediates through the barrier
/// shuffle or the push-based [`ShuffleService`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PushMode {
    /// Two synchronous waves per job: every reduce task starts only
    /// after the whole map wave (the paper's Hadoop 0.20 model — the
    /// reference path every push run is checked against).
    #[default]
    Barrier,
    /// Run-granular flow: map attempts push each sealed run into
    /// per-partition mailboxes and a job's reduce tasks are submitted to
    /// the shared reduce slots as soon as their first runs arrive,
    /// overlapping the job's reduce wave with its *own* map wave.
    Push,
}

/// Scheduler shape: shared slot counts plus the speculation and shuffle
/// knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Concurrent map tasks across *all* jobs.
    pub map_slots: usize,
    /// Concurrent reduce tasks across *all* jobs.
    pub reduce_slots: usize,
    /// Clone stragglers onto idle slots (first-completion-wins).
    pub speculative: bool,
    /// Straggler-detection thresholds.
    pub policy: SpecPolicy,
    /// Barrier or push-based shuffle for every job on this scheduler
    /// (a single job can also opt in via
    /// [`JobConfig::push`](crate::mapreduce::JobConfig::push)).
    pub push: PushMode,
}

impl SchedulerConfig {
    /// `n` map slots and `n` reduce slots, speculation off, barrier
    /// shuffle.
    pub fn slots(n: usize) -> Self {
        Self {
            map_slots: n.max(1),
            reduce_slots: n.max(1),
            speculative: false,
            policy: SpecPolicy::default(),
            push: PushMode::Barrier,
        }
    }

    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculative = on;
        self
    }

    pub fn with_policy(mut self, policy: SpecPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Select the shuffle mode for every job on this scheduler.
    pub fn with_push(mut self, push: PushMode) -> Self {
        self.push = push;
        self
    }

    /// Mirror a simulated cluster's slot counts and speculation knob, so
    /// measured and simulated makespans stay comparable.
    pub fn from_cluster(spec: &ClusterSpec) -> Self {
        Self {
            map_slots: spec.map_slots().max(1),
            reduce_slots: spec.reduce_slots().max(1),
            speculative: spec.speculative,
            policy: SpecPolicy::default(),
            push: PushMode::Barrier,
        }
    }
}

struct SchedInner {
    cfg: SchedulerConfig,
    map_pool: ThreadPool,
    reduce_pool: ThreadPool,
}

/// The shared-slot multi-job scheduler.  Cheap to clone (all clones share
/// the same pools); dropping the last clone joins the worker threads.
#[derive(Clone)]
pub struct JobScheduler {
    inner: Arc<SchedInner>,
}

/// A submitted job's pending result.
pub struct JobHandle<KO, VO> {
    handle: JoinHandle<JobResult<KO, VO>>,
}

impl<KO, VO> JobHandle<KO, VO> {
    /// Block until the job finishes.  DAG edges between jobs are expressed
    /// by joining a prerequisite's handle before submitting the dependent
    /// job.  Panics inside the job's tasks resurface here.
    pub fn join(self) -> JobResult<KO, VO> {
        match self.handle.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

impl JobScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let map_pool = ThreadPool::new(cfg.map_slots);
        let reduce_pool = ThreadPool::new(cfg.reduce_slots);
        Self {
            inner: Arc::new(SchedInner {
                cfg,
                map_pool,
                reduce_pool,
            }),
        }
    }

    /// Shorthand: `n` map + `n` reduce slots, speculation off.
    pub fn with_slots(n: usize) -> Self {
        Self::new(SchedulerConfig::slots(n))
    }

    pub fn map_slots(&self) -> usize {
        self.inner.map_pool.size()
    }

    pub fn reduce_slots(&self) -> usize {
        self.inner.reduce_pool.size()
    }

    pub fn speculative(&self) -> bool {
        self.inner.cfg.speculative
    }

    pub fn push_mode(&self) -> PushMode {
        self.inner.cfg.push
    }

    /// Run one job inline on the caller's thread; its tasks execute on the
    /// scheduler's shared slots.  Signature mirrors [`run_job`], with the
    /// extra `Clone`/`Sync` bounds speculation needs to re-run a task from
    /// its retained input.  `config.workers` is ignored — slot counts come
    /// from the scheduler.
    pub fn run<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(config, input, mapper, partitioner, grouping, reducer, None)
    }

    /// As [`JobScheduler::run`], with a map-side combiner.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(
            config,
            input,
            mapper,
            partitioner,
            grouping,
            reducer,
            Some(make_combine_fn(combiner)),
        )
    }

    /// Submit a job for concurrent execution: a driver thread is spawned
    /// for the job and a [`JobHandle`] returned immediately.  All
    /// submitted jobs' tasks interleave on the scheduler's shared slots.
    pub fn submit<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.spawn_driver(config, input, mapper, partitioner, grouping, reducer, None)
    }

    /// As [`JobScheduler::submit`], with a map-side combiner.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.spawn_driver(
            config,
            input,
            mapper,
            partitioner,
            grouping,
            reducer,
            Some(make_combine_fn(combiner)),
        )
    }

    /// The one driver-thread spawn point behind `submit*`.
    #[allow(clippy::too_many_arguments)]
    fn spawn_driver<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        let sched = self.clone();
        let handle = std::thread::Builder::new()
            .name(format!("snmr-job-{}", config.name))
            .spawn(move || {
                sched.run_inner(
                    &config,
                    input,
                    mapper,
                    partitioner,
                    grouping,
                    reducer,
                    combine_fn,
                )
            })
            .expect("spawn job driver");
        JobHandle { handle }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_inner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        if self.inner.cfg.push == PushMode::Push || config.push {
            return self.run_push(config, input, mapper, partitioner, grouping, reducer, combine_fn);
        }
        let spec = self.inner.cfg.speculative.then(|| self.inner.cfg.policy.clone());
        let counters = Arc::new(Counters::new());
        let r = config.num_reduce_tasks;
        let sort_budget = config.sort_buffer_records;
        // same spill plumbing as the serial driver: resolve the codec
        // once, hand it to every map attempt (speculative clones write
        // their own run files; only the winner's reach the shuffle)
        let spill: Option<ResolvedSpill<(KT, VT)>> = config.spill.as_ref().map(|s| s.resolve());
        let has_combiner = combine_fn.is_some();

        // ---- the two barrier waves, on the shared slots -------------------
        // Each attempt runs against private counters; only the winning
        // attempt's are merged, so a losing speculative clone never
        // double-counts user-code increments.  Without speculation each
        // attempt is the sole owner of its split and consumes it in
        // place; a speculative wave retains a reference per task (so a
        // clone can re-run it), which forces the deep-clone fallback.
        let map_wave = {
            let sched = self.clone();
            let mapper = Arc::clone(&mapper);
            let partitioner = Arc::clone(&partitioner);
            let counters = Arc::clone(&counters);
            let spec = spec.clone();
            move |splits: Vec<Vec<(KI, VI)>>| {
                let map_attempt = move |_i: usize, split: Arc<Vec<(KI, VI)>>| {
                    let local = Counters::new();
                    let split = Arc::try_unwrap(split).unwrap_or_else(|shared| (*shared).clone());
                    let out = exec_map_task(
                        split,
                        r,
                        sort_budget,
                        spill.as_ref(),
                        mapper.as_ref(),
                        partitioner.as_ref(),
                        combine_fn.as_ref(),
                        &local,
                        None,
                    );
                    (out, local)
                };
                let map_results: Vec<(MapTaskOutput<KT, VT>, Counters)> = speculate::run_tasks(
                    &sched.inner.map_pool,
                    splits,
                    Arc::new(map_attempt),
                    spec,
                    &counters,
                );
                let mut map_outputs = Vec::with_capacity(map_results.len());
                for (out, local) in map_results {
                    counters.merge(&local);
                    map_outputs.push(out);
                }
                map_outputs
            }
        };
        let reduce_wave = {
            let sched = self.clone();
            let reducer = Arc::clone(&reducer);
            let grouping = Arc::clone(&grouping);
            let counters = Arc::clone(&counters);
            move |per_reducer_runs: Vec<Vec<Run<(KT, VT)>>>| {
                let reduce_attempt = move |_j: usize, runs: Arc<Vec<Run<(KT, VT)>>>| {
                    let local = Counters::new();
                    let runs = Arc::try_unwrap(runs).unwrap_or_else(|shared| (*shared).clone());
                    let out = exec_reduce_task(runs, reducer.as_ref(), grouping.as_ref(), &local);
                    (out, local)
                };
                let red_results: Vec<(ReduceTaskOutput<KO, VO>, Counters)> = speculate::run_tasks(
                    &sched.inner.reduce_pool,
                    per_reducer_runs,
                    Arc::new(reduce_attempt),
                    spec,
                    &counters,
                );
                let mut red_outputs = Vec::with_capacity(red_results.len());
                for (out, local) in red_results {
                    counters.merge(&local);
                    red_outputs.push(out);
                }
                red_outputs
            }
        };
        driver::drive_barrier_job(config, input, &counters, has_combiner, map_wave, reduce_wave)
    }

    /// The push-based shuffle path: no map→reduce barrier.  Map attempts
    /// push every sealed run into the job's [`ShuffleService`] mailboxes
    /// (mid-task when a sort budget seals early), a dispatcher thread
    /// submits each reduce task to the shared reduce slots at its first
    /// run's arrival, and reducers pre-merge the committed prefix while
    /// the map wave is still running, catching up on late runs after the
    /// seal.  Output is byte-identical to the barrier path (same task
    /// bodies, same merge order — `tests/prop_push.rs`).
    ///
    /// Speculation applies to the map wave (staged pushes, losing
    /// attempts retracted); reduce tasks are event-driven singletons —
    /// their elapsed time includes waiting on mailboxes, which would
    /// defeat the straggler detector's runtime comparison.
    #[allow(clippy::too_many_arguments)]
    fn run_push<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        let inner = &self.inner;
        let spec = inner.cfg.speculative.then(|| inner.cfg.policy.clone());
        let t_start = Instant::now();
        let counters = Arc::new(Counters::new());
        let r = config.num_reduce_tasks;
        let sort_budget = config.sort_buffer_records;
        let spill: Option<ResolvedSpill<(KT, VT)>> = config.spill.as_ref().map(|s| s.resolve());
        let compressed_spill = config.spill.as_ref().map(|s| s.compress()).unwrap_or(false);

        counters.add(names::MAP_INPUT_RECORDS, input.len() as u64);
        let splits = split_input(input, config.num_map_tasks);
        let m = splits.len();

        // one mailbox per reduce partition; staged (retractable) pushes
        // exactly when more than one attempt per task can exist
        let service: Arc<ShuffleService<(KT, VT)>> = Arc::new(ShuffleService::new(
            m,
            r,
            spec.is_some(),
            Arc::clone(&counters),
        ));
        // each slot holds (output, task-local counters, execution-start
        // seconds) — the start stamp is taken on the reduce slot itself,
        // so overlap_secs reports real execution overlap even when slot
        // contention delays a submitted task
        let results: Arc<OnceSlots<(ReduceTaskOutput<KO, VO>, Counters, f64)>> =
            Arc::new(OnceSlots::empty(r));
        // (finished, panicked) reduce tasks — the driver's completion gate
        let done: Arc<(Mutex<(usize, usize)>, Condvar)> =
            Arc::new((Mutex::new((0, 0)), Condvar::new()));

        // ---- dispatcher: event-driven reduce submission -------------------
        // Runs until every partition is submitted: on first-run arrival
        // for eager partitions, at seal for the rest (reduce tasks run
        // their configure/close hooks even on empty input).
        let dispatcher = {
            let sched = self.clone();
            let service = Arc::clone(&service);
            let reducer = Arc::clone(&reducer);
            let grouping = Arc::clone(&grouping);
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            std::thread::Builder::new()
                .name(format!("snmr-push-{}", config.name))
                .spawn(move || {
                    let mut submitted = vec![false; r];
                    let mut left = r;
                    while left > 0 {
                        let (ready, sealed) = service.wait_ready(&submitted);
                        if ready.is_empty() && sealed {
                            // aborted map wave: never start reduce tasks
                            // for a job that failed before feeding them
                            break;
                        }
                        for j in ready {
                            submitted[j] = true;
                            left -= 1;
                            let service = Arc::clone(&service);
                            let reducer = Arc::clone(&reducer);
                            let grouping = Arc::clone(&grouping);
                            let results = Arc::clone(&results);
                            let done = Arc::clone(&done);
                            sched.inner.reduce_pool.execute(move || {
                                let started = t_start.elapsed().as_secs_f64();
                                let outcome = catch_unwind(AssertUnwindSafe(|| {
                                    let local = Counters::new();
                                    let (sources, late, fold_secs) =
                                        push::collect_reduce_sources(&service, j);
                                    if late > 0 {
                                        local.add(names::LATE_RUNS, late);
                                    }
                                    let mut out = exec_reduce_task(
                                        sources,
                                        reducer.as_ref(),
                                        grouping.as_ref(),
                                        &local,
                                    );
                                    // the pre-merge folding is reduce work
                                    // too (the waits are not measured)
                                    out.secs += fold_secs;
                                    (out, local, started)
                                }));
                                let (lock, cv) = &*done;
                                let mut g = lock.lock().unwrap();
                                match outcome {
                                    Ok(pair) => {
                                        results.put(j, pair);
                                        g.0 += 1;
                                    }
                                    Err(_) => {
                                        g.0 += 1;
                                        g.1 += 1;
                                    }
                                }
                                cv.notify_all();
                            });
                        }
                    }
                })
                .expect("spawn push dispatcher")
        };

        // ---- map wave on the shared map slots, pushing as runs seal -------
        let t_map = Instant::now();
        let map_attempt = {
            let mapper = Arc::clone(&mapper);
            let partitioner = Arc::clone(&partitioner);
            let combine_fn = combine_fn.clone();
            let spill = spill.clone();
            let service = Arc::clone(&service);
            move |i: usize, split: Arc<Vec<(KI, VI)>>| {
                let local = Counters::new();
                let split = Arc::try_unwrap(split).unwrap_or_else(|shared| (*shared).clone());
                let attempt = ShuffleService::begin_attempt(&service, i);
                let out = exec_map_task(
                    split,
                    r,
                    sort_budget,
                    spill.as_ref(),
                    mapper.as_ref(),
                    partitioner.as_ref(),
                    combine_fn.as_ref(),
                    &local,
                    Some(&attempt),
                );
                // first finisher wins the task; a loser's pushes are
                // retracted before reducers could ever fold them
                let _won = attempt.finish();
                (out, local)
            }
        };
        let wave = AssertUnwindSafe(|| {
            speculate::run_tasks(&inner.map_pool, splits, Arc::new(map_attempt), spec, &counters)
        });
        let map_results: Vec<(MapTaskOutput<KT, VT>, Counters)> = match catch_unwind(wave) {
            Ok(results) => results,
            Err(panic) => {
                // unblock the reducers and the dispatcher before
                // unwinding, or they would park reduce slots forever
                service.abort();
                let _ = dispatcher.join();
                std::panic::resume_unwind(panic);
            }
        };
        let mut map_outputs: Vec<MapTaskOutput<KT, VT>> = Vec::with_capacity(map_results.len());
        for (out, local) in map_results {
            counters.merge(&local);
            map_outputs.push(out);
        }
        let map_phase_secs = t_map.elapsed().as_secs_f64();
        let map_wave_done_secs = t_start.elapsed().as_secs_f64();

        let mut stats = JobStats {
            map_phase_secs,
            map_wave_done_secs,
            ..Default::default()
        };
        // the exact accounting fold the barrier driver runs — the runs
        // themselves already flowed through the service, so the returned
        // per-reducer lists are empty and only the byte sums matter
        // (attempts are deterministic: the winning outputs' volumes equal
        // what the committed runs carried)
        let _ = driver::record_map_phase(
            &mut stats,
            &counters,
            map_outputs,
            r,
            combine_fn.is_some(),
            compressed_spill,
        );

        // every task decided → every run committed: wake the reducers for
        // their catch-up pass and flush the dispatcher's remainder
        service.seal();
        dispatcher.join().expect("push dispatcher panicked");

        // ---- gather the event-driven reduce wave --------------------------
        {
            let (lock, cv) = &*done;
            let mut g = lock.lock().unwrap();
            while g.0 < r {
                g = cv.wait(g).unwrap();
            }
            assert_eq!(g.1, 0, "{} push reduce task attempt(s) panicked", g.1);
        }
        let mut red_outputs: Vec<ReduceTaskOutput<KO, VO>> = Vec::with_capacity(r);
        let mut first_start = f64::INFINITY;
        for j in 0..r {
            let (out, local, started) = results.take(j);
            counters.merge(&local);
            first_start = first_start.min(started);
            red_outputs.push(out);
        }
        stats.reduce_first_start_secs = if first_start.is_finite() { first_start } else { 0.0 };
        stats.overlap_secs = (map_wave_done_secs - stats.reduce_first_start_secs).max(0.0);
        stats.reduce_phase_secs =
            (t_start.elapsed().as_secs_f64() - stats.reduce_first_start_secs).max(0.0);
        stats.reduce_task_secs = red_outputs.iter().map(|o| o.secs).collect();
        stats.reduce_task_output_records =
            red_outputs.iter().map(|o| o.output.len() as u64).collect();
        stats.reduce_output_records = record_reduce_wave(&counters, &red_outputs);
        let outputs: Vec<Vec<(KO, VO)>> = red_outputs.into_iter().map(|o| o.output).collect();
        stats.total_secs = t_start.elapsed().as_secs_f64();

        JobResult {
            outputs,
            counters,
            stats,
        }
    }
}

/// Wrap a [`Combiner`] into the engine's type-erased combine step (the
/// same fold [`run_job_with_combiner`] builds on the serial path).
fn make_combine_fn<KT, VT>(combiner: Arc<dyn Combiner<KT, VT>>) -> CombineFn<KT, VT>
where
    KT: Ord + Clone + SizeEstimate + 'static,
    VT: SizeEstimate + 'static,
{
    Arc::new(move |run: &mut Vec<(KT, VT)>, c: &Counters| {
        combine_sorted_bucket(run, combiner.as_ref(), c)
    })
}

/// How a caller executes an engine job: on a job-private pool (the
/// serial [`run_job`] driver), or through a shared [`JobScheduler`] whose
/// slots are contended by every concurrently submitted job.
#[derive(Clone, Copy)]
pub enum Exec<'a> {
    /// `run_job` / `run_job_with_combiner` on a job-private pool.
    Serial,
    /// Tasks on the scheduler's shared slots (inline on this thread).
    Scheduler(&'a JobScheduler),
}

impl Exec<'_> {
    /// Dispatch a job to this executor.
    pub fn run_job<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        match self {
            Exec::Serial => run_job(config, input, mapper, partitioner, grouping, reducer),
            Exec::Scheduler(s) => s.run(config, input, mapper, partitioner, grouping, reducer),
        }
    }

    /// Dispatch a combiner job to this executor.
    #[allow(clippy::too_many_arguments)]
    pub fn run_job_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        match self {
            Exec::Serial => run_job_with_combiner(
                config,
                input,
                mapper,
                partitioner,
                grouping,
                reducer,
                combiner,
            ),
            Exec::Scheduler(s) => s.run_with_combiner(
                config,
                input,
                mapper,
                partitioner,
                grouping,
                reducer,
                combiner,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::types::{Emitter, FnMapTask, FnReduceTask, HashPartitioner, ValuesIter};
    use std::time::Duration;

    fn busy_wait(d: Duration) {
        let t0 = Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    fn histogram_job(
        n: u64,
        modulus: u64,
    ) -> (
        Vec<((), u64)>,
        Arc<FnMapTask<impl Fn((), u64, &mut Emitter<u64, u64>, &Counters)>>,
        Arc<FnReduceTask<impl Fn(&u64, ValuesIter<'_, u64>, &mut Emitter<u64, u64>, &Counters)>>,
    ) {
        let input: Vec<((), u64)> = (0..n).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            move |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(v % modulus, 1);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        (input, mapper, reducer)
    }

    fn grouping() -> GroupFn<u64> {
        Arc::new(|a: &u64, b: &u64| a == b)
    }

    #[test]
    fn scheduler_matches_serial_run_job() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let cfg = JobConfig::named("hist").with_tasks(4, 3).with_workers(2);
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let sched = JobScheduler::with_slots(3);
        let scheduled = sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        assert_eq!(serial.counters.snapshot(), scheduled.counters.snapshot());
        assert_eq!(
            serial.stats.map_output_records,
            scheduled.stats.map_output_records
        );
        assert_eq!(
            serial.stats.reduce_output_records,
            scheduled.stats.reduce_output_records
        );
    }

    #[test]
    fn disk_backed_job_on_scheduler_matches_serial() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("sched-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("hist-disk")
            .with_tasks(4, 3)
            .with_workers(2)
            .with_sort_buffer(Some(32))
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let scheduled = JobScheduler::with_slots(3).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        // run files and their contents are deterministic, so even the
        // byte-level spill counters agree across executors
        assert_eq!(serial.counters.snapshot(), scheduled.counters.snapshot());
        assert!(serial.counters.get(names::SPILLED_RUNS) > 0);
        assert_eq!(
            serial.stats.spill_bytes_written,
            scheduled.stats.spill_bytes_written
        );
    }

    #[test]
    fn speculation_composes_with_disk_backed_runs() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                busy_wait(Duration::from_millis(if v == 7 { 120 } else { 1 }));
                out.emit(v % 3, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let dir = TempSpillDir::new("sched-spec-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("straggle-disk")
            .with_tasks(8, 2)
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let plain = JobScheduler::with_slots(4).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let spec = JobScheduler::new(SchedulerConfig::slots(4).with_speculation(true)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        // losing attempts' run files are discarded (and deleted); output
        // and engine counters stay identical
        assert_eq!(plain.outputs, spec.outputs);
        assert_eq!(
            plain.counters.get(names::SHUFFLE_BYTES),
            spec.counters.get(names::SHUFFLE_BYTES)
        );
        assert_eq!(
            plain.counters.get(names::SPILL_BYTES_WRITTEN),
            spec.counters.get(names::SPILL_BYTES_WRITTEN)
        );
    }

    #[test]
    fn concurrent_jobs_share_slots_and_keep_separate_stats() {
        let sched = JobScheduler::with_slots(4);
        let mut handles = Vec::new();
        for j in 0..3u64 {
            let (input, mapper, reducer) = histogram_job(400 + 100 * j, 5 + j);
            let cfg = JobConfig::named(&format!("job{j}")).with_tasks(4, 2);
            handles.push(sched.submit(
                cfg,
                input,
                mapper,
                Arc::new(HashPartitioner::new(|k: &u64| *k)),
                grouping(),
                reducer,
            ));
        }
        for (j, h) in handles.into_iter().enumerate() {
            let j = j as u64;
            let res = h.join();
            let n = 400 + 100 * j;
            let total: u64 = res.outputs.iter().flatten().map(|(_, c)| *c).sum();
            assert_eq!(total, n, "job {j} lost records");
            assert_eq!(res.stats.map_task_secs.len(), 4);
            assert_eq!(res.counters.get(names::MAP_INPUT_RECORDS), n);
        }
    }

    #[test]
    fn speculation_preserves_output_and_launches_on_straggler() {
        // one of 8 single-record splits busy-waits 150ms, the rest ~1ms:
        // a clean straggler for the median detector
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                busy_wait(Duration::from_millis(if v == 7 { 150 } else { 1 }));
                out.emit(v % 3, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("straggle").with_tasks(8, 2);
        let plain = JobScheduler::with_slots(4).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let spec_sched = JobScheduler::new(SchedulerConfig::slots(4).with_speculation(true));
        let spec = spec_sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(plain.outputs, spec.outputs);
        assert_eq!(plain.counters.get(names::SPECULATIVE_LAUNCHED), 0);
        assert!(
            spec.counters.get(names::SPECULATIVE_LAUNCHED) >= 1,
            "straggler should trigger at least one clone"
        );
        // engine counters unaffected by losing attempts
        assert_eq!(
            plain.counters.get(names::MAP_OUTPUT_RECORDS),
            spec.counters.get(names::MAP_OUTPUT_RECORDS)
        );
        assert_eq!(
            plain.counters.get(names::REDUCE_INPUT_RECORDS),
            spec.counters.get(names::REDUCE_INPUT_RECORDS)
        );
    }

    #[test]
    fn combiner_job_on_scheduler_matches_serial() {
        use crate::mapreduce::combiner::FnCombiner;
        let (input, mapper, reducer) = histogram_job(500, 5);
        let cfg = JobConfig::named("comb").with_tasks(4, 2).with_workers(2);
        let combiner = || {
            Arc::new(FnCombiner::new(|_k: &u64, vals: Vec<u64>, _c: &Counters| {
                vec![vals.into_iter().sum()]
            }))
        };
        let serial = run_job_with_combiner(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
            combiner(),
        );
        let scheduled = JobScheduler::with_slots(2).run_with_combiner(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
            combiner(),
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        assert_eq!(
            serial.counters.get(names::COMBINE_INPUT_RECORDS),
            scheduled.counters.get(names::COMBINE_INPUT_RECORDS)
        );
        assert_eq!(
            serial.counters.get(names::SHUFFLE_BYTES),
            scheduled.counters.get(names::SHUFFLE_BYTES)
        );
    }

    #[test]
    fn push_mode_matches_barrier_output_and_counters() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let cfg = JobConfig::named("hist-push").with_tasks(4, 3);
        let barrier = JobScheduler::with_slots(3).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let push = JobScheduler::new(SchedulerConfig::slots(3).with_push(PushMode::Push)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(barrier.outputs, push.outputs);
        for name in [
            names::MAP_OUTPUT_RECORDS,
            names::SHUFFLE_BYTES,
            names::SHUFFLE_BYTES_RAW,
            names::REDUCE_INPUT_RECORDS,
            names::REDUCE_GROUPS,
            names::MAP_SPILL_RUNS,
        ] {
            assert_eq!(
                barrier.counters.get(name),
                push.counters.get(name),
                "engine counter {name} diverged under push"
            );
        }
        // every sealed run flowed through the service, exactly once
        assert_eq!(
            push.counters.get(names::PUSHED_RUNS),
            push.counters.get(names::MAP_SPILL_RUNS)
        );
        assert_eq!(barrier.counters.get(names::PUSHED_RUNS), 0);
        assert_eq!(barrier.stats.overlap_secs, 0.0);
    }

    #[test]
    fn job_level_push_opt_in_on_barrier_scheduler() {
        let (input, mapper, reducer) = histogram_job(400, 5);
        let cfg = JobConfig::named("hist-optin").with_tasks(4, 2).with_push(true);
        let sched = JobScheduler::with_slots(2);
        assert_eq!(sched.push_mode(), PushMode::Barrier);
        let pushed = sched.run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let serial = run_job(
            &cfg.clone().with_workers(2),
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, pushed.outputs);
        assert!(pushed.counters.get(names::PUSHED_RUNS) > 0);
        // the serial driver is the barrier reference: push is ignored
        assert_eq!(serial.counters.get(names::PUSHED_RUNS), 0);
    }

    #[test]
    fn push_with_sort_budget_and_spill_matches_barrier() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("push-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("hist-push-disk")
            .with_tasks(4, 3)
            .with_sort_buffer(Some(16))
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let barrier = JobScheduler::with_slots(3).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let push = JobScheduler::new(SchedulerConfig::slots(3).with_push(PushMode::Push)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(barrier.outputs, push.outputs);
        // the sort budget seals runs mid-task, so pushes happen while the
        // map function is still running; every one became a run file
        assert_eq!(
            push.counters.get(names::PUSHED_RUNS),
            push.counters.get(names::SPILLED_RUNS)
        );
        assert_eq!(
            barrier.counters.get(names::SPILL_BYTES_WRITTEN),
            push.counters.get(names::SPILL_BYTES_WRITTEN)
        );
        assert_eq!(
            barrier.counters.get(names::SHUFFLE_BYTES),
            push.counters.get(names::SHUFFLE_BYTES)
        );
    }

    /// A panicking map task in push mode must unwind cleanly: parked
    /// reducers drain, the dispatcher stops submitting, nothing hangs.
    #[test]
    #[should_panic(expected = "task attempt(s) panicked")]
    fn push_map_panic_unwinds_without_hanging() {
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                if v == 5 {
                    panic!("boom");
                }
                out.emit(v % 2, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("boom-push").with_tasks(8, 2);
        let _ = JobScheduler::new(SchedulerConfig::slots(2).with_push(PushMode::Push)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
    }

    #[test]
    fn push_runs_reducers_with_empty_mailboxes() {
        let (input, mapper, reducer) = histogram_job(200, 4);
        let cfg = JobConfig::named("hist-empty").with_tasks(2, 3);
        // everything routes to partition 0; partitions 1 and 2 see no runs
        let push = JobScheduler::new(SchedulerConfig::slots(2).with_push(PushMode::Push)).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|_: &u64| 0)),
            grouping(),
            reducer.clone(),
        );
        let barrier = JobScheduler::with_slots(2).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|_: &u64| 0)),
            grouping(),
            reducer,
        );
        assert_eq!(barrier.outputs, push.outputs);
        assert_eq!(push.outputs.len(), 3);
        assert!(push.outputs[1].is_empty() && push.outputs[2].is_empty());
        let total: u64 = push.outputs.iter().flatten().map(|(_, c)| *c).sum();
        assert_eq!(total, 200);
    }
}
