//! Multi-job slot scheduler: concurrent job execution on one shared
//! worker pool, with speculative execution.
//!
//! ## The slot model
//!
//! Hadoop schedules tasks onto a fixed number of per-node **map slots**
//! and **reduce slots** (§5.1: "each node was configured to run at most
//! two map and reduce tasks in parallel") that are shared by *every* job
//! in the cluster — submitting a second job does not buy more slots, it
//! contends for the same ones.  The serial [`run_job`] driver models a
//! cluster running exactly one job: it spins up a private pool per phase.
//! This module models the cluster itself:
//!
//! * a [`JobScheduler`] owns one map pool and one reduce pool (mirroring
//!   [`ClusterSpec::map_slots`]/[`ClusterSpec::reduce_slots`] accounting);
//! * any number of jobs run concurrently ([`JobScheduler::submit`] spawns
//!   a lightweight driver thread per job and returns a [`JobHandle`];
//!   [`JobScheduler::run`] drives a job inline on the caller's thread);
//! * map/reduce *tasks* of independent jobs interleave FIFO across the
//!   shared slots — job A's reduce wave can overlap job B's map wave,
//!   exactly as on a real cluster;
//! * each job still gets its own [`JobStats`] and [`Counters`], so
//!   per-job simulator profiles stay meaningful;
//! * a **DAG** of jobs is expressed with handles: join a prerequisite
//!   before submitting the dependent job (`sn::jobsn` chains two jobs
//!   this way; `sn::multipass` fans out independent per-key jobs).
//!
//! ## Speculative execution
//!
//! The paper disables speculation (§5.1), and its skew study (Fig. 9)
//! shows why that matters: stragglers dominate makespan.  With
//! `speculative = true` the scheduler clones any running task whose
//! elapsed time exceeds `slowdown ×` the running median of completed task
//! durations onto an *idle* slot; the first attempt to finish wins (an
//! atomic [`OnceSlots::try_put`](crate::util::threadpool::OnceSlots::try_put)
//! race), the loser's result and counters are discarded.  Task bodies are
//! deterministic functions of their input, so speculation never changes
//! job output — only, possibly, the makespan.  New counters
//! [`names::SPECULATIVE_LAUNCHED`] / [`names::SPECULATIVE_WON`] report
//! what it did; [`ClusterSpec::speculative`] is the matching simulator
//! knob, so simulated and measured makespans stay comparable.
//!
//! Both execution paths share the exact same task bodies
//! ([`engine::exec_map_task`](super::engine) / `exec_reduce_task`), which
//! makes "scheduler output == serial output" structural rather than
//! per-job luck; `tests/prop_sched.rs` asserts it property-style.
//!
//! ## Push-based shuffle
//!
//! With [`PushMode::Push`] (scheduler-wide) or
//! [`JobConfig::push`](crate::mapreduce::JobConfig::push) (per job), a
//! job's map→reduce barrier disappears: map attempts push each sealed
//! run into the job's [`ShuffleService`](super::push::ShuffleService)
//! mailboxes the moment it exists, a dispatcher thread submits each
//! reduce task to the shared reduce slots at its **first run's
//! arrival**, and reducers pre-merge the committed run prefix while the
//! map wave is still running (the overlap the two-wave model forfeits —
//! the communication/computation overlap Afrati et al. point to).
//! Output stays byte-identical to the barrier path, which remains the
//! reference baseline; see the [`push`](super::push) module docs for the
//! ordering and speculation-retraction rules, and
//! [`JobStats::overlap_secs`] for the measured effect.
//!
//! ## Scheduler/executor split
//!
//! [`DistScheduler`] is the message-passing sibling of this in-process
//! scheduler: an event loop owning the job/task state machines
//! ([`dist`]-module `ControlState`), N executor workers
//! ([`executor`](self::executor)) running the same shared task bodies,
//! and a [`transport`](self::transport) layer carrying every control and
//! data frame between them. Intermediates are addressed by *location* —
//! executors register sealed runs as `(executor, run ids)` and reduce
//! tasks fetch them over the data plane — so push dispatch, speculation
//! retraction, bounded retry, dead-lettering, and executor-loss
//! resubmission all ride the same typed message protocol. The in-process
//! paths here remain the byte-identical reference (`tests/prop_exec.rs`
//! pins dist against serial the same way `prop_sched.rs` pins this one).

mod dist;
pub(crate) mod executor;
mod speculate;
pub mod transport;

pub use dist::{DistConfig, DistScheduler};
pub use executor::KillPlan;
pub use speculate::{SpecMode, SpecPolicy};
pub use transport::{ChannelTransport, LinkClass, LinkClosed, Transport, TransportFaults};

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::checkpoint::CheckpointWriter;
use super::combiner::{combine_sorted_bucket, Combiner};
use super::config::JobConfig;
use super::counters::{names, Counters};
use super::driver;
use super::engine::{
    exec_map_task, exec_reduce_task, run_job, run_job_with_combiner, split_input, CombineFn,
    DeadLetter, GroupFn, JobOutcome, JobResult, JobStats, MapTaskOutput, ReduceTaskOutput,
};
use super::fault::{FaultInjector, FaultPlan, TaskPhase};
use super::memory::{MemoryPool, ADMISSION_FLOOR_PER_TASK, DEFAULT_ADMIT_WAIT};
use super::push::{self, ShuffleService};
use super::sim::ClusterSpec;
use super::sortspill::{ResolvedSpill, Run};
use super::trace::{TraceEvent, TracePhase};
use super::types::{MapTaskFactory, Partitioner, ReduceTaskFactory, SizeEstimate};
use crate::metrics::registry::{
    EngineSnapshot, HealthSampler, MetricsSpec, PoolGaugeStats, PoolOccupancy,
};
use crate::util::threadpool::{OnceSlots, ThreadPool};

/// Whether jobs on this scheduler ship intermediates through the barrier
/// shuffle or the push-based [`ShuffleService`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PushMode {
    /// Two synchronous waves per job: every reduce task starts only
    /// after the whole map wave (the paper's Hadoop 0.20 model — the
    /// reference path every push run is checked against).
    #[default]
    Barrier,
    /// Run-granular flow: map attempts push each sealed run into
    /// per-partition mailboxes and a job's reduce tasks are submitted to
    /// the shared reduce slots as soon as their first runs arrive,
    /// overlapping the job's reduce wave with its *own* map wave.
    Push,
}

/// Scheduler shape: shared slot counts plus the speculation and shuffle
/// knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Concurrent map tasks across *all* jobs.
    pub map_slots: usize,
    /// Concurrent reduce tasks across *all* jobs.
    pub reduce_slots: usize,
    /// Clone stragglers onto idle slots (first-completion-wins).
    pub speculative: bool,
    /// Straggler-detection thresholds.
    pub policy: SpecPolicy,
    /// Barrier or push-based shuffle for every job on this scheduler
    /// (a single job can also opt in via
    /// [`JobConfig::push`](crate::mapreduce::JobConfig::push)).
    pub push: PushMode,
    /// Scheduler-wide retry budget for panicked task attempts.  A job can
    /// override it with [`JobConfig::max_task_retries`]; `0` (the
    /// default) keeps the seed engine's fail-fast behavior.
    pub max_task_retries: u32,
    /// Scheduler-wide fault-injection plan, applied to every job that
    /// does not carry its own [`JobConfig::faults`].
    pub faults: Option<FaultPlan>,
    /// Live-metrics registry ([`MetricsSpec`]): when set, the scheduler
    /// updates its gauges/counters in-line and spawns a [`HealthSampler`]
    /// thread that snapshots occupancy, queue depths, mailbox volumes,
    /// and dead-letter counts on the spec's cadence.  `None` (the
    /// default) keeps the engine metric-free — no thread, no atomics on
    /// the task path.
    pub metrics: Option<MetricsSpec>,
    /// Process-wide memory pool shared by every job on this scheduler:
    /// map tasks charge their sorter buffers (sealing early when the
    /// pool denies a grow), push mailboxes charge staged-run residency
    /// (backpressuring or diverting denied pushes), reduce merges
    /// reserve their streaming working set, and jobs pass admission
    /// control before their first wave starts.  `None` (the default)
    /// keeps the engine entirely accounting-free, and an
    /// [`MemoryPool::unlimited`] pool never denies — both are strict
    /// no-ops against the unpooled engine (byte-identical outputs *and*
    /// counters).  A job can override with
    /// [`JobConfig::with_memory`](crate::mapreduce::JobConfig::with_memory).
    pub memory: Option<MemoryPool>,
}

impl SchedulerConfig {
    /// `n` map slots and `n` reduce slots, speculation off, barrier
    /// shuffle.
    pub fn slots(n: usize) -> Self {
        Self {
            map_slots: n.max(1),
            reduce_slots: n.max(1),
            speculative: false,
            policy: SpecPolicy::default(),
            push: PushMode::Barrier,
            max_task_retries: 0,
            faults: None,
            metrics: None,
            memory: None,
        }
    }

    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculative = on;
        self
    }

    /// Retry budget for panicked task attempts on every job (unless the
    /// job overrides it).
    pub fn with_retries(mut self, n: u32) -> Self {
        self.max_task_retries = n;
        self
    }

    /// Inject faults into every job that doesn't carry its own plan.
    /// An empty plan is normalized to `None`.
    pub fn with_faults(mut self, plan: Option<FaultPlan>) -> Self {
        self.faults = plan.filter(|p| !p.is_empty());
        self
    }

    pub fn with_policy(mut self, policy: SpecPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Select the shuffle mode for every job on this scheduler.
    pub fn with_push(mut self, push: PushMode) -> Self {
        self.push = push;
        self
    }

    /// Attach a live-metrics registry.  The scheduler built from this
    /// config updates the spec's gauges and counters as tasks move
    /// through the slots and runs a background [`HealthSampler`] on the
    /// spec's cadence; keep a clone of `spec` to read
    /// [`MetricsSpec::snapshots`] / render the dashboard afterwards.
    pub fn with_metrics(mut self, spec: MetricsSpec) -> Self {
        self.metrics = Some(spec);
        self
    }

    /// Budget every job's intermediate memory against `pool` (see
    /// [`SchedulerConfig::memory`]).  Pass the same pool to several
    /// schedulers (or [`DistConfig`](crate::mapreduce::scheduler::DistConfig)s)
    /// to share one process-wide budget.
    pub fn with_memory_pool(mut self, pool: MemoryPool) -> Self {
        self.memory = Some(pool);
        self
    }

    /// Mirror a simulated cluster's slot counts and speculation knob, so
    /// measured and simulated makespans stay comparable.
    pub fn from_cluster(spec: &ClusterSpec) -> Self {
        Self {
            map_slots: spec.map_slots().max(1),
            reduce_slots: spec.reduce_slots().max(1),
            speculative: spec.speculative,
            policy: SpecPolicy::default(),
            push: PushMode::Barrier,
            max_task_retries: 0,
            faults: None,
            metrics: None,
            memory: None,
        }
    }
}

struct SchedInner {
    cfg: SchedulerConfig,
    map_pool: ThreadPool,
    reduce_pool: ThreadPool,
    /// Background snapshot thread, present iff `cfg.metrics` is.  Its
    /// probe holds only a `Weak` back-reference, so the sampler never
    /// keeps the scheduler alive; declared after the pools so the pools
    /// are still valid while the sampler drains its final tick, and
    /// dropping it (with the last scheduler clone) stops and joins the
    /// thread.
    sampler: Mutex<Option<HealthSampler>>,
}

/// The shared-slot multi-job scheduler.  Cheap to clone (all clones share
/// the same pools); dropping the last clone joins the worker threads.
#[derive(Clone)]
pub struct JobScheduler {
    inner: Arc<SchedInner>,
}

/// A submitted job's pending result.
pub struct JobHandle<KO, VO> {
    handle: JoinHandle<JobResult<KO, VO>>,
}

impl<KO, VO> JobHandle<KO, VO> {
    /// Block until the job finishes.  DAG edges between jobs are expressed
    /// by joining a prerequisite's handle before submitting the dependent
    /// job.  Panics inside the job's tasks resurface here.
    pub fn join(self) -> JobResult<KO, VO> {
        match self.handle.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

impl JobScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let map_pool = ThreadPool::new(cfg.map_slots);
        let reduce_pool = ThreadPool::new(cfg.reduce_slots);
        let metrics = cfg.metrics.clone();
        // pool gauges ride the same sampler: the probe holds a weak pool
        // handle, so it prunes itself once every strong handle is gone
        if let (Some(spec), Some(pool)) = (&metrics, &cfg.memory) {
            let weak = pool.downgrade();
            spec.register_pool_probe(Box::new(move || {
                weak.upgrade().map(|p| PoolGaugeStats {
                    reserved_bytes: p.reserved_bytes(),
                    denied_grows: p.denied_grows(),
                    spill_requests: p.spill_requests(),
                })
            }));
        }
        let inner = Arc::new(SchedInner {
            cfg,
            map_pool,
            reduce_pool,
            sampler: Mutex::new(None),
        });
        if let Some(spec) = metrics {
            // The probe holds a Weak reference: once the last scheduler
            // clone drops, upgrade() fails and the sampler thread exits
            // on its own (its owning handle also stops it on drop).
            let weak = Arc::downgrade(&inner);
            let sampler = HealthSampler::spawn(
                spec,
                Box::new(move || {
                    weak.upgrade().map(|i| PoolOccupancy {
                        map_slots: i.map_pool.size() as u64,
                        reduce_slots: i.reduce_pool.size() as u64,
                        map_running: i.map_pool.in_flight() as u64,
                        reduce_running: i.reduce_pool.in_flight() as u64,
                    })
                }),
            );
            *inner.sampler.lock().unwrap() = Some(sampler);
        }
        Self { inner }
    }

    /// Shorthand: `n` map + `n` reduce slots, speculation off.
    pub fn with_slots(n: usize) -> Self {
        Self::new(SchedulerConfig::slots(n))
    }

    pub fn map_slots(&self) -> usize {
        self.inner.map_pool.size()
    }

    pub fn reduce_slots(&self) -> usize {
        self.inner.reduce_pool.size()
    }

    pub fn speculative(&self) -> bool {
        self.inner.cfg.speculative
    }

    pub fn push_mode(&self) -> PushMode {
        self.inner.cfg.push
    }

    /// The live-metrics registry this scheduler reports into (a clone of
    /// the spec handed to [`SchedulerConfig::with_metrics`] — same shared
    /// registry), or `None` when metrics are off.
    pub fn metrics(&self) -> Option<MetricsSpec> {
        self.inner.cfg.metrics.clone()
    }

    /// Take one on-demand [`EngineSnapshot`] of the scheduler right now,
    /// pushing it into the registry ring as if the background sampler had
    /// ticked.  `None` when metrics are off.  Complements the sampler for
    /// tests and end-of-run summaries, where "the state *after* the last
    /// job" matters more than cadence alignment.
    pub fn sample_metrics_now(&self) -> Option<EngineSnapshot> {
        self.inner.cfg.metrics.as_ref().map(|m| {
            m.sample(Some(PoolOccupancy {
                map_slots: self.inner.map_pool.size() as u64,
                reduce_slots: self.inner.reduce_pool.size() as u64,
                map_running: self.inner.map_pool.in_flight() as u64,
                reduce_running: self.inner.reduce_pool.in_flight() as u64,
            }))
        })
    }

    /// Run one job inline on the caller's thread; its tasks execute on the
    /// scheduler's shared slots.  Signature mirrors [`run_job`], with the
    /// extra `Clone`/`Sync` bounds speculation needs to re-run a task from
    /// its retained input.  `config.workers` is ignored — slot counts come
    /// from the scheduler.
    pub fn run<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(config, input, mapper, partitioner, grouping, reducer, None)
    }

    /// As [`JobScheduler::run`], with a map-side combiner.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(
            config,
            input,
            mapper,
            partitioner,
            grouping,
            reducer,
            Some(make_combine_fn(combiner)),
        )
    }

    /// Submit a job for concurrent execution: a driver thread is spawned
    /// for the job and a [`JobHandle`] returned immediately.  All
    /// submitted jobs' tasks interleave on the scheduler's shared slots.
    pub fn submit<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.spawn_driver(config, input, mapper, partitioner, grouping, reducer, None)
    }

    /// As [`JobScheduler::submit`], with a map-side combiner.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.spawn_driver(
            config,
            input,
            mapper,
            partitioner,
            grouping,
            reducer,
            Some(make_combine_fn(combiner)),
        )
    }

    /// The one driver-thread spawn point behind `submit*`.
    #[allow(clippy::too_many_arguments)]
    fn spawn_driver<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        let sched = self.clone();
        let handle = std::thread::Builder::new()
            .name(format!("snmr-job-{}", config.name))
            .spawn(move || {
                sched.run_inner(
                    &config,
                    input,
                    mapper,
                    partitioner,
                    grouping,
                    reducer,
                    combine_fn,
                )
            })
            .expect("spawn job driver");
        JobHandle { handle }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_inner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        if self.inner.cfg.push == PushMode::Push || config.push {
            return self.run_push(config, input, mapper, partitioner, grouping, reducer, combine_fn);
        }
        let spec = self.inner.cfg.speculative.then(|| self.inner.cfg.policy.clone());
        let counters = Arc::new(Counters::new());
        let r = config.num_reduce_tasks;
        let sort_budget = config.sort_buffer_records;
        // ---- memory pool: job override, then admission control ------------
        // Reserve a small per-runnable-task floor before the first wave
        // starts, so a saturated pool queues whole jobs instead of
        // thrashing every running task; held until this driver returns.
        let pool = config.memory.clone().or_else(|| self.inner.cfg.memory.clone());
        let _admission = pool.as_ref().map(|p| {
            let tasks = config.num_map_tasks.min(self.inner.cfg.map_slots).max(1) as u64;
            p.admit(&config.name, tasks * ADMISSION_FLOOR_PER_TASK, DEFAULT_ADMIT_WAIT)
        });
        // same spill plumbing as the serial driver: resolve the codec
        // once, hand it to every map attempt (speculative clones write
        // their own run files; only the winner's reach the shuffle)
        let spill: Option<ResolvedSpill<(KT, VT)>> = config.spill.as_ref().map(|s| s.resolve());
        let has_combiner = combine_fn.is_some();
        // One trace context per job; wave closures carry clones of it.
        let jctx = config.trace.as_ref().map(|t| t.job_ctx(&config.name));
        // Live-metrics handles, when the scheduler carries a registry:
        // per-job queue/run gauges plus the engine-wide dead-letter and
        // active-job accounting.  `jm` lives until this driver returns,
        // which is what keeps `engine.jobs_active` honest.
        let jm = self.inner.cfg.metrics.as_ref().map(|m| m.job_metrics(&config.name));
        let map_wm = jm.as_ref().map(|j| j.wave());
        let reduce_wm = jm.as_ref().map(|j| j.wave());
        let map_dl = jm.as_ref().map(|j| j.dead_letters.clone());
        let reduce_dl = jm.as_ref().map(|j| j.dead_letters.clone());

        // ---- fault-tolerance wiring ---------------------------------------
        // Job-level knobs win over scheduler-wide defaults.
        let retries = config
            .max_task_retries
            .unwrap_or(self.inner.cfg.max_task_retries);
        let dead_letter = config.dead_letter;
        let injector = FaultInjector::from_plan(
            config
                .faults
                .clone()
                .or_else(|| self.inner.cfg.faults.clone()),
        );
        let dead_letters: Arc<Mutex<Vec<DeadLetter>>> = Arc::new(Mutex::new(Vec::new()));
        // Checkpoint state shared by both waves and the post-job cleanup:
        // (writer, prior manifest if resumable, run codec, output codec).
        let ckpt = config.checkpoint.as_ref().map(|c| {
            let codec = c.resolve::<(KT, VT)>();
            let out_codec = c.resolve_output::<(KO, VO)>();
            let (writer, prior) =
                CheckpointWriter::new(c, &config.name, config.num_map_tasks, r);
            (writer, prior.map(Arc::new), codec, out_codec)
        });

        // ---- the two barrier waves, on the shared slots -------------------
        // Each attempt runs against private counters; only the winning
        // attempt's are merged, so a losing speculative clone never
        // double-counts user-code increments.  Without speculation each
        // attempt is the sole owner of its split and consumes it in
        // place; a speculative or retryable wave retains a reference per
        // task (so a clone or retry can re-run it), which forces the
        // deep-clone fallback.
        let map_wave = {
            let sched = self.clone();
            let mapper = Arc::clone(&mapper);
            let partitioner = Arc::clone(&partitioner);
            let counters = Arc::clone(&counters);
            let spec = spec.clone();
            let injector = Arc::clone(&injector);
            let ckpt = ckpt.clone();
            let dead_letters = Arc::clone(&dead_letters);
            let jctx = jctx.clone();
            let pool = pool.clone();
            move |splits: Vec<Vec<(KI, VI)>>| {
                let split_lens: Vec<u64> = splits.iter().map(|s| s.len() as u64).collect();
                let map_attempt = {
                    let injector = Arc::clone(&injector);
                    let ckpt = ckpt.clone();
                    let jctx = jctx.clone();
                    let pool = pool.clone();
                    move |i: usize, attempt: u32, split: Arc<Vec<(KI, VI)>>| {
                        let tctx = jctx.as_ref().map(|j| j.task(TracePhase::Map, i, attempt));
                        let local = Counters::new();
                        // A task covered by a prior run's manifest restores
                        // its sealed runs instead of executing (and never
                        // fires the injector: it does not run).
                        if let Some((_, Some(prior), codec, _)) = &ckpt {
                            if let Some(out) = prior.restore_map(i, r, codec) {
                                local.inc(names::TASKS_RESUMED);
                                if let Some(t) = &tctx {
                                    t.emit(TraceEvent::CheckpointRestore);
                                }
                                return (out, local);
                            }
                        }
                        injector.fire_traced(TaskPhase::Map, i, tctx.as_ref());
                        let split =
                            Arc::try_unwrap(split).unwrap_or_else(|shared| (*shared).clone());
                        let out = exec_map_task(
                            split,
                            r,
                            sort_budget,
                            spill.as_ref(),
                            mapper.as_ref(),
                            partitioner.as_ref(),
                            combine_fn.as_ref(),
                            &local,
                            None,
                            tctx.as_ref(),
                            pool.as_ref(),
                        );
                        (out, local)
                    }
                };
                // Checkpoint commits ride the decided-swap arbiter: on_win
                // fires exactly once per task, never for a losing clone.
                let on_win = ckpt.as_ref().map(|(writer, _, codec, _)| {
                    let writer = Arc::clone(writer);
                    let codec = Arc::clone(codec);
                    let jctx = jctx.clone();
                    Arc::new(move |i: usize, t: &(MapTaskOutput<KT, VT>, Counters)| {
                        writer.record_map(i, &t.0, &codec);
                        if let Some(j) = &jctx {
                            // attempt is unknown here (the hook runs after
                            // the win race); commits stamp ordinal 0
                            j.task(TracePhase::Map, i, 0).emit(TraceEvent::CheckpointCommit);
                        }
                    })
                        as Arc<dyn Fn(usize, &(MapTaskOutput<KT, VT>, Counters)) + Send + Sync>
                });
                let wave = speculate::run_tasks_ft(
                    &sched.inner.map_pool,
                    splits,
                    Arc::new(map_attempt),
                    speculate::WaveOptions {
                        spec,
                        max_retries: retries,
                        allow_failure: dead_letter,
                        on_win,
                        trace: jctx.clone().map(|j| (j, TracePhase::Map)),
                        metrics: map_wm.clone(),
                    },
                    &counters,
                );
                let mut map_outputs = Vec::with_capacity(wave.results.len());
                for (i, slot) in wave.results.into_iter().enumerate() {
                    match slot {
                        Some((out, local)) => {
                            counters.merge(&local);
                            map_outputs.push(out);
                        }
                        None => {
                            // Exhausted retries: dead-letter the split and
                            // keep the wave going with an empty stand-in.
                            counters.inc(names::DEAD_LETTERED);
                            if let Some(c) = &map_dl {
                                c.inc();
                            }
                            if let Some(j) = &jctx {
                                j.task(TracePhase::Map, i, 0).emit(TraceEvent::DeadLettered {
                                    message: format!(
                                        "map task {i} exhausted its retry budget"
                                    ),
                                });
                            }
                            dead_letters.lock().unwrap().push(DeadLetter {
                                phase: TaskPhase::Map,
                                task: i,
                                records: split_lens[i],
                            });
                            map_outputs.push(MapTaskOutput::empty(r));
                        }
                    }
                }
                map_outputs
            }
        };
        let reduce_wave = {
            let sched = self.clone();
            let reducer = Arc::clone(&reducer);
            let grouping = Arc::clone(&grouping);
            let counters = Arc::clone(&counters);
            let injector = Arc::clone(&injector);
            let ckpt = ckpt.clone();
            let dead_letters = Arc::clone(&dead_letters);
            let jctx = jctx.clone();
            let pool = pool.clone();
            move |per_reducer_runs: Vec<Vec<Run<(KT, VT)>>>| {
                let run_counts: Vec<u64> =
                    per_reducer_runs.iter().map(|rs| rs.len() as u64).collect();
                let reduce_attempt = {
                    let injector = Arc::clone(&injector);
                    let ckpt = ckpt.clone();
                    let jctx = jctx.clone();
                    let pool = pool.clone();
                    move |j: usize, attempt: u32, runs: Arc<Vec<Run<(KT, VT)>>>| {
                        let tctx =
                            jctx.as_ref().map(|jc| jc.task(TracePhase::Reduce, j, attempt));
                        let local = Counters::new();
                        if let Some((_, Some(prior), _, Some(oc))) = &ckpt {
                            if let Some(out) = prior.restore_reduce(j, oc) {
                                local.inc(names::TASKS_RESUMED);
                                if let Some(t) = &tctx {
                                    t.emit(TraceEvent::CheckpointRestore);
                                }
                                return (out, local);
                            }
                        }
                        injector.fire_traced(TaskPhase::Reduce, j, tctx.as_ref());
                        let runs =
                            Arc::try_unwrap(runs).unwrap_or_else(|shared| (*shared).clone());
                        let out = exec_reduce_task(
                            runs,
                            reducer.as_ref(),
                            grouping.as_ref(),
                            &local,
                            tctx.as_ref(),
                            pool.as_ref(),
                        );
                        (out, local)
                    }
                };
                // Reduce outputs are only worth persisting when nothing has
                // been dead-lettered: a partial-input reduce output must not
                // be restorable by a later (complete) run.
                let on_win = ckpt.as_ref().and_then(|(writer, _, _, out_codec)| {
                    out_codec.as_ref().map(|oc| {
                        let writer = Arc::clone(writer);
                        let oc = Arc::clone(oc);
                        let dead_letters = Arc::clone(&dead_letters);
                        let jctx = jctx.clone();
                        Arc::new(move |j: usize, t: &(ReduceTaskOutput<KO, VO>, Counters)| {
                            if dead_letters.lock().unwrap().is_empty() {
                                writer.record_reduce(j, &t.0, &oc);
                                if let Some(jc) = &jctx {
                                    jc.task(TracePhase::Reduce, j, 0)
                                        .emit(TraceEvent::CheckpointCommit);
                                }
                            }
                        })
                            as Arc<
                                dyn Fn(usize, &(ReduceTaskOutput<KO, VO>, Counters))
                                    + Send
                                    + Sync,
                            >
                    })
                });
                let wave = speculate::run_tasks_ft(
                    &sched.inner.reduce_pool,
                    per_reducer_runs,
                    Arc::new(reduce_attempt),
                    speculate::WaveOptions {
                        spec,
                        max_retries: retries,
                        allow_failure: dead_letter,
                        on_win,
                        trace: jctx.clone().map(|j| (j, TracePhase::Reduce)),
                        metrics: reduce_wm.clone(),
                    },
                    &counters,
                );
                let mut red_outputs = Vec::with_capacity(wave.results.len());
                for (j, slot) in wave.results.into_iter().enumerate() {
                    match slot {
                        Some((out, local)) => {
                            counters.merge(&local);
                            red_outputs.push(out);
                        }
                        None => {
                            counters.inc(names::DEAD_LETTERED);
                            if let Some(c) = &reduce_dl {
                                c.inc();
                            }
                            if let Some(jc) = &jctx {
                                jc.task(TracePhase::Reduce, j, 0).emit(
                                    TraceEvent::DeadLettered {
                                        message: format!(
                                            "reduce task {j} exhausted its retry budget"
                                        ),
                                    },
                                );
                            }
                            dead_letters.lock().unwrap().push(DeadLetter {
                                phase: TaskPhase::Reduce,
                                task: j,
                                records: run_counts[j],
                            });
                            red_outputs.push(ReduceTaskOutput::empty());
                        }
                    }
                }
                red_outputs
            }
        };
        let mut res = driver::drive_barrier_job(
            config,
            input,
            &counters,
            has_combiner,
            map_wave,
            reduce_wave,
            jctx,
        );
        res.stats.dead_letters = std::mem::take(&mut *dead_letters.lock().unwrap());
        if res.outcome == JobOutcome::Ok {
            if let Some((writer, _, _, _)) = &ckpt {
                // Clean finish: the manifest (and any runs parked in the
                // checkpoint dir) have nothing left to resume.
                writer.complete();
            }
        }
        // Fold the finished job's counters and task-duration histograms
        // into the registry, then let `jm` drop: jobs_active decrements
        // and the job's gauges are already quiesced by the wave exits.
        if let Some(m) = &self.inner.cfg.metrics {
            m.absorb_job(&res.counters, &res.stats);
        }
        res
    }

    /// The push-based shuffle path: no map→reduce barrier.  Map attempts
    /// push every sealed run into the job's [`ShuffleService`] mailboxes
    /// (mid-task when a sort budget seals early), a dispatcher thread
    /// submits each reduce task to the shared reduce slots at its first
    /// run's arrival, and reducers pre-merge the committed prefix while
    /// the map wave is still running, catching up on late runs after the
    /// seal.  Output is byte-identical to the barrier path (same task
    /// bodies, same merge order — `tests/prop_push.rs`).
    ///
    /// Speculation applies to the map wave (staged pushes, losing
    /// attempts retracted); reduce tasks are event-driven singletons —
    /// their elapsed time includes waiting on mailboxes, which would
    /// defeat the straggler detector's runtime comparison.
    #[allow(clippy::too_many_arguments)]
    fn run_push<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        let inner = &self.inner;
        let spec = inner.cfg.speculative.then(|| inner.cfg.policy.clone());
        let t_start = Instant::now();
        let counters = Arc::new(Counters::new());
        let r = config.num_reduce_tasks;
        let sort_budget = config.sort_buffer_records;
        let spill: Option<ResolvedSpill<(KT, VT)>> = config.spill.as_ref().map(|s| s.resolve());
        let compressed_spill = config.spill.as_ref().map(|s| s.compress()).unwrap_or(false);

        // Fault-tolerance knobs (job-level wins over scheduler-wide).
        // The push path ignores `config.checkpoint` — its commit points
        // are run-granular, not task-granular; resumable jobs run barrier.
        let retries = config
            .max_task_retries
            .unwrap_or(inner.cfg.max_task_retries);
        let dead_letter = config.dead_letter;
        let faults = config
            .faults
            .clone()
            .or_else(|| inner.cfg.faults.clone());
        let faults_active = faults.is_some();
        let injector = FaultInjector::from_plan(faults);
        let dead_letters: Arc<Mutex<Vec<DeadLetter>>> = Arc::new(Mutex::new(Vec::new()));
        // One trace context per job, shared by the map wave, the shuffle
        // service (run pushed/retracted events), and the dispatcher.
        let jctx = config.trace.as_ref().map(|t| t.job_ctx(&config.name));
        // Live-metrics handles (see `run_inner`).  The push path threads
        // the reduce-wave handles through the dispatcher, whose
        // event-driven submissions bypass the speculate wave runner.
        let jm = inner.cfg.metrics.as_ref().map(|m| m.job_metrics(&config.name));
        let map_wm = jm.as_ref().map(|j| j.wave());
        let reduce_wm = jm.as_ref().map(|j| j.wave());
        let reduce_dl = jm.as_ref().map(|j| j.dead_letters.clone());

        counters.add(names::MAP_INPUT_RECORDS, input.len() as u64);
        let splits = split_input(input, config.num_map_tasks);
        let split_lens: Vec<u64> = splits.iter().map(|s| s.len() as u64).collect();
        let m = splits.len();

        // ---- memory pool: job override, then admission control ------------
        // (same protocol as the barrier path; held until this driver
        // returns)
        let pool = config.memory.clone().or_else(|| inner.cfg.memory.clone());
        let _admission = pool.as_ref().map(|p| {
            let tasks = m.min(inner.cfg.map_slots).max(1) as u64;
            p.admit(&config.name, tasks * ADMISSION_FLOOR_PER_TASK, DEFAULT_ADMIT_WAIT)
        });

        // one mailbox per reduce partition; staged (retractable) pushes
        // exactly when more than one attempt per task can exist — a retry
        // or an injected panic mid-task must not leave half a task's runs
        // committed.  Retained (clone-on-read) mailboxes exactly when a
        // panicked reduce attempt may re-read its partition.  With a
        // memory pool the mailboxes account their resident bytes and a
        // denied push backpressures — or diverts to the job's spill dir
        // when one is configured (then runs arrive spilled anyway, and
        // the divert is dormant).
        let staged = spec.is_some() || retries > 0 || dead_letter || faults_active;
        let retain = retries > 0;
        let service: Arc<ShuffleService<(KT, VT)>> = Arc::new(
            ShuffleService::new(m, r, staged, Arc::clone(&counters))
                .with_retained_runs(retain)
                .with_trace(jctx.clone())
                .with_memory(pool.as_ref(), spill.clone()),
        );
        if let Some(mspec) = &inner.cfg.metrics {
            // Mailbox-depth probe for the sampler: a Weak reference, so
            // the finished job's service can free itself; the registry
            // prunes the probe once it reports `None`.
            let weak_service = Arc::downgrade(&service);
            mspec.register_mailbox_probe(Box::new(move || {
                weak_service.upgrade().map(|s| s.depth_stats())
            }));
            if let Some(s) = &config.spill {
                mspec.register_spill_dir(s.dir());
            }
        }
        // each slot holds (output, task-local counters, execution-start
        // seconds) — the start stamp is taken on the reduce slot itself,
        // so overlap_secs reports real execution overlap even when slot
        // contention delays a submitted task
        let results: Arc<OnceSlots<(ReduceTaskOutput<KO, VO>, Counters, f64)>> =
            Arc::new(OnceSlots::empty(r));
        // (finished, panicked) reduce tasks — the driver's completion gate
        let done: Arc<(Mutex<(usize, usize)>, Condvar)> =
            Arc::new((Mutex::new((0, 0)), Condvar::new()));

        // ---- dispatcher: event-driven reduce submission -------------------
        // Runs until every partition is submitted: on first-run arrival
        // for eager partitions, at seal for the rest (reduce tasks run
        // their configure/close hooks even on empty input).
        let dispatcher = {
            let sched = self.clone();
            let service = Arc::clone(&service);
            let reducer = Arc::clone(&reducer);
            let grouping = Arc::clone(&grouping);
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            let counters = Arc::clone(&counters);
            let injector = Arc::clone(&injector);
            let dead_letters = Arc::clone(&dead_letters);
            let jctx = jctx.clone();
            let pool = pool.clone();
            std::thread::Builder::new()
                .name(format!("snmr-push-{}", config.name))
                .spawn(move || {
                    let mut submitted = vec![false; r];
                    let mut left = r;
                    while left > 0 {
                        let (ready, sealed) = service.wait_ready(&submitted);
                        if ready.is_empty() && sealed {
                            // aborted map wave: never start reduce tasks
                            // for a job that failed before feeding them
                            break;
                        }
                        for j in ready {
                            submitted[j] = true;
                            left -= 1;
                            let service = Arc::clone(&service);
                            let reducer = Arc::clone(&reducer);
                            let grouping = Arc::clone(&grouping);
                            let results = Arc::clone(&results);
                            let done = Arc::clone(&done);
                            let counters = Arc::clone(&counters);
                            let injector = Arc::clone(&injector);
                            let dead_letters = Arc::clone(&dead_letters);
                            let jctx = jctx.clone();
                            let pool = pool.clone();
                            if let Some(m) = &reduce_wm {
                                m.on_submit();
                            }
                            let wm = reduce_wm.clone();
                            let dl = reduce_dl.clone();
                            sched.inner.reduce_pool.execute(move || {
                                let started = t_start.elapsed().as_secs_f64();
                                if let Some(m) = &wm {
                                    m.on_start();
                                }
                                // Inline retry loop: a panicked attempt
                                // restarts the whole merge against the
                                // retained (clone-on-read) mailbox, just
                                // like a barrier resubmission re-reads its
                                // retained input.
                                let mut attempts_left = retries;
                                let mut attempt_no: u32 = 0;
                                let outcome = loop {
                                    let tctx = jctx
                                        .as_ref()
                                        .map(|jc| jc.task(TracePhase::Reduce, j, attempt_no));
                                    if let Some(t) = &tctx {
                                        if attempt_no == 0 {
                                            // the primary attempt's start is
                                            // stamped with the exact slot-start
                                            // second the stats use, so the
                                            // trace-derived first-reduce-start
                                            // equals the stats field
                                            t.emit_at(TraceEvent::AttemptStarted, started);
                                        } else {
                                            t.emit(TraceEvent::AttemptStarted);
                                        }
                                    }
                                    let attempt = catch_unwind(AssertUnwindSafe(|| {
                                        injector.fire_traced(
                                            TaskPhase::Reduce,
                                            j,
                                            tctx.as_ref(),
                                        );
                                        let local = Counters::new();
                                        let (sources, late, fold_secs) =
                                            push::collect_reduce_sources(&service, j);
                                        if late > 0 {
                                            local.add(names::LATE_RUNS, late);
                                            if let Some(t) = &tctx {
                                                t.emit(TraceEvent::ReduceCatchUp {
                                                    late_runs: late,
                                                });
                                            }
                                        }
                                        let mut out = exec_reduce_task(
                                            sources,
                                            reducer.as_ref(),
                                            grouping.as_ref(),
                                            &local,
                                            tctx.as_ref(),
                                            pool.as_ref(),
                                        );
                                        // the pre-merge folding is reduce work
                                        // too (the waits are not measured)
                                        out.secs += fold_secs;
                                        (out, local, started)
                                    }));
                                    match attempt {
                                        Ok(pair) => {
                                            if let Some(t) = &tctx {
                                                t.emit(TraceEvent::AttemptFinished);
                                                t.emit(TraceEvent::AttemptWon);
                                            }
                                            break Ok(pair);
                                        }
                                        Err(p) => {
                                            if let Some(t) = &tctx {
                                                t.emit(TraceEvent::AttemptPanicked {
                                                    message: speculate::panic_message(
                                                        p.as_ref(),
                                                    ),
                                                });
                                            }
                                            if attempts_left == 0 {
                                                break Err(p);
                                            }
                                            attempts_left -= 1;
                                            counters.inc(names::TASK_RETRIES);
                                            if let Some(m) = &wm {
                                                m.on_retry();
                                            }
                                            attempt_no += 1;
                                            if let Some(jc) = &jctx {
                                                jc.task(TracePhase::Reduce, j, attempt_no)
                                                    .emit(TraceEvent::TaskRetried);
                                            }
                                        }
                                    }
                                };
                                let (lock, cv) = &*done;
                                let mut g = lock.lock().unwrap();
                                match outcome {
                                    Ok(pair) => {
                                        if retain {
                                            // committed output: the retained
                                            // mailbox is dead weight now
                                            service.release_partition(j);
                                        }
                                        results.put(j, pair);
                                        g.0 += 1;
                                    }
                                    Err(_) => {
                                        counters.inc(names::TASKS_FAILED);
                                        if dead_letter {
                                            counters.inc(names::DEAD_LETTERED);
                                            if let Some(c) = &dl {
                                                c.inc();
                                            }
                                            if let Some(jc) = &jctx {
                                                jc.task(TracePhase::Reduce, j, 0).emit(
                                                    TraceEvent::DeadLettered {
                                                        message: format!(
                                                            "reduce task {j} exhausted its \
                                                             retry budget"
                                                        ),
                                                    },
                                                );
                                            }
                                            dead_letters.lock().unwrap().push(DeadLetter {
                                                phase: TaskPhase::Reduce,
                                                task: j,
                                                records: service.committed_len(j) as u64,
                                            });
                                            results.put(
                                                j,
                                                (ReduceTaskOutput::empty(), Counters::new(), started),
                                            );
                                            g.0 += 1;
                                        } else {
                                            g.0 += 1;
                                            g.1 += 1;
                                        }
                                    }
                                }
                                cv.notify_all();
                                if let Some(m) = &wm {
                                    m.on_exit();
                                }
                            });
                        }
                    }
                })
                .expect("spawn push dispatcher")
        };

        // ---- map wave on the shared map slots, pushing as runs seal -------
        let t_map = Instant::now();
        let map_attempt = {
            let mapper = Arc::clone(&mapper);
            let partitioner = Arc::clone(&partitioner);
            let combine_fn = combine_fn.clone();
            let spill = spill.clone();
            let service = Arc::clone(&service);
            let injector = Arc::clone(&injector);
            let jctx = jctx.clone();
            let pool = pool.clone();
            move |i: usize, attempt_no: u32, split: Arc<Vec<(KI, VI)>>| {
                let tctx = jctx.as_ref().map(|j| j.task(TracePhase::Map, i, attempt_no));
                // fire before opening the attempt: an injected panic here
                // models a worker that died before producing anything
                injector.fire_traced(TaskPhase::Map, i, tctx.as_ref());
                let local = Counters::new();
                let split = Arc::try_unwrap(split).unwrap_or_else(|shared| (*shared).clone());
                let attempt = ShuffleService::begin_attempt_traced(&service, i, attempt_no);
                let out = exec_map_task(
                    split,
                    r,
                    sort_budget,
                    spill.as_ref(),
                    mapper.as_ref(),
                    partitioner.as_ref(),
                    combine_fn.as_ref(),
                    &local,
                    Some(&attempt),
                    tctx.as_ref(),
                    pool.as_ref(),
                );
                // first finisher wins the task; a loser's pushes are
                // retracted before reducers could ever fold them
                let _won = attempt.finish();
                (out, local)
            }
        };
        let wave = AssertUnwindSafe(|| {
            speculate::run_tasks_ft(
                &inner.map_pool,
                splits,
                Arc::new(map_attempt),
                speculate::WaveOptions {
                    spec,
                    max_retries: retries,
                    allow_failure: dead_letter,
                    on_win: None,
                    trace: jctx.clone().map(|j| (j, TracePhase::Map)),
                    metrics: map_wm.clone(),
                },
                &counters,
            )
        });
        let map_wave_out = match catch_unwind(wave) {
            Ok(out) => out,
            Err(panic) => {
                // unblock the reducers and the dispatcher before
                // unwinding, or they would park reduce slots forever
                service.abort();
                let _ = dispatcher.join();
                std::panic::resume_unwind(panic);
            }
        };
        let mut map_outputs: Vec<MapTaskOutput<KT, VT>> =
            Vec::with_capacity(map_wave_out.results.len());
        for (i, slot) in map_wave_out.results.into_iter().enumerate() {
            match slot {
                Some((out, local)) => {
                    counters.merge(&local);
                    map_outputs.push(out);
                }
                None => {
                    // Dead-lettered map task: retract whatever its attempts
                    // staged and release the commit prefix so downstream
                    // reducers see a shorter (but consistent) stream.
                    service.fail_task(i);
                    counters.inc(names::DEAD_LETTERED);
                    if let Some(j) = &jm {
                        j.dead_letters.inc();
                    }
                    if let Some(j) = &jctx {
                        j.task(TracePhase::Map, i, 0).emit(TraceEvent::DeadLettered {
                            message: format!("map task {i} exhausted its retry budget"),
                        });
                    }
                    dead_letters.lock().unwrap().push(DeadLetter {
                        phase: TaskPhase::Map,
                        task: i,
                        records: split_lens[i],
                    });
                    map_outputs.push(MapTaskOutput::empty(r));
                }
            }
        }
        let map_phase_secs = t_map.elapsed().as_secs_f64();
        let map_wave_done_secs = t_start.elapsed().as_secs_f64();
        if let Some(jc) = &jctx {
            jc.emit_job_at(TraceEvent::MapWaveDone, map_wave_done_secs);
        }

        let mut stats = JobStats {
            map_phase_secs,
            map_wave_done_secs,
            ..Default::default()
        };
        // the exact accounting fold the barrier driver runs — the runs
        // themselves already flowed through the service, so the returned
        // per-reducer lists are empty and only the byte sums matter
        // (attempts are deterministic: the winning outputs' volumes equal
        // what the committed runs carried)
        let _ = driver::record_map_phase(
            &mut stats,
            &counters,
            map_outputs,
            r,
            combine_fn.is_some(),
            compressed_spill,
        );

        // every task decided → every run committed: wake the reducers for
        // their catch-up pass and flush the dispatcher's remainder
        service.seal();
        dispatcher.join().expect("push dispatcher panicked");

        // ---- gather the event-driven reduce wave --------------------------
        {
            let (lock, cv) = &*done;
            let mut g = lock.lock().unwrap();
            while g.0 < r {
                g = cv.wait(g).unwrap();
            }
            assert_eq!(g.1, 0, "{} push reduce task attempt(s) panicked", g.1);
        }
        let mut red_outputs: Vec<ReduceTaskOutput<KO, VO>> = Vec::with_capacity(r);
        let mut first_start = f64::INFINITY;
        for j in 0..r {
            let (out, local, started) = results.take(j);
            counters.merge(&local);
            first_start = first_start.min(started);
            red_outputs.push(out);
        }
        stats.reduce_first_start_secs = if first_start.is_finite() { first_start } else { 0.0 };
        stats.overlap_secs = (map_wave_done_secs - stats.reduce_first_start_secs).max(0.0);
        if let Some(jc) = &jctx {
            jc.emit_job_at(TraceEvent::ReduceFirstStart, stats.reduce_first_start_secs);
        }
        stats.reduce_phase_secs =
            (t_start.elapsed().as_secs_f64() - stats.reduce_first_start_secs).max(0.0);
        driver::record_reduce_phase(&mut stats, &counters, &red_outputs);
        let outputs: Vec<Vec<(KO, VO)>> = red_outputs.into_iter().map(|o| o.output).collect();
        stats.total_secs = t_start.elapsed().as_secs_f64();
        if let Some(jc) = &jctx {
            jc.emit_job_at(TraceEvent::JobFinished, stats.total_secs);
        }

        // the push path bypasses the barrier driver's tail, so it folds
        // the fault accounting into the result itself
        stats.task_retries = counters.get(names::TASK_RETRIES);
        stats.tasks_failed = counters.get(names::TASKS_FAILED);
        stats.dead_letters = std::mem::take(&mut *dead_letters.lock().unwrap());
        stats
            .dead_letters
            .sort_by_key(|d| (d.phase != TaskPhase::Map, d.task));
        let outcome = if counters.get(names::DEAD_LETTERED) > 0 {
            JobOutcome::Degraded
        } else {
            JobOutcome::Ok
        };
        // Fold the finished job into the registry (see `run_inner`); the
        // job's mailbox probe starts answering `None` as soon as the
        // service drops with this frame, and the sampler prunes it.
        if let Some(mspec) = &inner.cfg.metrics {
            mspec.absorb_job(&counters, &stats);
        }

        JobResult {
            outputs,
            counters,
            stats,
            outcome,
        }
    }
}

/// Wrap a [`Combiner`] into the engine's type-erased combine step (the
/// same fold [`run_job_with_combiner`] builds on the serial path).
fn make_combine_fn<KT, VT>(combiner: Arc<dyn Combiner<KT, VT>>) -> CombineFn<KT, VT>
where
    KT: Ord + Clone + SizeEstimate + 'static,
    VT: SizeEstimate + 'static,
{
    Arc::new(move |run: &mut Vec<(KT, VT)>, c: &Counters| {
        combine_sorted_bucket(run, combiner.as_ref(), c)
    })
}

/// How a caller executes an engine job: on a job-private pool (the
/// serial [`run_job`] driver), or through a shared [`JobScheduler`] whose
/// slots are contended by every concurrently submitted job.
#[derive(Clone, Copy)]
pub enum Exec<'a> {
    /// `run_job` / `run_job_with_combiner` on a job-private pool.
    Serial,
    /// Tasks on the scheduler's shared slots (inline on this thread).
    Scheduler(&'a JobScheduler),
    /// Tasks on a message-passing executor cluster ([`DistScheduler`]):
    /// the scheduler/executor split with location-addressed shuffle.
    Dist(&'a DistScheduler),
}

impl Exec<'_> {
    /// Dispatch a job to this executor.
    pub fn run_job<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        match self {
            Exec::Serial => run_job(config, input, mapper, partitioner, grouping, reducer),
            Exec::Scheduler(s) => s.run(config, input, mapper, partitioner, grouping, reducer),
            Exec::Dist(d) => d.run(config, input, mapper, partitioner, grouping, reducer),
        }
    }

    /// Dispatch a combiner job to this executor.
    #[allow(clippy::too_many_arguments)]
    pub fn run_job_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        match self {
            Exec::Serial => run_job_with_combiner(
                config,
                input,
                mapper,
                partitioner,
                grouping,
                reducer,
                combiner,
            ),
            Exec::Scheduler(s) => s.run_with_combiner(
                config,
                input,
                mapper,
                partitioner,
                grouping,
                reducer,
                combiner,
            ),
            Exec::Dist(d) => d.run_with_combiner(
                config,
                input,
                mapper,
                partitioner,
                grouping,
                reducer,
                combiner,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::types::{Emitter, FnMapTask, FnReduceTask, HashPartitioner, ValuesIter};
    use std::time::Duration;

    fn busy_wait(d: Duration) {
        let t0 = Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    fn histogram_job(
        n: u64,
        modulus: u64,
    ) -> (
        Vec<((), u64)>,
        Arc<FnMapTask<impl Fn((), u64, &mut Emitter<u64, u64>, &Counters)>>,
        Arc<FnReduceTask<impl Fn(&u64, ValuesIter<'_, u64>, &mut Emitter<u64, u64>, &Counters)>>,
    ) {
        let input: Vec<((), u64)> = (0..n).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            move |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(v % modulus, 1);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        (input, mapper, reducer)
    }

    fn grouping() -> GroupFn<u64> {
        Arc::new(|a: &u64, b: &u64| a == b)
    }

    #[test]
    fn scheduler_matches_serial_run_job() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let cfg = JobConfig::named("hist").with_tasks(4, 3).with_workers(2);
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let sched = JobScheduler::with_slots(3);
        let scheduled = sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        assert_eq!(serial.counters.snapshot(), scheduled.counters.snapshot());
        assert_eq!(
            serial.stats.map_output_records,
            scheduled.stats.map_output_records
        );
        assert_eq!(
            serial.stats.reduce_output_records,
            scheduled.stats.reduce_output_records
        );
    }

    #[test]
    fn disk_backed_job_on_scheduler_matches_serial() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("sched-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("hist-disk")
            .with_tasks(4, 3)
            .with_workers(2)
            .with_sort_buffer(Some(32))
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let scheduled = JobScheduler::with_slots(3).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        // run files and their contents are deterministic, so even the
        // byte-level spill counters agree across executors
        assert_eq!(serial.counters.snapshot(), scheduled.counters.snapshot());
        assert!(serial.counters.get(names::SPILLED_RUNS) > 0);
        assert_eq!(
            serial.stats.spill_bytes_written,
            scheduled.stats.spill_bytes_written
        );
    }

    #[test]
    fn speculation_composes_with_disk_backed_runs() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                busy_wait(Duration::from_millis(if v == 7 { 120 } else { 1 }));
                out.emit(v % 3, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let dir = TempSpillDir::new("sched-spec-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("straggle-disk")
            .with_tasks(8, 2)
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let plain = JobScheduler::with_slots(4).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let spec = JobScheduler::new(SchedulerConfig::slots(4).with_speculation(true)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        // losing attempts' run files are discarded (and deleted); output
        // and engine counters stay identical
        assert_eq!(plain.outputs, spec.outputs);
        assert_eq!(
            plain.counters.get(names::SHUFFLE_BYTES),
            spec.counters.get(names::SHUFFLE_BYTES)
        );
        assert_eq!(
            plain.counters.get(names::SPILL_BYTES_WRITTEN),
            spec.counters.get(names::SPILL_BYTES_WRITTEN)
        );
    }

    #[test]
    fn concurrent_jobs_share_slots_and_keep_separate_stats() {
        let sched = JobScheduler::with_slots(4);
        let mut handles = Vec::new();
        for j in 0..3u64 {
            let (input, mapper, reducer) = histogram_job(400 + 100 * j, 5 + j);
            let cfg = JobConfig::named(&format!("job{j}")).with_tasks(4, 2);
            handles.push(sched.submit(
                cfg,
                input,
                mapper,
                Arc::new(HashPartitioner::new(|k: &u64| *k)),
                grouping(),
                reducer,
            ));
        }
        for (j, h) in handles.into_iter().enumerate() {
            let j = j as u64;
            let res = h.join();
            let n = 400 + 100 * j;
            let total: u64 = res.outputs.iter().flatten().map(|(_, c)| *c).sum();
            assert_eq!(total, n, "job {j} lost records");
            assert_eq!(res.stats.map_task_secs.len(), 4);
            assert_eq!(res.counters.get(names::MAP_INPUT_RECORDS), n);
        }
    }

    #[test]
    fn speculation_preserves_output_and_launches_on_straggler() {
        // one of 8 single-record splits busy-waits 150ms, the rest ~1ms:
        // a clean straggler for the median detector
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                busy_wait(Duration::from_millis(if v == 7 { 150 } else { 1 }));
                out.emit(v % 3, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("straggle").with_tasks(8, 2);
        let plain = JobScheduler::with_slots(4).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let spec_sched = JobScheduler::new(SchedulerConfig::slots(4).with_speculation(true));
        let spec = spec_sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(plain.outputs, spec.outputs);
        assert_eq!(plain.counters.get(names::SPECULATIVE_LAUNCHED), 0);
        assert!(
            spec.counters.get(names::SPECULATIVE_LAUNCHED) >= 1,
            "straggler should trigger at least one clone"
        );
        // engine counters unaffected by losing attempts
        assert_eq!(
            plain.counters.get(names::MAP_OUTPUT_RECORDS),
            spec.counters.get(names::MAP_OUTPUT_RECORDS)
        );
        assert_eq!(
            plain.counters.get(names::REDUCE_INPUT_RECORDS),
            spec.counters.get(names::REDUCE_INPUT_RECORDS)
        );
    }

    #[test]
    fn combiner_job_on_scheduler_matches_serial() {
        use crate::mapreduce::combiner::FnCombiner;
        let (input, mapper, reducer) = histogram_job(500, 5);
        let cfg = JobConfig::named("comb").with_tasks(4, 2).with_workers(2);
        let combiner = || {
            Arc::new(FnCombiner::new(|_k: &u64, vals: Vec<u64>, _c: &Counters| {
                vec![vals.into_iter().sum()]
            }))
        };
        let serial = run_job_with_combiner(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
            combiner(),
        );
        let scheduled = JobScheduler::with_slots(2).run_with_combiner(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
            combiner(),
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        assert_eq!(
            serial.counters.get(names::COMBINE_INPUT_RECORDS),
            scheduled.counters.get(names::COMBINE_INPUT_RECORDS)
        );
        assert_eq!(
            serial.counters.get(names::SHUFFLE_BYTES),
            scheduled.counters.get(names::SHUFFLE_BYTES)
        );
    }

    #[test]
    fn push_mode_matches_barrier_output_and_counters() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let cfg = JobConfig::named("hist-push").with_tasks(4, 3);
        let barrier = JobScheduler::with_slots(3).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let push = JobScheduler::new(SchedulerConfig::slots(3).with_push(PushMode::Push)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(barrier.outputs, push.outputs);
        for name in [
            names::MAP_OUTPUT_RECORDS,
            names::SHUFFLE_BYTES,
            names::SHUFFLE_BYTES_RAW,
            names::REDUCE_INPUT_RECORDS,
            names::REDUCE_GROUPS,
            names::MAP_SPILL_RUNS,
        ] {
            assert_eq!(
                barrier.counters.get(name),
                push.counters.get(name),
                "engine counter {name} diverged under push"
            );
        }
        // every sealed run flowed through the service, exactly once
        assert_eq!(
            push.counters.get(names::PUSHED_RUNS),
            push.counters.get(names::MAP_SPILL_RUNS)
        );
        assert_eq!(barrier.counters.get(names::PUSHED_RUNS), 0);
        assert_eq!(barrier.stats.overlap_secs, 0.0);
    }

    #[test]
    fn job_level_push_opt_in_on_barrier_scheduler() {
        let (input, mapper, reducer) = histogram_job(400, 5);
        let cfg = JobConfig::named("hist-optin").with_tasks(4, 2).with_push(true);
        let sched = JobScheduler::with_slots(2);
        assert_eq!(sched.push_mode(), PushMode::Barrier);
        let pushed = sched.run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let serial = run_job(
            &cfg.clone().with_workers(2),
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, pushed.outputs);
        assert!(pushed.counters.get(names::PUSHED_RUNS) > 0);
        // the serial driver is the barrier reference: push is ignored
        assert_eq!(serial.counters.get(names::PUSHED_RUNS), 0);
    }

    #[test]
    fn push_with_sort_budget_and_spill_matches_barrier() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("push-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("hist-push-disk")
            .with_tasks(4, 3)
            .with_sort_buffer(Some(16))
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let barrier = JobScheduler::with_slots(3).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let push = JobScheduler::new(SchedulerConfig::slots(3).with_push(PushMode::Push)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(barrier.outputs, push.outputs);
        // the sort budget seals runs mid-task, so pushes happen while the
        // map function is still running; every one became a run file
        assert_eq!(
            push.counters.get(names::PUSHED_RUNS),
            push.counters.get(names::SPILLED_RUNS)
        );
        assert_eq!(
            barrier.counters.get(names::SPILL_BYTES_WRITTEN),
            push.counters.get(names::SPILL_BYTES_WRITTEN)
        );
        assert_eq!(
            barrier.counters.get(names::SHUFFLE_BYTES),
            push.counters.get(names::SHUFFLE_BYTES)
        );
    }

    /// A panicking map task in push mode must unwind cleanly: parked
    /// reducers drain, the dispatcher stops submitting, nothing hangs.
    #[test]
    #[should_panic(expected = "task attempt(s) panicked")]
    fn push_map_panic_unwinds_without_hanging() {
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                if v == 5 {
                    panic!("boom");
                }
                out.emit(v % 2, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("boom-push").with_tasks(8, 2);
        let _ = JobScheduler::new(SchedulerConfig::slots(2).with_push(PushMode::Push)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
    }

    #[test]
    fn push_runs_reducers_with_empty_mailboxes() {
        let (input, mapper, reducer) = histogram_job(200, 4);
        let cfg = JobConfig::named("hist-empty").with_tasks(2, 3);
        // everything routes to partition 0; partitions 1 and 2 see no runs
        let push = JobScheduler::new(SchedulerConfig::slots(2).with_push(PushMode::Push)).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|_: &u64| 0)),
            grouping(),
            reducer.clone(),
        );
        let barrier = JobScheduler::with_slots(2).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|_: &u64| 0)),
            grouping(),
            reducer,
        );
        assert_eq!(barrier.outputs, push.outputs);
        assert_eq!(push.outputs.len(), 3);
        assert!(push.outputs[1].is_empty() && push.outputs[2].is_empty());
        let total: u64 = push.outputs.iter().flatten().map(|(_, c)| *c).sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn barrier_retry_recovers_injected_panics() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let clean_cfg = JobConfig::named("hist-ft").with_tasks(4, 3);
        let clean = JobScheduler::with_slots(3).run(
            &clean_cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        // kill the first attempt of one map and one reduce task; one
        // retry each recovers the job byte-identically
        let cfg = clean_cfg
            .clone()
            .with_faults(Some(FaultPlan::new().panic_map(1, 0).panic_reduce(0, 0)))
            .with_retries(Some(1));
        let retried = JobScheduler::with_slots(3).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(clean.outputs, retried.outputs);
        assert_eq!(retried.outcome, JobOutcome::Ok);
        assert_eq!(retried.stats.task_retries, 2);
        assert_eq!(retried.counters.get(names::TASK_RETRIES), 2);
        assert!(retried.stats.dead_letters.is_empty());
    }

    #[test]
    fn scheduler_wide_retry_budget_applies_to_jobs() {
        let (input, mapper, reducer) = histogram_job(300, 5);
        // retry budget and fault plan both set on the *scheduler*: jobs
        // inherit them without any JobConfig opt-in
        let sched = JobScheduler::new(
            SchedulerConfig::slots(2)
                .with_retries(1)
                .with_faults(Some(FaultPlan::new().panic_map(0, 0))),
        );
        let cfg = JobConfig::named("hist-sched-ft").with_tasks(3, 2);
        let res = sched.run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let clean = run_job(
            &cfg.clone().with_workers(2),
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(clean.outputs, res.outputs);
        assert_eq!(res.stats.task_retries, 1);
    }

    #[test]
    fn push_retry_recovers_injected_panics() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let clean_cfg = JobConfig::named("hist-push-ft").with_tasks(4, 3);
        let sched = JobScheduler::new(SchedulerConfig::slots(3).with_push(PushMode::Push));
        let clean = sched.run(
            &clean_cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        // a map attempt dies after staging pushes, a reduce attempt dies
        // after folding part of its mailbox: the retry re-stages and
        // re-reads the retained partition
        let cfg = clean_cfg
            .clone()
            .with_faults(Some(FaultPlan::new().panic_map(2, 0).panic_reduce(1, 0)))
            .with_retries(Some(2));
        let retried = sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(clean.outputs, retried.outputs);
        assert_eq!(retried.outcome, JobOutcome::Ok);
        assert_eq!(retried.stats.task_retries, 2);
        assert!(retried.stats.dead_letters.is_empty());
    }

    #[test]
    fn exhausted_retries_dead_letter_and_degrade() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        // map task 1 panics on every attempt; with a 1-retry budget and
        // dead-lettering on, the job completes without task 1's split
        let cfg = JobConfig::named("hist-dl")
            .with_tasks(4, 3)
            .with_faults(Some(
                FaultPlan::new().panic_map(1, 0).panic_map(1, 1),
            ))
            .with_retries(Some(1))
            .with_dead_letter(true);
        let res = JobScheduler::with_slots(3).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(res.outcome, JobOutcome::Degraded);
        assert_eq!(res.counters.get(names::DEAD_LETTERED), 1);
        assert_eq!(res.stats.task_retries, 1);
        assert_eq!(res.stats.dead_letters.len(), 1);
        let dl = &res.stats.dead_letters[0];
        assert_eq!((dl.phase, dl.task), (TaskPhase::Map, 1));
        assert_eq!(dl.records, 150, "4 even splits of 600");
        // partial output: exactly the dead-lettered split's records are
        // missing
        let total: u64 = res.outputs.iter().flatten().map(|(_, c)| *c).sum();
        assert_eq!(total, 450);
    }

    #[test]
    fn push_dead_letters_a_poisoned_reduce_partition() {
        let (input, mapper, reducer) = histogram_job(400, 5);
        let clean_cfg = JobConfig::named("push-dl").with_tasks(4, 3);
        let sched = JobScheduler::new(SchedulerConfig::slots(3).with_push(PushMode::Push));
        let clean = sched.run(
            &clean_cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        // reduce partition 1 fails every attempt (0 retries): its output
        // is empty, the rest of the job is untouched
        let cfg = clean_cfg.clone().with_faults(Some(FaultPlan::new().panic_reduce(1, 0)))
            .with_dead_letter(true);
        let res = sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(res.outcome, JobOutcome::Degraded);
        assert!(res.outputs[1].is_empty());
        assert_eq!(res.outputs[0], clean.outputs[0]);
        assert_eq!(res.outputs[2], clean.outputs[2]);
        assert_eq!(res.stats.dead_letters.len(), 1);
        assert_eq!(res.stats.dead_letters[0].phase, TaskPhase::Reduce);
        assert_eq!(res.stats.dead_letters[0].task, 1);
    }

    #[test]
    fn checkpoint_resumes_only_missing_tasks() {
        use crate::mapreduce::checkpoint::CheckpointSpec;
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("sched-ckpt").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let out_codec: Arc<dyn Codec<(u64, u64)>> =
            Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let spec = CheckpointSpec::new::<(u64, u64)>(dir.path(), codec)
            .with_output_codec::<(u64, u64)>(out_codec);
        let cfg = JobConfig::named("hist-ckpt")
            .with_tasks(4, 3)
            .with_checkpoint(Some(spec.clone()));
        let clean = run_job(
            &cfg.clone().with_workers(2),
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        // run 1: the whole map wave commits to the manifest, then a
        // poisoned reduce task fails the job (fail-fast, no retries)
        let sched = JobScheduler::with_slots(3);
        let killed = catch_unwind(AssertUnwindSafe(|| {
            sched.run(
                &cfg.clone()
                    .with_faults(Some(FaultPlan::new().panic_reduce(0, 0))),
                input.clone(),
                mapper.clone(),
                Arc::new(HashPartitioner::new(|k: &u64| *k)),
                grouping(),
                reducer.clone(),
            )
        }));
        assert!(killed.is_err(), "fail-fast job should panic");
        assert!(
            spec.manifest_path().exists(),
            "failed job must leave its manifest for resume"
        );
        // run 2: same job, no faults — every map task restores from the
        // manifest instead of re-executing
        let resumed = sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(clean.outputs, resumed.outputs);
        assert_eq!(resumed.outcome, JobOutcome::Ok);
        assert!(
            resumed.counters.get(names::TASKS_RESUMED) >= 4,
            "all 4 map tasks should restore, got {}",
            resumed.counters.get(names::TASKS_RESUMED)
        );
        assert!(
            !spec.manifest_path().exists(),
            "clean finish must retire the manifest"
        );
    }

    /// Satellite of the abort-path guarantee: a fail-fast disk-backed job
    /// that dies mid-wave must delete every spill file it created.
    #[test]
    fn aborted_barrier_job_leaks_no_spill_files() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("abort-barrier").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("abort-barrier")
            .with_tasks(4, 3)
            .with_sort_buffer(Some(16))
            .with_spill(Some(SpillSpec::new(dir.path(), codec)))
            .with_faults(Some(FaultPlan::new().panic_map(3, 0)));
        let sched = JobScheduler::with_slots(3);
        let res = catch_unwind(AssertUnwindSafe(|| {
            sched.run(
                &cfg,
                input,
                mapper,
                Arc::new(HashPartitioner::new(|k: &u64| *k)),
                grouping(),
                reducer,
            )
        }));
        assert!(res.is_err());
        drop(sched); // join the slots: in-flight tasks release their runs
        let leaked = std::fs::read_dir(dir.path()).unwrap().count();
        assert_eq!(leaked, 0, "aborted barrier job leaked {leaked} spill files");
    }

    /// Same guarantee on the push path, where committed runs live in the
    /// service mailboxes: aborting the wave must still drop every file.
    #[test]
    fn aborted_push_job_leaks_no_spill_files() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("abort-push").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("abort-push")
            .with_tasks(4, 3)
            .with_sort_buffer(Some(16))
            .with_spill(Some(SpillSpec::new(dir.path(), codec)))
            .with_faults(Some(FaultPlan::new().panic_map(3, 0)));
        let sched = JobScheduler::new(SchedulerConfig::slots(3).with_push(PushMode::Push));
        let res = catch_unwind(AssertUnwindSafe(|| {
            sched.run(
                &cfg,
                input,
                mapper,
                Arc::new(HashPartitioner::new(|k: &u64| *k)),
                grouping(),
                reducer,
            )
        }));
        assert!(res.is_err());
        drop(sched);
        let leaked = std::fs::read_dir(dir.path()).unwrap().count();
        assert_eq!(leaked, 0, "aborted push job leaked {leaked} spill files");
    }

    /// A reduce-side panic in push mode (no retries, no dead-letter) must
    /// fail the job without hanging the completion gate.
    #[test]
    #[should_panic(expected = "push reduce task attempt(s) panicked")]
    fn push_reduce_panic_unwinds_without_hanging() {
        let (input, mapper, reducer) = histogram_job(400, 5);
        let cfg = JobConfig::named("boom-push-reduce")
            .with_tasks(4, 2)
            .with_faults(Some(FaultPlan::new().panic_reduce(0, 0)));
        let _ = JobScheduler::new(SchedulerConfig::slots(2).with_push(PushMode::Push)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
    }
}
