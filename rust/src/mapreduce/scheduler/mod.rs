//! Multi-job slot scheduler: concurrent job execution on one shared
//! worker pool, with speculative execution.
//!
//! ## The slot model
//!
//! Hadoop schedules tasks onto a fixed number of per-node **map slots**
//! and **reduce slots** (§5.1: "each node was configured to run at most
//! two map and reduce tasks in parallel") that are shared by *every* job
//! in the cluster — submitting a second job does not buy more slots, it
//! contends for the same ones.  The serial [`run_job`] driver models a
//! cluster running exactly one job: it spins up a private pool per phase.
//! This module models the cluster itself:
//!
//! * a [`JobScheduler`] owns one map pool and one reduce pool (mirroring
//!   [`ClusterSpec::map_slots`]/[`ClusterSpec::reduce_slots`] accounting);
//! * any number of jobs run concurrently ([`JobScheduler::submit`] spawns
//!   a lightweight driver thread per job and returns a [`JobHandle`];
//!   [`JobScheduler::run`] drives a job inline on the caller's thread);
//! * map/reduce *tasks* of independent jobs interleave FIFO across the
//!   shared slots — job A's reduce wave can overlap job B's map wave,
//!   exactly as on a real cluster;
//! * each job still gets its own [`JobStats`] and [`Counters`], so
//!   per-job simulator profiles stay meaningful;
//! * a **DAG** of jobs is expressed with handles: join a prerequisite
//!   before submitting the dependent job (`sn::jobsn` chains two jobs
//!   this way; `sn::multipass` fans out independent per-key jobs).
//!
//! ## Speculative execution
//!
//! The paper disables speculation (§5.1), and its skew study (Fig. 9)
//! shows why that matters: stragglers dominate makespan.  With
//! `speculative = true` the scheduler clones any running task whose
//! elapsed time exceeds `slowdown ×` the running median of completed task
//! durations onto an *idle* slot; the first attempt to finish wins (an
//! atomic [`OnceSlots::try_put`](crate::util::threadpool::OnceSlots::try_put)
//! race), the loser's result and counters are discarded.  Task bodies are
//! deterministic functions of their input, so speculation never changes
//! job output — only, possibly, the makespan.  New counters
//! [`names::SPECULATIVE_LAUNCHED`] / [`names::SPECULATIVE_WON`] report
//! what it did; [`ClusterSpec::speculative`] is the matching simulator
//! knob, so simulated and measured makespans stay comparable.
//!
//! Both execution paths share the exact same task bodies
//! ([`engine::exec_map_task`](super::engine) / `exec_reduce_task`), which
//! makes "scheduler output == serial output" structural rather than
//! per-job luck; `tests/prop_sched.rs` asserts it property-style.

mod speculate;

pub use speculate::SpecPolicy;

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::combiner::{combine_sorted_bucket, Combiner};
use super::config::JobConfig;
use super::counters::{names, Counters};
use super::engine::{
    exec_map_task, exec_reduce_task, record_map_wave, record_reduce_wave, run_job,
    run_job_with_combiner, split_input, transpose_runs, CombineFn, GroupFn, JobResult, JobStats,
    MapTaskOutput, ReduceTaskOutput,
};
use super::sim::ClusterSpec;
use super::sortspill::{ResolvedSpill, Run};
use super::types::{MapTaskFactory, Partitioner, ReduceTaskFactory, SizeEstimate};
use crate::util::threadpool::ThreadPool;

/// Scheduler shape: shared slot counts plus the speculation knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Concurrent map tasks across *all* jobs.
    pub map_slots: usize,
    /// Concurrent reduce tasks across *all* jobs.
    pub reduce_slots: usize,
    /// Clone stragglers onto idle slots (first-completion-wins).
    pub speculative: bool,
    /// Straggler-detection thresholds.
    pub policy: SpecPolicy,
}

impl SchedulerConfig {
    /// `n` map slots and `n` reduce slots, speculation off.
    pub fn slots(n: usize) -> Self {
        Self {
            map_slots: n.max(1),
            reduce_slots: n.max(1),
            speculative: false,
            policy: SpecPolicy::default(),
        }
    }

    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculative = on;
        self
    }

    pub fn with_policy(mut self, policy: SpecPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Mirror a simulated cluster's slot counts and speculation knob, so
    /// measured and simulated makespans stay comparable.
    pub fn from_cluster(spec: &ClusterSpec) -> Self {
        Self {
            map_slots: spec.map_slots().max(1),
            reduce_slots: spec.reduce_slots().max(1),
            speculative: spec.speculative,
            policy: SpecPolicy::default(),
        }
    }
}

struct SchedInner {
    cfg: SchedulerConfig,
    map_pool: ThreadPool,
    reduce_pool: ThreadPool,
}

/// The shared-slot multi-job scheduler.  Cheap to clone (all clones share
/// the same pools); dropping the last clone joins the worker threads.
#[derive(Clone)]
pub struct JobScheduler {
    inner: Arc<SchedInner>,
}

/// A submitted job's pending result.
pub struct JobHandle<KO, VO> {
    handle: JoinHandle<JobResult<KO, VO>>,
}

impl<KO, VO> JobHandle<KO, VO> {
    /// Block until the job finishes.  DAG edges between jobs are expressed
    /// by joining a prerequisite's handle before submitting the dependent
    /// job.  Panics inside the job's tasks resurface here.
    pub fn join(self) -> JobResult<KO, VO> {
        match self.handle.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

impl JobScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let map_pool = ThreadPool::new(cfg.map_slots);
        let reduce_pool = ThreadPool::new(cfg.reduce_slots);
        Self {
            inner: Arc::new(SchedInner {
                cfg,
                map_pool,
                reduce_pool,
            }),
        }
    }

    /// Shorthand: `n` map + `n` reduce slots, speculation off.
    pub fn with_slots(n: usize) -> Self {
        Self::new(SchedulerConfig::slots(n))
    }

    pub fn map_slots(&self) -> usize {
        self.inner.map_pool.size()
    }

    pub fn reduce_slots(&self) -> usize {
        self.inner.reduce_pool.size()
    }

    pub fn speculative(&self) -> bool {
        self.inner.cfg.speculative
    }

    /// Run one job inline on the caller's thread; its tasks execute on the
    /// scheduler's shared slots.  Signature mirrors [`run_job`], with the
    /// extra `Clone`/`Sync` bounds speculation needs to re-run a task from
    /// its retained input.  `config.workers` is ignored — slot counts come
    /// from the scheduler.
    pub fn run<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(config, input, mapper, partitioner, grouping, reducer, None)
    }

    /// As [`JobScheduler::run`], with a map-side combiner.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(
            config,
            input,
            mapper,
            partitioner,
            grouping,
            reducer,
            Some(make_combine_fn(combiner)),
        )
    }

    /// Submit a job for concurrent execution: a driver thread is spawned
    /// for the job and a [`JobHandle`] returned immediately.  All
    /// submitted jobs' tasks interleave on the scheduler's shared slots.
    pub fn submit<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.spawn_driver(config, input, mapper, partitioner, grouping, reducer, None)
    }

    /// As [`JobScheduler::submit`], with a map-side combiner.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.spawn_driver(
            config,
            input,
            mapper,
            partitioner,
            grouping,
            reducer,
            Some(make_combine_fn(combiner)),
        )
    }

    /// The one driver-thread spawn point behind `submit*`.
    #[allow(clippy::too_many_arguments)]
    fn spawn_driver<KI, VI, KT, VT, KO, VO>(
        &self,
        config: JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobHandle<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        let sched = self.clone();
        let handle = std::thread::Builder::new()
            .name(format!("snmr-job-{}", config.name))
            .spawn(move || {
                sched.run_inner(
                    &config,
                    input,
                    mapper,
                    partitioner,
                    grouping,
                    reducer,
                    combine_fn,
                )
            })
            .expect("spawn job driver");
        JobHandle { handle }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_inner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        let inner = &self.inner;
        let spec = inner.cfg.speculative.then(|| inner.cfg.policy.clone());
        let t_start = Instant::now();
        let counters = Arc::new(Counters::new());
        let r = config.num_reduce_tasks;
        let sort_budget = config.sort_buffer_records;
        // same spill plumbing as the serial driver: resolve the codec
        // once, hand it to every map attempt (speculative clones write
        // their own run files; only the winner's reach the shuffle)
        let spill: Option<ResolvedSpill<(KT, VT)>> = config.spill.as_ref().map(|s| s.resolve());
        let compressed_spill = config.spill.as_ref().map(|s| s.compress()).unwrap_or(false);

        counters.add(names::MAP_INPUT_RECORDS, input.len() as u64);
        let splits = split_input(input, config.num_map_tasks);

        // ---- map wave on the shared map slots -----------------------------
        // Each attempt runs against private counters; only the winning
        // attempt's are merged, so a losing speculative clone never
        // double-counts user-code increments.  Without speculation each
        // attempt is the sole owner of its split and consumes it in
        // place; a speculative wave retains a reference per task (so a
        // clone can re-run it), which forces the deep-clone fallback.
        let t_map = Instant::now();
        let map_attempt = {
            let mapper = Arc::clone(&mapper);
            let partitioner = Arc::clone(&partitioner);
            let combine_fn = combine_fn.clone();
            let spill = spill.clone();
            move |_i: usize, split: Arc<Vec<(KI, VI)>>| {
                let local = Counters::new();
                let split = Arc::try_unwrap(split).unwrap_or_else(|shared| (*shared).clone());
                let out = exec_map_task(
                    split,
                    r,
                    sort_budget,
                    spill.as_ref(),
                    mapper.as_ref(),
                    partitioner.as_ref(),
                    combine_fn.as_ref(),
                    &local,
                );
                (out, local)
            }
        };
        let map_results: Vec<(MapTaskOutput<KT, VT>, Counters)> = speculate::run_tasks(
            &inner.map_pool,
            splits,
            Arc::new(map_attempt),
            spec.clone(),
            &counters,
        );
        let mut map_outputs: Vec<MapTaskOutput<KT, VT>> = Vec::with_capacity(map_results.len());
        for (out, local) in map_results {
            counters.merge(&local);
            map_outputs.push(out);
        }
        let map_phase_secs = t_map.elapsed().as_secs_f64();

        let mut stats = JobStats {
            map_task_secs: map_outputs.iter().map(|o| o.secs).collect(),
            map_phase_secs,
            ..Default::default()
        };
        stats.map_output_records = record_map_wave(&counters, &map_outputs, combine_fn.is_some());
        stats.spill_bytes_written = map_outputs.iter().map(|o| o.spill_file_bytes).sum();

        // ---- shuffle transpose (driver-side, cheap) -----------------------
        let t_shuffle = Instant::now();
        let (per_reducer_runs, shuffle_bytes, shuffle_bytes_raw) = transpose_runs(map_outputs, r);
        counters.add(names::SHUFFLE_BYTES, shuffle_bytes.iter().sum());
        counters.add(names::SHUFFLE_BYTES_RAW, shuffle_bytes_raw.iter().sum());
        stats.shuffle_bytes_per_reducer = shuffle_bytes;
        stats.shuffle_bytes_raw = shuffle_bytes_raw.iter().sum();
        stats.intermediate_compressed = compressed_spill && stats.spill_bytes_written > 0;
        stats.shuffle_phase_secs = t_shuffle.elapsed().as_secs_f64();

        // ---- reduce wave on the shared reduce slots -----------------------
        let t_reduce = Instant::now();
        let reduce_attempt = {
            let reducer = Arc::clone(&reducer);
            let grouping = Arc::clone(&grouping);
            move |_j: usize, runs: Arc<Vec<Run<(KT, VT)>>>| {
                let local = Counters::new();
                let runs = Arc::try_unwrap(runs).unwrap_or_else(|shared| (*shared).clone());
                let out = exec_reduce_task(runs, reducer.as_ref(), grouping.as_ref(), &local);
                (out, local)
            }
        };
        let red_results: Vec<(ReduceTaskOutput<KO, VO>, Counters)> = speculate::run_tasks(
            &inner.reduce_pool,
            per_reducer_runs,
            Arc::new(reduce_attempt),
            spec,
            &counters,
        );
        let mut red_outputs: Vec<ReduceTaskOutput<KO, VO>> = Vec::with_capacity(red_results.len());
        for (out, local) in red_results {
            counters.merge(&local);
            red_outputs.push(out);
        }
        stats.reduce_phase_secs = t_reduce.elapsed().as_secs_f64();
        stats.reduce_task_secs = red_outputs.iter().map(|o| o.secs).collect();
        stats.reduce_task_output_records =
            red_outputs.iter().map(|o| o.output.len() as u64).collect();
        stats.reduce_output_records = record_reduce_wave(&counters, &red_outputs);
        let outputs: Vec<Vec<(KO, VO)>> = red_outputs.into_iter().map(|o| o.output).collect();
        stats.total_secs = t_start.elapsed().as_secs_f64();

        JobResult {
            outputs,
            counters,
            stats,
        }
    }
}

/// Wrap a [`Combiner`] into the engine's type-erased combine step (the
/// same fold [`run_job_with_combiner`] builds on the serial path).
fn make_combine_fn<KT, VT>(combiner: Arc<dyn Combiner<KT, VT>>) -> CombineFn<KT, VT>
where
    KT: Ord + Clone + SizeEstimate + 'static,
    VT: SizeEstimate + 'static,
{
    Arc::new(move |run: &mut Vec<(KT, VT)>, c: &Counters| {
        combine_sorted_bucket(run, combiner.as_ref(), c)
    })
}

/// How a caller executes an engine job: on a job-private pool (the
/// serial [`run_job`] driver), or through a shared [`JobScheduler`] whose
/// slots are contended by every concurrently submitted job.
#[derive(Clone, Copy)]
pub enum Exec<'a> {
    /// `run_job` / `run_job_with_combiner` on a job-private pool.
    Serial,
    /// Tasks on the scheduler's shared slots (inline on this thread).
    Scheduler(&'a JobScheduler),
}

impl Exec<'_> {
    /// Dispatch a job to this executor.
    pub fn run_job<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        match self {
            Exec::Serial => run_job(config, input, mapper, partitioner, grouping, reducer),
            Exec::Scheduler(s) => s.run(config, input, mapper, partitioner, grouping, reducer),
        }
    }

    /// Dispatch a combiner job to this executor.
    #[allow(clippy::too_many_arguments)]
    pub fn run_job_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        match self {
            Exec::Serial => run_job_with_combiner(
                config,
                input,
                mapper,
                partitioner,
                grouping,
                reducer,
                combiner,
            ),
            Exec::Scheduler(s) => s.run_with_combiner(
                config,
                input,
                mapper,
                partitioner,
                grouping,
                reducer,
                combiner,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::types::{Emitter, FnMapTask, FnReduceTask, HashPartitioner, ValuesIter};
    use std::time::Duration;

    fn busy_wait(d: Duration) {
        let t0 = Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    fn histogram_job(
        n: u64,
        modulus: u64,
    ) -> (
        Vec<((), u64)>,
        Arc<FnMapTask<impl Fn((), u64, &mut Emitter<u64, u64>, &Counters)>>,
        Arc<FnReduceTask<impl Fn(&u64, ValuesIter<'_, u64>, &mut Emitter<u64, u64>, &Counters)>>,
    ) {
        let input: Vec<((), u64)> = (0..n).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            move |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(v % modulus, 1);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        (input, mapper, reducer)
    }

    fn grouping() -> GroupFn<u64> {
        Arc::new(|a: &u64, b: &u64| a == b)
    }

    #[test]
    fn scheduler_matches_serial_run_job() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let cfg = JobConfig::named("hist").with_tasks(4, 3).with_workers(2);
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let sched = JobScheduler::with_slots(3);
        let scheduled = sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        assert_eq!(serial.counters.snapshot(), scheduled.counters.snapshot());
        assert_eq!(
            serial.stats.map_output_records,
            scheduled.stats.map_output_records
        );
        assert_eq!(
            serial.stats.reduce_output_records,
            scheduled.stats.reduce_output_records
        );
    }

    #[test]
    fn disk_backed_job_on_scheduler_matches_serial() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_job(600, 7);
        let dir = TempSpillDir::new("sched-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("hist-disk")
            .with_tasks(4, 3)
            .with_workers(2)
            .with_sort_buffer(Some(32))
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let scheduled = JobScheduler::with_slots(3).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        // run files and their contents are deterministic, so even the
        // byte-level spill counters agree across executors
        assert_eq!(serial.counters.snapshot(), scheduled.counters.snapshot());
        assert!(serial.counters.get(names::SPILLED_RUNS) > 0);
        assert_eq!(
            serial.stats.spill_bytes_written,
            scheduled.stats.spill_bytes_written
        );
    }

    #[test]
    fn speculation_composes_with_disk_backed_runs() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                busy_wait(Duration::from_millis(if v == 7 { 120 } else { 1 }));
                out.emit(v % 3, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let dir = TempSpillDir::new("sched-spec-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("straggle-disk")
            .with_tasks(8, 2)
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let plain = JobScheduler::with_slots(4).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let spec = JobScheduler::new(SchedulerConfig::slots(4).with_speculation(true)).run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        // losing attempts' run files are discarded (and deleted); output
        // and engine counters stay identical
        assert_eq!(plain.outputs, spec.outputs);
        assert_eq!(
            plain.counters.get(names::SHUFFLE_BYTES),
            spec.counters.get(names::SHUFFLE_BYTES)
        );
        assert_eq!(
            plain.counters.get(names::SPILL_BYTES_WRITTEN),
            spec.counters.get(names::SPILL_BYTES_WRITTEN)
        );
    }

    #[test]
    fn concurrent_jobs_share_slots_and_keep_separate_stats() {
        let sched = JobScheduler::with_slots(4);
        let mut handles = Vec::new();
        for j in 0..3u64 {
            let (input, mapper, reducer) = histogram_job(400 + 100 * j, 5 + j);
            let cfg = JobConfig::named(&format!("job{j}")).with_tasks(4, 2);
            handles.push(sched.submit(
                cfg,
                input,
                mapper,
                Arc::new(HashPartitioner::new(|k: &u64| *k)),
                grouping(),
                reducer,
            ));
        }
        for (j, h) in handles.into_iter().enumerate() {
            let j = j as u64;
            let res = h.join();
            let n = 400 + 100 * j;
            let total: u64 = res.outputs.iter().flatten().map(|(_, c)| *c).sum();
            assert_eq!(total, n, "job {j} lost records");
            assert_eq!(res.stats.map_task_secs.len(), 4);
            assert_eq!(res.counters.get(names::MAP_INPUT_RECORDS), n);
        }
    }

    #[test]
    fn speculation_preserves_output_and_launches_on_straggler() {
        // one of 8 single-record splits busy-waits 150ms, the rest ~1ms:
        // a clean straggler for the median detector
        let input: Vec<((), u64)> = (0..8).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                busy_wait(Duration::from_millis(if v == 7 { 150 } else { 1 }));
                out.emit(v % 3, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("straggle").with_tasks(8, 2);
        let plain = JobScheduler::with_slots(4).run(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
        );
        let spec_sched = JobScheduler::new(SchedulerConfig::slots(4).with_speculation(true));
        let spec = spec_sched.run(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
        );
        assert_eq!(plain.outputs, spec.outputs);
        assert_eq!(plain.counters.get(names::SPECULATIVE_LAUNCHED), 0);
        assert!(
            spec.counters.get(names::SPECULATIVE_LAUNCHED) >= 1,
            "straggler should trigger at least one clone"
        );
        // engine counters unaffected by losing attempts
        assert_eq!(
            plain.counters.get(names::MAP_OUTPUT_RECORDS),
            spec.counters.get(names::MAP_OUTPUT_RECORDS)
        );
        assert_eq!(
            plain.counters.get(names::REDUCE_INPUT_RECORDS),
            spec.counters.get(names::REDUCE_INPUT_RECORDS)
        );
    }

    #[test]
    fn combiner_job_on_scheduler_matches_serial() {
        use crate::mapreduce::combiner::FnCombiner;
        let (input, mapper, reducer) = histogram_job(500, 5);
        let cfg = JobConfig::named("comb").with_tasks(4, 2).with_workers(2);
        let combiner = || {
            Arc::new(FnCombiner::new(|_k: &u64, vals: Vec<u64>, _c: &Counters| {
                vec![vals.into_iter().sum()]
            }))
        };
        let serial = run_job_with_combiner(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer.clone(),
            combiner(),
        );
        let scheduled = JobScheduler::with_slots(2).run_with_combiner(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            grouping(),
            reducer,
            combiner(),
        );
        assert_eq!(serial.outputs, scheduled.outputs);
        assert_eq!(
            serial.counters.get(names::COMBINE_INPUT_RECORDS),
            scheduled.counters.get(names::COMBINE_INPUT_RECORDS)
        );
        assert_eq!(
            serial.counters.get(names::SHUFFLE_BYTES),
            scheduled.counters.get(names::SHUFFLE_BYTES)
        );
    }
}
