//! The distributed control plane: one event-loop scheduler driving N
//! executor workers over typed message links (ballista-style split).
//!
//! The scheduler owns every job/task state machine ([`ControlState`]) and
//! never touches task bodies or intermediate data; executors own both.
//! Map outputs are addressed by *location*: when a map task seals its
//! runs, the executor registers `(executor_id, run ids)` per reduce
//! partition on the control plane, and reduce tasks fetch the runs
//! themselves over the data plane ([`super::executor::FetchRequest`]).
//! The channel-backed [`ChannelTransport`] is the reference wiring; the
//! message protocol is the contract a socket transport would implement.
//!
//! What moves onto the message path (previously in-process calls):
//! - **push dispatch** — reduces launch at the first `MapDone` with
//!   `sealed: false`; every later registration streams in as
//!   `AddSources`, and the wave end sends `SealReduce`,
//! - **speculation** — the scheduler clones stragglers onto another
//!   executor; first `MapDone` wins, the loser is retracted by a
//!   `DropRuns` frame when its stale completion arrives,
//! - **fault retry** — `TaskFailed` frames feed the same bounded-retry /
//!   dead-letter policy as the in-process scheduler,
//! - **loss recovery** — a dead control link (or a failed fetch pinned on
//!   a source executor) marks the executor lost: its running tasks *and*
//!   its committed map registrations are resubmitted to survivors, and
//!   parked reduces relaunch once the registry is whole again,
//! - **checkpoint restore** — executors short-circuit committed map tasks
//!   to the manifest (restore-only; the dist path does not write).
//!
//! Output is byte-identical to the serial engine: splits are computed by
//! the same `split_input`, task bodies are the shared `exec_map_task` /
//! `exec_reduce_task`, and each reduce merges fetched runs in canonical
//! map-task-ascending order — the same order `transpose_runs` produces.
//! Shuffle-byte accounting stays with the data plane: the registry
//! records run counts and ids, not bytes, so `SHUFFLE_BYTES` is zero on
//! this path (the `DIST_*` counters describe the fetch traffic instead).

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::mapreduce::checkpoint::Manifest;
use crate::mapreduce::combiner::Combiner;
use crate::mapreduce::config::JobConfig;
use crate::mapreduce::counters::{names, Counters};
use crate::mapreduce::driver;
use crate::mapreduce::engine::{
    split_input, CombineFn, DeadLetter, GroupFn, JobOutcome, JobResult, JobStats, MapTaskOutput,
    ReduceTaskOutput,
};
use crate::mapreduce::fault::{FaultInjector, FaultPlan, TaskPhase};
use crate::mapreduce::memory::{MemoryPool, ADMISSION_FLOOR_PER_TASK, DEFAULT_ADMIT_WAIT};
use crate::mapreduce::trace::{TraceEvent, TracePhase};
use crate::mapreduce::types::{MapTaskFactory, Partitioner, ReduceTaskFactory, SizeEstimate};
use crate::metrics::registry::{ExecutorLane, MetricsSpec};

use super::executor::{
    run_executor, ExecutorSpec, FetchRequest, FromExecutor, KillPlan, RunLocation, ToExecutor,
};
use super::transport::{ChannelTransport, LinkClass, Transport, TransportFaults, TxLink};
use super::{make_combine_fn, PushMode};

/// Scheduler tick: how long one `recv_timeout` waits before the loop
/// pings every executor (a failed ping is the loss signal on the
/// channel transport, where sends only fail once the peer is gone).
const TICK: Duration = Duration::from_millis(10);
/// Reduce-side fetch budget per source (fresh reply link per try).
const FETCH_ATTEMPTS: u32 = 4;
const FETCH_TIMEOUT: Duration = Duration::from_millis(500);

/// Configuration of a [`DistScheduler`]: executor count plus the
/// job-policy knobs that live scheduler-side (per-job [`JobConfig`]
/// fields override these where both exist).
#[derive(Clone)]
pub struct DistConfig {
    pub executors: usize,
    /// Barrier (two-wave) or push (reduces launch at first registration).
    pub push: PushMode,
    /// Retry budget for panicking tasks when the job doesn't set
    /// [`JobConfig::max_task_retries`].
    pub max_task_retries: u32,
    /// Clone still-running maps onto another executor once half the map
    /// wave is decided (first completion wins, loser retracted).
    pub speculative: bool,
    /// Fault plan applied when the job doesn't carry one.
    pub faults: Option<FaultPlan>,
    /// Deterministic executor-loss injection (requires ≥ 2 executors).
    pub kill: Option<KillPlan>,
    /// Drop the first N data-plane frames (fetch requests/replies) — the
    /// torn-link path `prop_exec.rs` pins.
    pub fetch_drops: u32,
    pub metrics: Option<MetricsSpec>,
    /// Shared memory pool every executor's [`RunStore`](super::executor)
    /// and task bodies account against (per-job
    /// [`JobConfig::memory`](crate::mapreduce::config::JobConfig) wins
    /// where both are set). `None` is a strict no-op.
    pub memory: Option<MemoryPool>,
}

impl DistConfig {
    pub fn executors(n: usize) -> Self {
        DistConfig {
            executors: n.max(1),
            push: PushMode::Barrier,
            max_task_retries: 0,
            speculative: false,
            faults: None,
            kill: None,
            fetch_drops: 0,
            metrics: None,
            memory: None,
        }
    }

    pub fn with_push(mut self, mode: PushMode) -> Self {
        self.push = mode;
        self
    }

    pub fn with_retries(mut self, n: u32) -> Self {
        self.max_task_retries = n;
        self
    }

    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculative = on;
        self
    }

    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    pub fn with_kill(mut self, kill: KillPlan) -> Self {
        self.kill = Some(kill);
        self
    }

    pub fn with_fetch_drops(mut self, n: u32) -> Self {
        self.fetch_drops = n;
        self
    }

    pub fn with_metrics(mut self, metrics: MetricsSpec) -> Self {
        self.metrics = Some(metrics);
        self
    }

    pub fn with_memory_pool(mut self, pool: MemoryPool) -> Self {
        self.memory = Some(pool);
        self
    }
}

/// The message-passing scheduler. Construct once, submit jobs through
/// [`DistScheduler::run`] / [`run_with_combiner`](Self::run_with_combiner)
/// (or route an SN variant through `Exec::Dist`).
pub struct DistScheduler {
    cfg: DistConfig,
}

impl DistScheduler {
    pub fn new(cfg: DistConfig) -> Self {
        DistScheduler { cfg }
    }

    pub fn with_executors(n: usize) -> Self {
        Self::new(DistConfig::executors(n))
    }

    pub fn config(&self) -> &DistConfig {
        &self.cfg
    }

    /// Run one job across this scheduler's executors. Same signature and
    /// (byte-identical) output as the serial `run_job`.
    #[allow(clippy::too_many_arguments)]
    pub fn run<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(config, input, mapper, partitioner, grouping, reducer, None)
    }

    /// As [`DistScheduler::run`], with a map-side combiner.
    #[allow(clippy::too_many_arguments)]
    pub fn run_with_combiner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combiner: Arc<dyn Combiner<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        self.run_inner(
            config,
            input,
            mapper,
            partitioner,
            grouping,
            reducer,
            Some(make_combine_fn(combiner)),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn run_inner<KI, VI, KT, VT, KO, VO>(
        &self,
        config: &JobConfig,
        input: Vec<(KI, VI)>,
        mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
        partitioner: Arc<dyn Partitioner<KT>>,
        grouping: GroupFn<KT>,
        reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
        combine_fn: Option<CombineFn<KT, VT>>,
    ) -> JobResult<KO, VO>
    where
        KI: Clone + Send + Sync + 'static,
        VI: Clone + Send + Sync + 'static,
        KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
        VT: Clone + Send + Sync + SizeEstimate + 'static,
        KO: Send + SizeEstimate + 'static,
        VO: Send + SizeEstimate + 'static,
    {
        let n = self.cfg.executors.max(1);
        let kill = self.cfg.kill;
        if kill.is_some() {
            assert!(n >= 2, "a kill plan needs >= 2 executors to fail over to");
        }
        let push = config.push || matches!(self.cfg.push, PushMode::Push);
        let retries = config.max_task_retries.unwrap_or(self.cfg.max_task_retries);
        let dead_letter = config.dead_letter;
        let faults = config.faults.clone().or_else(|| self.cfg.faults.clone());
        let r = config.num_reduce_tasks.max(1);
        let compressed_spill = config.spill.as_ref().map(|s| s.compress()).unwrap_or(false);

        let t_start = Instant::now();
        let counters = Arc::new(Counters::new());
        let jctx = config.trace.as_ref().map(|t| t.job_ctx(&config.name));

        counters.add(names::MAP_INPUT_RECORDS, input.len() as u64);
        let splits: Vec<Arc<Vec<(KI, VI)>>> = split_input(input, config.num_map_tasks)
            .into_iter()
            .map(Arc::new)
            .collect();
        let m = splits.len();
        let split_lens: Vec<u64> = splits.iter().map(|s| s.len() as u64).collect();

        let spill = config.spill.as_ref().map(|s| s.resolve::<(KT, VT)>());
        let manifest: Option<(Arc<Manifest>, _)> = config.checkpoint.as_ref().and_then(|c| {
            let man = Manifest::load(&c.manifest_path())?;
            if !man.matches(&config.name, m, r) {
                return None;
            }
            Some((Arc::new(man), c.resolve::<(KT, VT)>()))
        });
        let injector = FaultInjector::from_plan(faults);

        // ---- memory pool: job override wins, then admission control -----
        // (same protocol as the in-process scheduler; held until this
        // driver returns)
        let pool = config.memory.clone().or_else(|| self.cfg.memory.clone());
        let _admission = pool.as_ref().map(|p| {
            let tasks = m.min(n).max(1) as u64;
            p.admit(&config.name, tasks * ADMISSION_FLOOR_PER_TASK, DEFAULT_ADMIT_WAIT)
        });

        // ---- wire the transport and spawn the executors -----------------
        let transport = ChannelTransport::with_faults(TransportFaults {
            drop_data_sends: self.cfg.fetch_drops,
        });
        let (tx_out, rx_out) = transport.link::<FromExecutor<KT, VT, KO, VO>>(LinkClass::Control);
        let mut ctl_txs: Vec<TxLink<ToExecutor<KI, VI>>> = Vec::with_capacity(n);
        let mut ctl_rxs = Vec::with_capacity(n);
        let mut data_txs: Vec<TxLink<FetchRequest<(KT, VT)>>> = Vec::with_capacity(n);
        let mut data_rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = transport.link(LinkClass::Control);
            ctl_txs.push(tx);
            ctl_rxs.push(Some(rx));
            let (tx, rx) = transport.link(LinkClass::Data);
            data_txs.push(tx);
            data_rxs.push(Some(rx));
        }
        let mut handles = Vec::with_capacity(n);
        for (e, (ctl_rx, data_rx)) in ctl_rxs.iter_mut().zip(data_rxs.iter_mut()).enumerate() {
            let spec = ExecutorSpec {
                id: e,
                num_reducers: r,
                rx_ctl: ctl_rx.take().expect("control link taken twice"),
                tx_out: tx_out.clone(),
                rx_data: data_rx.take().expect("data link taken twice"),
                peers: data_txs.clone(),
                mapper: Arc::clone(&mapper),
                partitioner: Arc::clone(&partitioner),
                combine_fn: combine_fn.clone(),
                reducer: Arc::clone(&reducer),
                grouping: Arc::clone(&grouping),
                spill: spill.clone(),
                sort_budget: config.sort_buffer_records,
                injector: Arc::clone(&injector),
                kill,
                manifest: manifest.clone(),
                jctx: jctx.clone(),
                t0: t_start,
                fetch_attempts: FETCH_ATTEMPTS,
                fetch_timeout: FETCH_TIMEOUT,
                memory: pool.clone(),
            };
            let tp = transport.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("snmr-exec-{e}"))
                    .spawn(move || run_executor(spec, tp))
                    .expect("spawn executor"),
            );
        }
        drop(tx_out);

        let lanes: Option<Vec<ExecutorLane>> = self
            .cfg
            .metrics
            .as_ref()
            .map(|ms| (0..n).map(|e| ms.executor_lane(e)).collect());

        // ---- scheduler-side state ---------------------------------------
        struct RegistryEntry {
            executor: usize,
            run_counts: Vec<u32>,
            run_ids: Vec<Vec<u64>>,
        }
        let mut state = ControlState::new(n, m, r, retries);
        let mut registry: Vec<Option<RegistryEntry>> = (0..m).map(|_| None).collect();
        let mut map_outs: Vec<Option<MapTaskOutput<KT, VT>>> = (0..m).map(|_| None).collect();
        let mut map_counters: Vec<Option<Counters>> = (0..m).map(|_| None).collect();
        let mut red_outs: Vec<Option<(ReduceTaskOutput<KO, VO>, f64)>> =
            (0..r).map(|_| None).collect();
        let mut dead_letters: Vec<DeadLetter> = Vec::new();
        let mut sent_sources: Vec<Vec<bool>> = (0..r).map(|_| vec![false; m]).collect();
        let mut parked_reduces = vec![false; r];
        let mut lost_pending: Vec<usize> = Vec::new();
        let mut reduces_launched = false;
        let mut map_wave_done_secs: Option<f64> = None;
        let speculative = self.cfg.speculative;

        // The macros below expand inline over the locals above — the
        // pragmatic way to share dispatch logic across the loop arms
        // without fighting simultaneous closure borrows.
        macro_rules! launch_map {
            ($i:expr) => {{
                let i: usize = $i;
                let e = state.next_alive();
                let attempt = state.begin(TaskPhase::Map, i, e);
                if let Some(jc) = &jctx {
                    jc.task(TracePhase::Map, i, attempt)
                        .emit(TraceEvent::AttemptScheduled);
                }
                if let Some(l) = &lanes {
                    l[e].in_flight.inc();
                }
                if ctl_txs[e]
                    .send(ToExecutor::LaunchMap { task: i, attempt, split: Arc::clone(&splits[i]) })
                    .is_err()
                {
                    lost_pending.push(e);
                }
            }};
        }
        macro_rules! sources_for {
            ($j:expr) => {{
                let j: usize = $j;
                let mut v: Vec<RunLocation> = Vec::new();
                for (i, entry) in registry.iter().enumerate() {
                    if let Some(en) = entry {
                        debug_assert_eq!(en.run_ids[j].len() as u32, en.run_counts[j]);
                        sent_sources[j][i] = true;
                        v.push(RunLocation {
                            map_task: i,
                            executor: en.executor,
                            runs: en.run_counts[j],
                        });
                    }
                }
                v
            }};
        }
        macro_rules! launch_reduce {
            ($j:expr, $sealed:expr) => {{
                let j: usize = $j;
                sent_sources[j] = vec![false; m];
                parked_reduces[j] = false;
                let sources = sources_for!(j);
                let e = state.next_alive();
                let attempt = state.begin(TaskPhase::Reduce, j, e);
                if let Some(jc) = &jctx {
                    jc.task(TracePhase::Reduce, j, attempt)
                        .emit(TraceEvent::AttemptScheduled);
                }
                if let Some(l) = &lanes {
                    l[e].in_flight.inc();
                }
                if ctl_txs[e]
                    .send(ToExecutor::LaunchReduce { task: j, attempt, sources, sealed: $sealed })
                    .is_err()
                {
                    lost_pending.push(e);
                }
            }};
        }

        // Every map is dispatched up front, round-robin across executors
        // (location-oblivious; the shuffle is fetch-by-location anyway).
        for i in 0..m {
            launch_map!(i);
        }

        // ---- the event loop ---------------------------------------------
        loop {
            // 1. Settle reported losses: resubmit what the dead executor
            //    ran *and* what it had committed (its runs are gone).
            while let Some(e) = lost_pending.pop() {
                let report = state.mark_lost(e);
                if !report.was_alive {
                    continue;
                }
                counters.inc(names::EXECUTORS_LOST);
                if let Some(jc) = &jctx {
                    jc.emit_job(TraceEvent::ExecutorLost { executor: e as u64 });
                }
                if let Some(l) = &lanes {
                    l[e].lost.inc();
                    l[e].in_flight.set(0);
                    l[e].runs_held.set(0);
                }
                for i in 0..m {
                    if registry[i].as_ref().map(|en| en.executor == e).unwrap_or(false) {
                        registry[i] = None;
                        map_outs[i] = None;
                        map_counters[i] = None;
                    }
                }
                for i in report.maps {
                    counters.inc(names::TASK_RETRIES);
                    if let Some(jc) = &jctx {
                        jc.task(TracePhase::Map, i, state.attempts(TaskPhase::Map, i))
                            .emit(TraceEvent::TaskRetried);
                    }
                    launch_map!(i);
                }
                for j in report.reduces {
                    counters.inc(names::TASK_RETRIES);
                    if let Some(jc) = &jctx {
                        jc.task(TracePhase::Reduce, j, state.attempts(TaskPhase::Reduce, j))
                            .emit(TraceEvent::TaskRetried);
                    }
                    parked_reduces[j] = true;
                }
            }

            // 2. Map wave decided → stamp it once, then launch (barrier) or
            //    top-up-and-seal (push) every undecided reduce.
            if map_wave_done_secs.is_none() && state.maps_all_done() {
                let now = t_start.elapsed().as_secs_f64();
                map_wave_done_secs = Some(now);
                if let Some(jc) = &jctx {
                    jc.emit_job_at(TraceEvent::MapWaveDone, now);
                }
                for j in 0..r {
                    if state.reduces[j].done.is_some() || state.reduces[j].dead_lettered {
                        continue;
                    }
                    if parked_reduces[j] || state.reduces[j].running.is_empty() {
                        launch_reduce!(j, true);
                    } else {
                        // Pending push reduce: stream any sources it missed,
                        // then seal it.
                        let e_red = state.reduces[j].running[0].0;
                        let mut extra = Vec::new();
                        for (i, entry) in registry.iter().enumerate() {
                            if let Some(en) = entry {
                                if !sent_sources[j][i] {
                                    sent_sources[j][i] = true;
                                    extra.push(RunLocation {
                                        map_task: i,
                                        executor: en.executor,
                                        runs: en.run_counts[j],
                                    });
                                }
                            }
                        }
                        let mut down = false;
                        if !extra.is_empty() {
                            down = ctl_txs[e_red]
                                .send(ToExecutor::AddSources { task: j, sources: extra })
                                .is_err();
                        }
                        if !down {
                            down = ctl_txs[e_red].send(ToExecutor::SealReduce { task: j }).is_err();
                        }
                        if down {
                            lost_pending.push(e_red);
                        }
                    }
                }
                reduces_launched = true;
                if !lost_pending.is_empty() {
                    continue;
                }
            }

            // 3. Relaunch parked reduces once their sources are resolvable.
            for j in 0..r {
                if !parked_reduces[j]
                    || state.reduces[j].done.is_some()
                    || state.reduces[j].dead_lettered
                {
                    continue;
                }
                if map_wave_done_secs.is_some() {
                    if state.maps_all_done() {
                        launch_reduce!(j, true);
                    }
                } else if reduces_launched {
                    launch_reduce!(j, false);
                }
            }

            // 4. Speculation: once half the map wave is decided, clone each
            //    still-running map onto a different executor (once).
            if speculative && n >= 2 && state.alive_count() >= 2 {
                let done = state.maps.iter().filter(|s| s.done.is_some()).count();
                if done * 2 >= m {
                    for i in 0..m {
                        let slot = &state.maps[i];
                        if slot.done.is_some()
                            || slot.dead_lettered
                            || slot.clone_attempt.is_some()
                            || slot.running.len() != 1
                        {
                            continue;
                        }
                        let primary = slot.running[0].0;
                        if let Some(e) = state.next_alive_except(primary) {
                            let attempt = state.begin_speculative(TaskPhase::Map, i, e);
                            counters.inc(names::SPECULATIVE_LAUNCHED);
                            if let Some(jc) = &jctx {
                                jc.task(TracePhase::Map, i, attempt)
                                    .emit(TraceEvent::SpeculativeCloned);
                            }
                            if let Some(l) = &lanes {
                                l[e].in_flight.inc();
                            }
                            if ctl_txs[e]
                                .send(ToExecutor::LaunchMap {
                                    task: i,
                                    attempt,
                                    split: Arc::clone(&splits[i]),
                                })
                                .is_err()
                            {
                                lost_pending.push(e);
                            }
                        }
                    }
                }
            }
            if !lost_pending.is_empty() {
                continue;
            }

            // 5. Done?
            if state.maps_all_done() && state.reduces_all_done() {
                break;
            }

            // 6. Wait for the next frame; an idle tick pings every live
            //    executor so a silent disconnect can't stall the loop.
            let msg = match rx_out.recv_timeout(TICK) {
                Ok(Some(msg)) => msg,
                Ok(None) => {
                    for e in 0..n {
                        if state.is_alive(e) && ctl_txs[e].send(ToExecutor::Ping).is_err() {
                            lost_pending.push(e);
                        }
                    }
                    continue;
                }
                Err(_) => panic!("dist scheduler: every executor disconnected"),
            };
            let now = t_start.elapsed().as_secs_f64();
            match msg {
                FromExecutor::Registered { executor } => {
                    state.register(executor);
                    state.heartbeat(executor, now);
                    if let Some(jc) = &jctx {
                        jc.emit_job(TraceEvent::ExecutorRegistered { executor: executor as u64 });
                    }
                }
                FromExecutor::MapDone {
                    executor,
                    task,
                    attempt,
                    out,
                    run_counts,
                    run_ids,
                    counters: local,
                } => {
                    if !state.is_alive(executor) {
                        continue;
                    }
                    state.heartbeat(executor, now);
                    if let Some(l) = &lanes {
                        l[executor].in_flight.dec();
                    }
                    match state.complete(TaskPhase::Map, task, executor, attempt) {
                        Committed::Stale => {
                            // Speculation loser or superseded attempt: its
                            // registered runs must not survive.
                            if let Some(jc) = &jctx {
                                jc.task(TracePhase::Map, task, attempt)
                                    .emit(TraceEvent::AttemptLost);
                            }
                            let _ = ctl_txs[executor].send(ToExecutor::DropRuns { task, attempt });
                        }
                        Committed::Won => {
                            if state.maps[task].clone_attempt == Some(attempt) {
                                counters.inc(names::SPECULATIVE_WON);
                            }
                            if let Some(jc) = &jctx {
                                jc.task(TracePhase::Map, task, attempt)
                                    .emit(TraceEvent::AttemptWon);
                            }
                            if let Some(l) = &lanes {
                                l[executor].tasks_done.inc();
                                l[executor]
                                    .runs_held
                                    .add(run_counts.iter().map(|&c| c as i64).sum());
                            }
                            registry[task] = Some(RegistryEntry { executor, run_counts, run_ids });
                            map_outs[task] = Some(out);
                            map_counters[task] = Some(local);
                            if push && !reduces_launched {
                                reduces_launched = true;
                                for j in 0..r {
                                    launch_reduce!(j, false);
                                }
                            } else if push {
                                // Stream this registration into pending
                                // reduces that don't have it yet.
                                for j in 0..r {
                                    if sent_sources[j][task] || parked_reduces[j] {
                                        continue;
                                    }
                                    if let Some(&(e_red, _)) = state.reduces[j].running.first() {
                                        sent_sources[j][task] = true;
                                        let en = registry[task].as_ref().expect("just registered");
                                        if ctl_txs[e_red]
                                            .send(ToExecutor::AddSources {
                                                task: j,
                                                sources: vec![RunLocation {
                                                    map_task: task,
                                                    executor: en.executor,
                                                    runs: en.run_counts[j],
                                                }],
                                            })
                                            .is_err()
                                        {
                                            lost_pending.push(e_red);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                FromExecutor::ReduceDone {
                    executor,
                    task,
                    attempt,
                    out,
                    counters: local,
                    started_secs,
                } => {
                    if !state.is_alive(executor) {
                        continue;
                    }
                    state.heartbeat(executor, now);
                    if let Some(l) = &lanes {
                        l[executor].in_flight.dec();
                    }
                    match state.complete(TaskPhase::Reduce, task, executor, attempt) {
                        Committed::Stale => {}
                        Committed::Won => {
                            if let Some(jc) = &jctx {
                                jc.task(TracePhase::Reduce, task, attempt)
                                    .emit(TraceEvent::AttemptWon);
                            }
                            if let Some(l) = &lanes {
                                l[executor].tasks_done.inc();
                            }
                            counters.merge(&local);
                            red_outs[task] = Some((out, started_secs));
                            parked_reduces[task] = false;
                        }
                    }
                }
                FromExecutor::TaskFailed { executor, phase, task, attempt, message } => {
                    if !state.is_alive(executor) {
                        continue;
                    }
                    state.heartbeat(executor, now);
                    if let Some(l) = &lanes {
                        l[executor].in_flight.dec();
                    }
                    let tphase = trace_phase(phase);
                    match state.fail(phase, task, attempt) {
                        FailAction::Stale => {}
                        FailAction::Retry => {
                            counters.inc(names::TASK_RETRIES);
                            if let Some(jc) = &jctx {
                                jc.task(tphase, task, attempt).emit(TraceEvent::TaskRetried);
                            }
                            match phase {
                                TaskPhase::Map => launch_map!(task),
                                TaskPhase::Reduce => {
                                    launch_reduce!(task, map_wave_done_secs.is_some())
                                }
                            }
                        }
                        FailAction::Exhausted => {
                            counters.inc(names::TASKS_FAILED);
                            if !dead_letter {
                                // Fail fast, like the in-process paths: tear
                                // the cluster down and re-raise the panic.
                                for tx in &ctl_txs {
                                    let _ = tx.send(ToExecutor::Shutdown);
                                }
                                drop(ctl_txs);
                                drop(data_txs);
                                for h in handles {
                                    let _ = h.join();
                                }
                                panic!("{message}");
                            }
                            counters.inc(names::DEAD_LETTERED);
                            state.dead_letter(phase, task);
                            if let Some(jc) = &jctx {
                                jc.task(tphase, task, attempt).emit(TraceEvent::DeadLettered {
                                    message: format!(
                                        "{phase} task {task} exhausted its retry budget"
                                    ),
                                });
                            }
                            match phase {
                                TaskPhase::Map => {
                                    dead_letters.push(DeadLetter {
                                        phase,
                                        task,
                                        records: split_lens[task],
                                    });
                                    registry[task] = None;
                                    map_outs[task] = Some(MapTaskOutput::empty(r));
                                    map_counters[task] = None;
                                }
                                TaskPhase::Reduce => {
                                    let records: u64 = registry
                                        .iter()
                                        .flatten()
                                        .map(|en| en.run_counts[task] as u64)
                                        .sum();
                                    dead_letters.push(DeadLetter { phase, task, records });
                                    red_outs[task] =
                                        Some((ReduceTaskOutput::empty(), f64::INFINITY));
                                    parked_reduces[task] = false;
                                }
                            }
                        }
                    }
                }
                FromExecutor::FetchFailed { executor, task, attempt, source } => {
                    if !state.is_alive(executor) {
                        continue;
                    }
                    state.heartbeat(executor, now);
                    if let Some(l) = &lanes {
                        l[executor].in_flight.dec();
                    }
                    // The reduce attempt aborted; the source executor could
                    // not produce runs it had registered — treat it as lost
                    // and park the reduce until the registry is whole again.
                    if state.abort_attempt(TaskPhase::Reduce, task, attempt) {
                        counters.inc(names::TASK_RETRIES);
                        parked_reduces[task] = true;
                    }
                    if state.is_alive(source.executor) {
                        lost_pending.push(source.executor);
                    }
                }
            }
        }

        // ---- tear down and assemble the result --------------------------
        for tx in &ctl_txs {
            let _ = tx.send(ToExecutor::Shutdown);
        }
        drop(ctl_txs);
        drop(data_txs);
        for h in handles {
            let _ = h.join();
        }

        let wave_secs =
            map_wave_done_secs.unwrap_or_else(|| t_start.elapsed().as_secs_f64());
        let mut stats = JobStats {
            map_phase_secs: wave_secs,
            map_wave_done_secs: wave_secs,
            ..JobStats::default()
        };
        // Winning map attempts' counters merge exactly once, here — merging
        // at MapDone would double-count any task re-run after a loss.
        for local in map_counters.iter().flatten() {
            counters.merge(local);
        }
        let map_outputs: Vec<MapTaskOutput<KT, VT>> = map_outs
            .into_iter()
            .map(|o| o.expect("map output missing at job end"))
            .collect();
        // The runs were stripped executor-side, so the transpose only
        // feeds the (empty) byte accounting — same shape as the push path.
        let _ = driver::record_map_phase(
            &mut stats,
            &counters,
            map_outputs,
            r,
            combine_fn.is_some(),
            compressed_spill,
        );

        let mut first_start = f64::INFINITY;
        let mut red_outputs = Vec::with_capacity(r);
        for slot in red_outs {
            let (out, started) = slot.expect("reduce output missing at job end");
            first_start = first_start.min(started);
            red_outputs.push(out);
        }
        stats.reduce_first_start_secs = if first_start.is_finite() { first_start } else { 0.0 };
        stats.overlap_secs = (wave_secs - stats.reduce_first_start_secs).max(0.0);
        if let Some(jc) = &jctx {
            jc.emit_job_at(TraceEvent::ReduceFirstStart, stats.reduce_first_start_secs);
        }
        stats.reduce_phase_secs =
            (t_start.elapsed().as_secs_f64() - stats.reduce_first_start_secs).max(0.0);
        driver::record_reduce_phase(&mut stats, &counters, &red_outputs);
        let outputs: Vec<Vec<(KO, VO)>> = red_outputs.into_iter().map(|o| o.output).collect();
        stats.total_secs = t_start.elapsed().as_secs_f64();
        if let Some(jc) = &jctx {
            jc.emit_job_at(TraceEvent::JobFinished, stats.total_secs);
        }
        stats.task_retries = counters.get(names::TASK_RETRIES);
        stats.tasks_failed = counters.get(names::TASKS_FAILED);
        stats.dead_letters = dead_letters;
        stats.dead_letters.sort_by_key(|d| (d.phase != TaskPhase::Map, d.task));
        let outcome = if counters.get(names::DEAD_LETTERED) > 0 {
            JobOutcome::Degraded
        } else {
            JobOutcome::Ok
        };
        if let Some(ms) = &self.cfg.metrics {
            ms.absorb_job(&counters, &stats);
        }
        JobResult { outputs, counters, stats, outcome }
    }
}

fn trace_phase(p: TaskPhase) -> TracePhase {
    match p {
        TaskPhase::Map => TracePhase::Map,
        TaskPhase::Reduce => TracePhase::Reduce,
    }
}

// ---------------------------------------------------------------------------
// Pure task/executor state machines — everything the event loop decides,
// with no transport attached, so loss/retry/arbitration transitions are
// unit-testable (and reusable by a future socket-backed control plane).
// ---------------------------------------------------------------------------

/// One task's attempt ledger.
#[derive(Debug, Clone, Default)]
pub(crate) struct TaskSlot {
    /// Live attempts as `(executor, attempt)` — more than one only while
    /// a speculative clone races the primary.
    pub running: Vec<(usize, u32)>,
    /// The committed attempt, if decided.
    pub done: Option<(usize, u32)>,
    pub dead_lettered: bool,
    pub next_attempt: u32,
    /// Panic-failure count (loss resubmissions don't count against it).
    pub failures: u32,
    /// The speculative clone's attempt number, if one was launched.
    pub clone_attempt: Option<u32>,
}

#[derive(Debug, Clone)]
pub(crate) struct ExecutorSlot {
    pub registered: bool,
    pub alive: bool,
    pub last_seen_secs: f64,
}

#[derive(Debug, PartialEq, Eq)]
pub(crate) enum Committed {
    Won,
    Stale,
}

#[derive(Debug, PartialEq, Eq)]
pub(crate) enum FailAction {
    Retry,
    Exhausted,
    /// The failing attempt is no longer current (superseded or the task
    /// already decided) — ignore it.
    Stale,
}

/// What a lost executor takes with it: the tasks that must re-run.
#[derive(Debug, Default, PartialEq, Eq)]
pub(crate) struct LossReport {
    pub was_alive: bool,
    pub maps: Vec<usize>,
    pub reduces: Vec<usize>,
}

pub(crate) struct ControlState {
    pub executors: Vec<ExecutorSlot>,
    pub maps: Vec<TaskSlot>,
    pub reduces: Vec<TaskSlot>,
    max_retries: u32,
    cursor: usize,
}

impl ControlState {
    pub fn new(n: usize, m: usize, r: usize, max_retries: u32) -> Self {
        ControlState {
            executors: (0..n)
                .map(|_| ExecutorSlot { registered: false, alive: true, last_seen_secs: 0.0 })
                .collect(),
            maps: vec![TaskSlot::default(); m],
            reduces: vec![TaskSlot::default(); r],
            max_retries,
            cursor: n.saturating_sub(1),
        }
    }

    fn slot_mut(&mut self, phase: TaskPhase, task: usize) -> &mut TaskSlot {
        match phase {
            TaskPhase::Map => &mut self.maps[task],
            TaskPhase::Reduce => &mut self.reduces[task],
        }
    }

    fn slot(&self, phase: TaskPhase, task: usize) -> &TaskSlot {
        match phase {
            TaskPhase::Map => &self.maps[task],
            TaskPhase::Reduce => &self.reduces[task],
        }
    }

    pub fn register(&mut self, e: usize) {
        self.executors[e].registered = true;
    }

    pub fn heartbeat(&mut self, e: usize, now: f64) {
        self.executors[e].last_seen_secs = now;
    }

    /// Registered, still-alive executors whose last frame is older than
    /// `timeout`. The channel transport detects loss by failed sends
    /// instead; a socket control plane would drive `mark_lost` from this.
    pub fn heartbeats_missed(&self, now: f64, timeout: f64) -> Vec<usize> {
        self.executors
            .iter()
            .enumerate()
            .filter(|(_, s)| s.registered && s.alive && now - s.last_seen_secs > timeout)
            .map(|(e, _)| e)
            .collect()
    }

    pub fn is_alive(&self, e: usize) -> bool {
        self.executors[e].alive
    }

    pub fn alive_count(&self) -> usize {
        self.executors.iter().filter(|s| s.alive).count()
    }

    /// Round-robin over live executors.
    pub fn next_alive(&mut self) -> usize {
        assert!(self.alive_count() > 0, "dist scheduler: all executors lost");
        loop {
            self.cursor = (self.cursor + 1) % self.executors.len();
            if self.executors[self.cursor].alive {
                return self.cursor;
            }
        }
    }

    /// A live executor other than `not`, if one exists.
    pub fn next_alive_except(&mut self, not: usize) -> Option<usize> {
        for _ in 0..self.executors.len() {
            let e = self.next_alive();
            if e != not {
                return Some(e);
            }
        }
        None
    }

    /// Open a new attempt of `task` on `e`; returns the attempt number.
    pub fn begin(&mut self, phase: TaskPhase, task: usize, e: usize) -> u32 {
        let slot = self.slot_mut(phase, task);
        let attempt = slot.next_attempt;
        slot.next_attempt += 1;
        slot.running.push((e, attempt));
        attempt
    }

    /// As [`begin`](Self::begin), marking the attempt as the speculative
    /// clone (at most one per task).
    pub fn begin_speculative(&mut self, phase: TaskPhase, task: usize, e: usize) -> u32 {
        let attempt = self.begin(phase, task, e);
        self.slot_mut(phase, task).clone_attempt = Some(attempt);
        attempt
    }

    /// First-completion-wins arbitration: the first live attempt to report
    /// commits the task; everything else is stale.
    pub fn complete(&mut self, phase: TaskPhase, task: usize, e: usize, attempt: u32) -> Committed {
        let slot = self.slot_mut(phase, task);
        // Only a currently-scheduled attempt can win — one cleared by
        // `mark_lost` (and resubmitted elsewhere) reports as stale.
        let was_scheduled = slot.running.iter().any(|&(re, ra)| (re, ra) == (e, attempt));
        slot.running.retain(|&(re, ra)| (re, ra) != (e, attempt));
        if !was_scheduled || slot.done.is_some() || slot.dead_lettered {
            return Committed::Stale;
        }
        slot.done = Some((e, attempt));
        slot.running.clear();
        Committed::Won
    }

    /// A panicking attempt: consume a retry or declare exhaustion.
    pub fn fail(&mut self, phase: TaskPhase, task: usize, attempt: u32) -> FailAction {
        let max_retries = self.max_retries;
        let slot = self.slot_mut(phase, task);
        let had = slot.running.iter().any(|&(_, ra)| ra == attempt);
        slot.running.retain(|&(_, ra)| ra != attempt);
        if !had || slot.done.is_some() || slot.dead_lettered {
            return FailAction::Stale;
        }
        slot.failures += 1;
        if slot.failures <= max_retries {
            FailAction::Retry
        } else {
            FailAction::Exhausted
        }
    }

    /// Remove a live attempt without charging the retry budget (fetch
    /// aborts — the attempt never ran its body). True if it was current.
    pub fn abort_attempt(&mut self, phase: TaskPhase, task: usize, attempt: u32) -> bool {
        let slot = self.slot_mut(phase, task);
        let had = slot.running.iter().any(|&(_, ra)| ra == attempt);
        slot.running.retain(|&(_, ra)| ra != attempt);
        had && slot.done.is_none() && !slot.dead_lettered
    }

    pub fn dead_letter(&mut self, phase: TaskPhase, task: usize) {
        let slot = self.slot_mut(phase, task);
        slot.dead_lettered = true;
        slot.running.clear();
    }

    /// Declare `e` dead: clear its attempts and its committed map wins
    /// (their runs died with it) and report every task needing a re-run.
    pub fn mark_lost(&mut self, e: usize) -> LossReport {
        if !self.executors[e].alive {
            return LossReport::default();
        }
        self.executors[e].alive = false;
        let mut report = LossReport { was_alive: true, ..LossReport::default() };
        for (i, slot) in self.maps.iter_mut().enumerate() {
            let mut touched = false;
            if slot.done.map(|(de, _)| de == e).unwrap_or(false) {
                slot.done = None;
                touched = true;
            }
            if slot.running.iter().any(|&(re, _)| re == e) {
                slot.running.retain(|&(re, _)| re != e);
                touched = true;
            }
            if touched && !slot.dead_lettered && slot.done.is_none() && slot.running.is_empty() {
                slot.clone_attempt = None;
                report.maps.push(i);
            }
        }
        for (j, slot) in self.reduces.iter_mut().enumerate() {
            // A decided reduce stays decided — its output already crossed
            // the control plane.
            if slot.done.is_some() || slot.dead_lettered {
                continue;
            }
            if slot.running.iter().any(|&(re, _)| re == e) {
                slot.running.retain(|&(re, _)| re != e);
                if slot.running.is_empty() {
                    slot.clone_attempt = None;
                    report.reduces.push(j);
                }
            }
        }
        report
    }

    /// Total attempts opened so far for `task` (trace labelling).
    pub fn attempts(&self, phase: TaskPhase, task: usize) -> u32 {
        self.slot(phase, task).next_attempt
    }

    pub fn maps_all_done(&self) -> bool {
        self.maps.iter().all(|s| s.done.is_some() || s.dead_lettered)
    }

    pub fn reduces_all_done(&self) -> bool {
        self.reduces.iter().all(|s| s.done.is_some() || s.dead_lettered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::engine::run_job;
    use crate::mapreduce::types::{Emitter, FnMapTask, FnReduceTask, HashPartitioner, ValuesIter};

    // ---- ControlState transitions ------------------------------------

    #[test]
    fn loss_resubmits_running_and_committed_tasks() {
        let mut st = ControlState::new(2, 3, 1, 0);
        st.register(0);
        st.register(1);
        st.heartbeat(0, 0.0);
        st.heartbeat(1, 0.0);

        let a0 = st.begin(TaskPhase::Map, 0, 0);
        let a1 = st.begin(TaskPhase::Map, 1, 1);
        let _a2 = st.begin(TaskPhase::Map, 2, 0);
        assert_eq!(st.complete(TaskPhase::Map, 1, 1, a1), Committed::Won);
        assert_eq!(st.complete(TaskPhase::Map, 0, 0, a0), Committed::Won);

        // Executor 1 goes silent; executor 0 keeps reporting.
        st.heartbeat(0, 9.5);
        assert_eq!(st.heartbeats_missed(10.0, 5.0), vec![1]);

        // Losing executor 0 takes its running map 2 AND its committed
        // map 0 (the runs lived there); map 1's win on executor 1 stays.
        let report = st.mark_lost(0);
        assert!(report.was_alive);
        assert_eq!(report.maps, vec![0, 2]);
        assert!(report.reduces.is_empty());
        assert!(!st.is_alive(0));
        assert!(!st.maps_all_done());

        // Resubmit both to the survivor and finish.
        for i in report.maps {
            let e = st.next_alive();
            assert_eq!(e, 1);
            let a = st.begin(TaskPhase::Map, i, e);
            assert_eq!(st.complete(TaskPhase::Map, i, e, a), Committed::Won);
        }
        assert!(st.maps_all_done());

        // A second mark_lost is a no-op.
        assert_eq!(st.mark_lost(0), LossReport::default());
    }

    #[test]
    fn retry_budget_exhaustion_dead_letters_the_task() {
        let mut st = ControlState::new(1, 1, 1, 1);
        let a0 = st.begin(TaskPhase::Map, 0, 0);
        assert_eq!(st.fail(TaskPhase::Map, 0, a0), FailAction::Retry);
        let a1 = st.begin(TaskPhase::Map, 0, 0);
        assert_eq!(st.fail(TaskPhase::Map, 0, a1), FailAction::Exhausted);
        st.dead_letter(TaskPhase::Map, 0);
        assert!(st.maps_all_done());
        // Reports about dead-lettered attempts are stale from here on.
        assert_eq!(st.fail(TaskPhase::Map, 0, a1), FailAction::Stale);
        assert_eq!(st.complete(TaskPhase::Map, 0, 0, a1), Committed::Stale);
    }

    #[test]
    fn first_completion_wins_and_the_clone_loses() {
        let mut st = ControlState::new(2, 1, 1, 0);
        let primary = st.begin(TaskPhase::Map, 0, 0);
        let clone = st.begin_speculative(TaskPhase::Map, 0, 1);
        assert_eq!(st.maps[0].clone_attempt, Some(clone));
        assert_eq!(st.complete(TaskPhase::Map, 0, 1, clone), Committed::Won);
        assert_eq!(st.complete(TaskPhase::Map, 0, 0, primary), Committed::Stale);
        assert_eq!(st.maps[0].done, Some((1, clone)));
    }

    #[test]
    fn fetch_abort_does_not_charge_the_retry_budget() {
        let mut st = ControlState::new(2, 1, 1, 0);
        let a = st.begin(TaskPhase::Reduce, 0, 0);
        assert!(st.abort_attempt(TaskPhase::Reduce, 0, a));
        assert!(!st.abort_attempt(TaskPhase::Reduce, 0, a)); // idempotent
        assert_eq!(st.reduces[0].failures, 0);
        // The relaunch opens a fresh attempt and can still win.
        let b = st.begin(TaskPhase::Reduce, 0, 1);
        assert_eq!(st.complete(TaskPhase::Reduce, 0, 1, b), Committed::Won);
    }

    // ---- end-to-end over the channel transport -----------------------

    fn histogram_job(
        n: u64,
        modulus: u64,
    ) -> (
        Vec<((), u64)>,
        Arc<FnMapTask<impl Fn((), u64, &mut Emitter<u64, u64>, &Counters)>>,
        Arc<FnReduceTask<impl Fn(&u64, ValuesIter<'_, u64>, &mut Emitter<u64, u64>, &Counters)>>,
    ) {
        let input: Vec<((), u64)> = (0..n).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            move |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(v % modulus, 1);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        (input, mapper, reducer)
    }

    fn grouping() -> GroupFn<u64> {
        Arc::new(|a: &u64, b: &u64| a == b)
    }

    fn part() -> Arc<HashPartitioner<u64>> {
        Arc::new(HashPartitioner::new(|k: &u64| *k))
    }

    #[test]
    fn dist_matches_serial_barrier_and_push() {
        let (input, mapper, reducer) = histogram_job(600, 7);
        let cfg = JobConfig::named("dist-hist").with_tasks(6, 3);
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            part(),
            grouping(),
            reducer.clone(),
        );
        for push in [PushMode::Barrier, PushMode::Push] {
            let dist = DistScheduler::new(DistConfig::executors(4).with_push(push));
            let got = dist.run(
                &cfg,
                input.clone(),
                mapper.clone(),
                part(),
                grouping(),
                reducer.clone(),
            );
            assert_eq!(serial.outputs, got.outputs);
            assert_eq!(got.outcome, JobOutcome::Ok);
            assert_eq!(
                serial.counters.get(names::REDUCE_INPUT_RECORDS),
                got.counters.get(names::REDUCE_INPUT_RECORDS),
            );
            assert_eq!(
                serial.counters.get(names::MAP_OUTPUT_RECORDS),
                got.counters.get(names::MAP_OUTPUT_RECORDS),
            );
        }
    }

    #[test]
    fn killed_executor_resubmits_and_output_is_identical() {
        let (input, mapper, reducer) = histogram_job(400, 5);
        let cfg = JobConfig::named("dist-kill").with_tasks(6, 2);
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            part(),
            grouping(),
            reducer.clone(),
        );
        let dist = DistScheduler::new(
            DistConfig::executors(2).with_kill(KillPlan { executor: 1, after_map_tasks: 1 }),
        );
        let got = dist.run(&cfg, input, mapper, part(), grouping(), reducer);
        assert_eq!(serial.outputs, got.outputs);
        assert_eq!(got.outcome, JobOutcome::Ok);
        assert!(got.counters.get(names::EXECUTORS_LOST) >= 1);
        assert!(got.counters.get(names::TASK_RETRIES) >= 1);
        assert_eq!(
            serial.counters.get(names::REDUCE_INPUT_RECORDS),
            got.counters.get(names::REDUCE_INPUT_RECORDS),
            "no runs may be lost across the resubmission"
        );
    }

    #[test]
    fn dropped_fetch_frames_are_retried_from_the_registry() {
        let (input, mapper, reducer) = histogram_job(500, 9);
        let cfg = JobConfig::named("dist-torn").with_tasks(5, 3);
        let serial = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            part(),
            grouping(),
            reducer.clone(),
        );
        let dist = DistScheduler::new(DistConfig::executors(4).with_fetch_drops(2));
        let got = dist.run(&cfg, input, mapper, part(), grouping(), reducer);
        assert_eq!(serial.outputs, got.outputs);
        assert_eq!(got.outcome, JobOutcome::Ok);
        assert_eq!(got.counters.get(names::TASKS_FAILED), 0);
        assert_eq!(
            serial.counters.get(names::REDUCE_INPUT_RECORDS),
            got.counters.get(names::REDUCE_INPUT_RECORDS),
        );
    }
}
