//! Typed message transport between the scheduler and its executors.
//!
//! The distributed control plane ([`super::dist`]) never shares mutable
//! state with its workers: every interaction is a typed message sent over
//! a *link* obtained from a [`Transport`]. The only backend today is
//! [`ChannelTransport`] (std `mpsc` channels inside one process), but the
//! trait boundary is the seam where a socket backend drops in later — the
//! scheduler and executor loops are written against [`TxLink`]/[`RxLink`]
//! and never see the channel types.
//!
//! Links come in two classes:
//!
//! - [`LinkClass::Control`] — scheduler↔executor task protocol
//!   (launch/complete/fail/ping). Control frames are never dropped by the
//!   fault hooks; losing them would wedge the state machine rather than
//!   exercise a recovery path.
//! - [`LinkClass::Data`] — the shuffle plane (fetch requests and run
//!   replies). [`TransportFaults::drop_data_sends`] silently discards the
//!   first N data-class frames, which is how `tests/prop_exec.rs` forces a
//!   reduce task to time out mid-fetch and retry from the registry.
//!
//! A send can fail with [`LinkClosed`] when the peer is gone (its receiver
//! was dropped). The scheduler uses exactly this signal — a failed
//! `Ping` — to detect a dead executor and resubmit its tasks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Which plane a link belongs to; see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Scheduler↔executor task protocol; never fault-dropped.
    Control,
    /// Shuffle fetch requests/replies; subject to [`TransportFaults`].
    Data,
}

/// The peer's end of a link is gone; the message was not delivered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkClosed;

impl std::fmt::Display for LinkClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "transport link closed")
    }
}

impl std::error::Error for LinkClosed {}

/// Sending half of a typed link. Cheap to clone; clones share the
/// underlying connection.
pub struct TxLink<M> {
    send: Arc<dyn Fn(M) -> Result<(), LinkClosed> + Send + Sync>,
}

impl<M> Clone for TxLink<M> {
    fn clone(&self) -> Self {
        TxLink { send: Arc::clone(&self.send) }
    }
}

impl<M> TxLink<M> {
    /// Deliver one frame, or report the peer gone.
    pub fn send(&self, msg: M) -> Result<(), LinkClosed> {
        (self.send)(msg)
    }
}

/// Backend hook behind [`RxLink`]; one impl per transport backend.
pub trait LinkReceiver<M>: Send {
    /// Block until a frame arrives or the sending side is fully dropped.
    fn recv(&self) -> Result<M, LinkClosed>;
    /// Wait up to `timeout`; `Ok(None)` means no frame yet (link still up).
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<M>, LinkClosed>;
}

/// Receiving half of a typed link.
pub struct RxLink<M> {
    inner: Box<dyn LinkReceiver<M>>,
}

impl<M> RxLink<M> {
    /// Block until a frame arrives or every sender is gone.
    pub fn recv(&self) -> Result<M, LinkClosed> {
        self.inner.recv()
    }

    /// Wait up to `timeout` for a frame; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Option<M>, LinkClosed> {
        self.inner.recv_timeout(timeout)
    }
}

/// Factory for typed links. Not object-safe (the link method is generic
/// over the message type), so the control plane is generic over `T:
/// Transport` rather than holding a `dyn Transport`.
pub trait Transport: Send + Sync {
    /// Open a fresh one-directional link carrying messages of type `M`.
    fn link<M: Send + 'static>(&self, class: LinkClass) -> (TxLink<M>, RxLink<M>);
}

/// Deterministic fault hooks applied by a transport.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportFaults {
    /// Silently discard the first N [`LinkClass::Data`] sends across the
    /// whole transport (the send still returns `Ok` — the frame is "lost
    /// in flight", exactly like a dropped packet).
    pub drop_data_sends: u32,
}

/// In-process transport backed by std `mpsc` channels. Clones share the
/// fault budget, so the scheduler and every executor see one global
/// drop counter.
#[derive(Clone)]
pub struct ChannelTransport {
    drops_left: Arc<AtomicU64>,
}

impl ChannelTransport {
    pub fn new() -> Self {
        Self::with_faults(TransportFaults::default())
    }

    pub fn with_faults(faults: TransportFaults) -> Self {
        ChannelTransport { drops_left: Arc::new(AtomicU64::new(u64::from(faults.drop_data_sends))) }
    }
}

impl Default for ChannelTransport {
    fn default() -> Self {
        Self::new()
    }
}

/// Consume one drop token if any remain; `true` means "lose this frame".
fn take_drop(budget: &AtomicU64) -> bool {
    let mut cur = budget.load(Ordering::Relaxed);
    while cur > 0 {
        match budget.compare_exchange_weak(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(seen) => cur = seen,
        }
    }
    false
}

struct ChannelReceiver<M> {
    rx: mpsc::Receiver<M>,
}

impl<M: Send> LinkReceiver<M> for ChannelReceiver<M> {
    fn recv(&self) -> Result<M, LinkClosed> {
        self.rx.recv().map_err(|_| LinkClosed)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<M>, LinkClosed> {
        match self.rx.recv_timeout(timeout) {
            Ok(m) => Ok(Some(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(LinkClosed),
        }
    }
}

impl Transport for ChannelTransport {
    fn link<M: Send + 'static>(&self, class: LinkClass) -> (TxLink<M>, RxLink<M>) {
        let (tx, rx) = mpsc::channel::<M>();
        // `mpsc::Sender` is only `Sync` on newer toolchains; the mutex
        // keeps the closure `Send + Sync` everywhere without cloning
        // senders per call site.
        let tx = Mutex::new(tx);
        let drops = match class {
            LinkClass::Data => Some(Arc::clone(&self.drops_left)),
            LinkClass::Control => None,
        };
        let send = Arc::new(move |msg: M| {
            if let Some(budget) = &drops {
                if take_drop(budget) {
                    // Frame lost in flight: the sender cannot tell.
                    return Ok(());
                }
            }
            tx.lock().expect("transport sender poisoned").send(msg).map_err(|_| LinkClosed)
        });
        (TxLink { send }, RxLink { inner: Box::new(ChannelReceiver { rx }) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_clone_share_one_link() {
        let t = ChannelTransport::new();
        let (tx, rx) = t.link::<u32>(LinkClass::Control);
        let tx2 = tx.clone();
        tx.send(1).unwrap();
        tx2.send(2).unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
    }

    #[test]
    fn closed_link_reports_on_send_and_recv() {
        let t = ChannelTransport::new();
        let (tx, rx) = t.link::<u32>(LinkClass::Control);
        drop(rx);
        assert_eq!(tx.send(7), Err(LinkClosed));

        let (tx, rx) = t.link::<u32>(LinkClass::Control);
        drop(tx);
        assert_eq!(rx.recv(), Err(LinkClosed));
    }

    #[test]
    fn recv_timeout_distinguishes_empty_from_closed() {
        let t = ChannelTransport::new();
        let (tx, rx) = t.link::<u32>(LinkClass::Control);
        assert_eq!(rx.recv_timeout(Duration::from_millis(1)).unwrap(), None);
        tx.send(9).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(1)).unwrap(), Some(9));
        drop(tx);
        assert_eq!(rx.recv_timeout(Duration::from_millis(1)), Err(LinkClosed));
    }

    #[test]
    fn fault_budget_drops_first_data_sends_only() {
        let t = ChannelTransport::with_faults(TransportFaults { drop_data_sends: 2 });
        let (ctl_tx, ctl_rx) = t.link::<u32>(LinkClass::Control);
        let (data_tx, data_rx) = t.link::<u32>(LinkClass::Data);

        // Control frames are never dropped.
        ctl_tx.send(1).unwrap();
        assert_eq!(ctl_rx.recv().unwrap(), 1);

        // First two data frames vanish silently; the third arrives.
        data_tx.send(10).unwrap();
        data_tx.send(11).unwrap();
        data_tx.send(12).unwrap();
        assert_eq!(data_rx.recv().unwrap(), 12);
        assert_eq!(data_rx.recv_timeout(Duration::from_millis(1)).unwrap(), None);

        // The budget is shared across links of the same transport.
        let (d2_tx, d2_rx) = t.link::<u32>(LinkClass::Data);
        d2_tx.send(20).unwrap();
        assert_eq!(d2_rx.recv().unwrap(), 20);
    }
}
