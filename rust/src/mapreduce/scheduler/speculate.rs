//! Speculative task-attempt execution on a shared slot pool.
//!
//! One *task* may run as several *attempts*: the primary attempt, plus at
//! most one speculative clone launched by the straggler detector.  All
//! attempts of all concurrently running jobs contend for the same pool
//! slots; first-completion-wins is decided by
//! [`OnceSlots::try_put`](crate::util::threadpool::OnceSlots::try_put) —
//! exactly one attempt's EMPTY→WRITING transition succeeds, and the
//! loser's result is dropped without ever becoming observable.  Because
//! attempts execute a pure function of the task input, speculation can
//! change *when* a result is produced but never *what* it is.
//!
//! The straggler rule mirrors Hadoop's: a running task whose elapsed time
//! exceeds `slowdown ×` the running median of completed task durations
//! (and at least `min_secs`) is cloned — but only onto an *idle* slot, so
//! speculation never delays a primary attempt that is still queued.
//!
//! **Bounded retry** ([`WaveOptions::max_retries`]): a panicked attempt is
//! caught and — while the task is undecided and its cumulative panic
//! count is within budget — queued for resubmission from the retained
//! input; the wave driver relaunches it as a fresh primary attempt.  Only
//! when the budget is exhausted does the task become *failed*: with
//! [`WaveOptions::allow_failure`] the wave completes and reports the
//! failed indices (the dead-letter path); without it the wave panics like
//! `run_owned` — the default fail-fast contract of [`run_tasks`].
//! Retries compose with speculation: a clone that wins while a retry is
//! queued decides the task, and the stale retry is discarded at dispatch.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::mapreduce::counters::{names, Counters};
use crate::mapreduce::trace::{JobTraceCtx, TraceEvent, TracePhase};
use crate::metrics::registry::WaveMetrics;
use crate::util::threadpool::{OnceSlots, ThreadPool};

/// How the straggler detector assigns a speculative clone to a lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpecMode {
    /// Hadoop's heuristic: clone a straggler only when a slot is idle
    /// *right now*; a saturated pool launches nothing.
    #[default]
    RunningMedian,
    /// Trace-informed: project each lane's idle gap from the live
    /// attempt timeline (the board's start stamps plus the running
    /// median) and pre-queue the clone onto the lane with the earliest
    /// projected idle — but only when `gap + median` still beats the
    /// straggler's own projected finish, so a clone is never launched
    /// that the timeline says cannot win.
    IdleGap,
}

/// Straggler-detection knobs (Hadoop's speculative-execution analogue).
#[derive(Debug, Clone)]
pub struct SpecPolicy {
    /// A running task becomes a straggler when its elapsed time exceeds
    /// `slowdown ×` the running median of completed task durations.
    pub slowdown: f64,
    /// Never speculate before a task has run at least this long (Hadoop
    /// waits 60 s; our in-process tasks take milliseconds, so the default
    /// is small).
    pub min_secs: f64,
    /// How often the job driver re-scans running tasks for stragglers.
    pub poll: Duration,
    /// How a detected straggler's clone is assigned to a lane.
    pub mode: SpecMode,
}

impl Default for SpecPolicy {
    fn default() -> Self {
        Self {
            slowdown: 1.5,
            min_secs: 0.02,
            poll: Duration::from_millis(1),
            mode: SpecMode::RunningMedian,
        }
    }
}

impl SpecPolicy {
    /// Switch the lane-assignment heuristic.
    pub fn with_mode(mut self, mode: SpecMode) -> Self {
        self.mode = mode;
        self
    }
}

struct BoardState {
    /// Tasks that are settled: a winner is stored, or the task failed
    /// permanently.
    settled: usize,
    /// Winning-attempt durations, in completion order (median source).
    durations: Vec<f64>,
    /// Undecided tasks whose last attempt panicked within the retry
    /// budget, waiting for the driver to resubmit them.
    pending_retry: Vec<usize>,
    /// Tasks whose every attempt panicked (budget exhausted).
    failed: Vec<usize>,
}

/// Per-wave bookkeeping shared between the job driver and its attempts.
struct Board {
    epoch: Instant,
    /// Micros since `epoch` (+1 so 0 means "still queued") when the
    /// primary attempt started executing.
    started_us: Vec<AtomicU64>,
    /// A speculative clone has been launched for this task.
    cloned: Vec<AtomicBool>,
    /// The task's outcome is decided (winner stored, or failed for good).
    decided: Vec<AtomicBool>,
    /// Cumulative panicked attempts per task (retry budget accounting).
    fail_counts: Vec<AtomicU32>,
    /// Next attempt ordinal per task — every submission (primary, retry,
    /// speculative clone) consumes one, so the trace's attempt numbers
    /// are dense and unique per task.
    attempt_seq: Vec<AtomicU32>,
    /// Panicked attempts beyond this count fail the task.
    max_retries: u32,
    state: Mutex<BoardState>,
    cv: Condvar,
}

impl Board {
    fn new(n: usize, max_retries: u32) -> Self {
        Self {
            epoch: Instant::now(),
            started_us: (0..n).map(|_| AtomicU64::new(0)).collect(),
            cloned: (0..n).map(|_| AtomicBool::new(false)).collect(),
            decided: (0..n).map(|_| AtomicBool::new(false)).collect(),
            fail_counts: (0..n).map(|_| AtomicU32::new(0)).collect(),
            attempt_seq: (0..n).map(|_| AtomicU32::new(0)).collect(),
            max_retries,
            state: Mutex::new(BoardState {
                settled: 0,
                durations: Vec::new(),
                pending_retry: Vec::new(),
                failed: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }
}

/// Fault-handling knobs for one wave (see [`run_tasks_ft`]).
pub(crate) struct WaveOptions<T> {
    /// Straggler-cloning policy; `None` disables speculation.
    pub spec: Option<SpecPolicy>,
    /// Panicked-attempt budget per task before the task fails.
    pub max_retries: u32,
    /// `true`: failed tasks are reported in [`WaveOutcome::failed`] and
    /// the wave completes (dead-letter mode).  `false`: any failed task
    /// panics the wave (`run_owned`'s fail-fast contract).
    pub allow_failure: bool,
    /// Invoked once per task, on the winning attempt's thread, right
    /// after the win is decided and before the result is published —
    /// the checkpoint-commit hook.  A panicking callback is swallowed
    /// (checkpointing is best-effort and must not fail a healthy wave).
    pub on_win: Option<Arc<dyn Fn(usize, &T) + Send + Sync>>,
    /// Trace context for this wave's attempt-lifecycle events: the job
    /// context plus which phase the wave executes.  `None` traces
    /// nothing.
    pub trace: Option<(JobTraceCtx, TracePhase)>,
    /// Live-metrics handles for this wave's attempt lifecycle (queued /
    /// running gauges, retried counter).  `None` records nothing.
    pub metrics: Option<WaveMetrics>,
}

impl<T> Default for WaveOptions<T> {
    fn default() -> Self {
        Self {
            spec: None,
            max_retries: 0,
            allow_failure: false,
            on_win: None,
            trace: None,
            metrics: None,
        }
    }
}

/// Why an attempt is being submitted — determines which trace breadcrumb
/// precedes its `AttemptScheduled` event.
#[derive(Clone, Copy, PartialEq, Eq)]
enum AttemptKind {
    Primary,
    Retry,
    Clone,
}

/// One wave's results under fault handling.
pub(crate) struct WaveOutcome<T> {
    /// Per-task results in task order; `None` marks a failed task (only
    /// possible with [`WaveOptions::allow_failure`]).
    pub results: Vec<Option<T>>,
    /// Indices of failed tasks, in settlement order.
    pub failed: Vec<usize>,
    /// Retry attempts actually resubmitted.
    pub retries: u64,
}

/// Run one wave of tasks on `pool`, optionally cloning stragglers onto
/// idle slots.  Returns results in task order.  Panics if any attempt
/// panicked (matching `run_owned`'s contract).
///
/// The task body receives `(task, attempt, input)`: `attempt` is the
/// dense per-task attempt ordinal (0 = primary; retries and speculative
/// clones consume the next one) — the same ordinal the trace stamps on
/// the attempt's lifecycle events, so task bodies can emit their own
/// events under the matching identity.
///
/// Each attempt receives its input behind an `Arc`.  Without speculation
/// the attempt holds the *only* reference, so the task body can
/// `Arc::try_unwrap` and consume the input in place — no copy, and each
/// input is freed as its task finishes, exactly like the serial path.
/// With speculation on, a second reference per task is retained so a
/// straggler clone can re-run from the same input; only then does the
/// task body fall back to a deep clone.
pub(crate) fn run_tasks<I, T, F>(
    pool: &ThreadPool,
    items: Vec<I>,
    f: Arc<F>,
    spec: Option<SpecPolicy>,
    counters: &Arc<Counters>,
) -> Vec<T>
where
    I: Send + Sync + 'static,
    T: Send + 'static,
    F: Fn(usize, u32, Arc<I>) -> T + Send + Sync + 'static,
{
    run_tasks_ft(
        pool,
        items,
        f,
        WaveOptions {
            spec,
            ..WaveOptions::default()
        },
        counters,
    )
    .results
    .into_iter()
    .map(|t| t.expect("fail-fast wave cannot yield failed tasks"))
    .collect()
}

/// As [`run_tasks`], with the fault-handling knobs exposed: bounded
/// per-task retry, optional failure tolerance, and a winning-attempt
/// commit hook.  See [`WaveOptions`] / [`WaveOutcome`].
pub(crate) fn run_tasks_ft<I, T, F>(
    pool: &ThreadPool,
    items: Vec<I>,
    f: Arc<F>,
    opts: WaveOptions<T>,
    counters: &Arc<Counters>,
) -> WaveOutcome<T>
where
    I: Send + Sync + 'static,
    T: Send + 'static,
    F: Fn(usize, u32, Arc<I>) -> T + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return WaveOutcome {
            results: Vec::new(),
            failed: Vec::new(),
            retries: 0,
        };
    }
    let attempt_inputs: Vec<Arc<I>> = items.into_iter().map(Arc::new).collect();
    // Without speculation or retries every attempt holds the *only*
    // input reference and can consume it in place; either fault knob
    // needs a second reference to re-run from.
    let retained: Option<Vec<Arc<I>>> =
        (opts.spec.is_some() || opts.max_retries > 0).then(|| attempt_inputs.clone());
    let results = Arc::new(OnceSlots::<T>::empty(n));
    let board = Arc::new(Board::new(n, opts.max_retries));
    for (i, input) in attempt_inputs.into_iter().enumerate() {
        submit_attempt(
            pool,
            i,
            AttemptKind::Primary,
            input,
            Arc::clone(&f),
            Arc::clone(&results),
            Arc::clone(&board),
            Arc::clone(counters),
            opts.on_win.clone(),
            opts.trace.clone(),
            opts.metrics.clone(),
        );
    }

    let mut retries_launched = 0u64;
    let mut st = board.state.lock().unwrap();
    loop {
        // Drain retry requests before anything else: a queued retry is a
        // task with no running attempt (unless a clone is still going),
        // so waiting on it would deadlock a spec-less wave.
        while let Some(i) = st.pending_retry.pop() {
            drop(st);
            if !board.decided[i].load(Ordering::Acquire) {
                counters.inc(names::TASK_RETRIES);
                retries_launched += 1;
                if let Some(m) = &opts.metrics {
                    m.on_retry();
                }
                let inputs = retained
                    .as_ref()
                    .expect("inputs retained when retries are budgeted");
                submit_attempt(
                    pool,
                    i,
                    AttemptKind::Retry,
                    Arc::clone(&inputs[i]),
                    Arc::clone(&f),
                    Arc::clone(&results),
                    Arc::clone(&board),
                    Arc::clone(counters),
                    opts.on_win.clone(),
                    opts.trace.clone(),
                    opts.metrics.clone(),
                );
            }
            st = board.state.lock().unwrap();
        }
        if st.settled >= n {
            break;
        }
        match &opts.spec {
            None => st = board.cv.wait(st).unwrap(),
            Some(policy) => {
                let (guard, _) = board.cv.wait_timeout(st, policy.poll).unwrap();
                st = guard;
                if st.settled >= n || !st.pending_retry.is_empty() {
                    continue;
                }
                if st.durations.is_empty() {
                    continue; // no completed task yet: no median baseline
                }
                let mut ds = st.durations.clone();
                drop(st);
                ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let median = ds[ds.len() / 2];
                let threshold = policy.min_secs.max(policy.slowdown * median);
                let now_us = board.epoch.elapsed().as_micros() as u64 + 1;
                for i in 0..n {
                    if board.decided[i].load(Ordering::Acquire)
                        || board.cloned[i].load(Ordering::Acquire)
                    {
                        continue;
                    }
                    let s = board.started_us[i].load(Ordering::Acquire);
                    if s == 0 {
                        continue; // still queued: a clone would not start sooner
                    }
                    let elapsed = now_us.saturating_sub(s) as f64 / 1e6;
                    if elapsed < threshold {
                        continue;
                    }
                    match policy.mode {
                        SpecMode::RunningMedian => {
                            if pool.in_flight() >= pool.size() {
                                break; // no idle slot: never delay primary attempts
                            }
                        }
                        SpecMode::IdleGap => {
                            // Earliest projected idle gap across lanes:
                            // zero when a slot is idle now, otherwise
                            // the soonest median-projected completion
                            // among the other running attempts on the
                            // live board timeline.
                            let gap = if pool.in_flight() < pool.size() {
                                0.0
                            } else {
                                let mut earliest = f64::INFINITY;
                                for j in 0..n {
                                    if j == i || board.decided[j].load(Ordering::Acquire) {
                                        continue;
                                    }
                                    let sj = board.started_us[j].load(Ordering::Acquire);
                                    if sj == 0 {
                                        continue;
                                    }
                                    let ej = now_us.saturating_sub(sj) as f64 / 1e6;
                                    earliest = earliest.min((median - ej).max(0.0));
                                }
                                earliest
                            };
                            // A clone queued onto that lane starts after
                            // `gap` and projects one median of work; skip
                            // it when the straggler's own elapsed time
                            // says the clone cannot finish first.
                            if gap + median >= elapsed {
                                continue;
                            }
                        }
                    }
                    if board.cloned[i].swap(true, Ordering::AcqRel) {
                        continue;
                    }
                    counters.inc(names::SPECULATIVE_LAUNCHED);
                    let inputs = retained.as_ref().expect("inputs retained when speculating");
                    submit_attempt(
                        pool,
                        i,
                        AttemptKind::Clone,
                        Arc::clone(&inputs[i]),
                        Arc::clone(&f),
                        Arc::clone(&results),
                        Arc::clone(&board),
                        Arc::clone(counters),
                        opts.on_win.clone(),
                        opts.trace.clone(),
                        opts.metrics.clone(),
                    );
                }
                st = board.state.lock().unwrap();
            }
        }
    }
    let failed = std::mem::take(&mut st.failed);
    drop(st);
    if !opts.allow_failure {
        assert!(
            failed.is_empty(),
            "{} task attempt(s) panicked",
            failed.len()
        );
    }
    let mut is_failed = vec![false; n];
    for &i in &failed {
        is_failed[i] = true;
    }
    // Losing attempts may still be running; `take` transitions each slot
    // FULL→TAKEN, after which a late loser's publish simply never happens
    // (the win was already decided by the `decided` flag).
    let outputs = (0..n)
        .map(|i| (!is_failed[i]).then(|| results.take(i)))
        .collect();
    WaveOutcome {
        results: outputs,
        failed,
        retries: retries_launched,
    }
}

/// Best-effort extraction of a panic payload's message.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "panic".to_string())
}

#[allow(clippy::too_many_arguments)]
fn submit_attempt<I, T, F>(
    pool: &ThreadPool,
    i: usize,
    kind: AttemptKind,
    input: Arc<I>,
    f: Arc<F>,
    results: Arc<OnceSlots<T>>,
    board: Arc<Board>,
    counters: Arc<Counters>,
    on_win: Option<Arc<dyn Fn(usize, &T) + Send + Sync>>,
    trace: Option<(JobTraceCtx, TracePhase)>,
    metrics: Option<WaveMetrics>,
) where
    I: Send + Sync + 'static,
    T: Send + 'static,
    F: Fn(usize, u32, Arc<I>) -> T + Send + Sync + 'static,
{
    let attempt = board.attempt_seq[i].fetch_add(1, Ordering::Relaxed);
    let tctx = trace.map(|(j, ph)| j.task(ph, i, attempt));
    if let Some(t) = &tctx {
        match kind {
            AttemptKind::Retry => t.emit(TraceEvent::TaskRetried),
            AttemptKind::Clone => t.emit(TraceEvent::SpeculativeCloned),
            AttemptKind::Primary => {}
        }
        t.emit(TraceEvent::AttemptScheduled);
    }
    if let Some(m) = &metrics {
        m.on_submit();
    }
    let speculative = kind == AttemptKind::Clone;
    pool.execute(move || {
        if let Some(m) = &metrics {
            m.on_start();
        }
        if board.decided[i].load(Ordering::Acquire) {
            if let Some(m) = &metrics {
                m.on_exit();
            }
            return; // winner finished while this attempt was queued
        }
        if !speculative {
            board.started_us[i].store(
                board.epoch.elapsed().as_micros() as u64 + 1,
                Ordering::Release,
            );
        }
        if let Some(t) = &tctx {
            t.emit(TraceEvent::AttemptStarted);
        }
        let t0 = Instant::now();
        match catch_unwind(AssertUnwindSafe(|| f(i, attempt, input))) {
            Ok(t) => {
                if let Some(tc) = &tctx {
                    tc.emit(TraceEvent::AttemptFinished);
                }
                // `decided` is the single win arbiter: exactly one
                // attempt's false→true transition succeeds, so the slot
                // write below is exclusive and losers drop their result
                // right here.
                if !board.decided[i].swap(true, Ordering::AcqRel) {
                    if let Some(tc) = &tctx {
                        tc.emit(TraceEvent::AttemptWon);
                    }
                    if let Some(cb) = &on_win {
                        let _ = catch_unwind(AssertUnwindSafe(|| cb(i, &t)));
                    }
                    let won = results.try_put(i, t);
                    debug_assert!(won, "decided attempt must own the slot");
                    if speculative {
                        counters.inc(names::SPECULATIVE_WON);
                    }
                    let mut st = board.state.lock().unwrap();
                    st.settled += 1;
                    st.durations.push(t0.elapsed().as_secs_f64());
                    board.cv.notify_all();
                } else if let Some(tc) = &tctx {
                    tc.emit(TraceEvent::AttemptLost);
                }
            }
            Err(p) => {
                if let Some(tc) = &tctx {
                    tc.emit(TraceEvent::AttemptPanicked {
                        message: panic_message(p.as_ref()),
                    });
                }
                // a panicked attempt consumes one unit of retry budget;
                // within budget (and while undecided) the task is queued
                // for resubmission, beyond it the task fails for good
                let fails = board.fail_counts[i].fetch_add(1, Ordering::AcqRel) + 1;
                if !board.decided[i].load(Ordering::Acquire) && fails <= board.max_retries {
                    let mut st = board.state.lock().unwrap();
                    st.pending_retry.push(i);
                    board.cv.notify_all();
                } else {
                    let first = !board.decided[i].swap(true, Ordering::AcqRel);
                    let mut st = board.state.lock().unwrap();
                    if first {
                        counters.inc(names::TASKS_FAILED);
                        st.failed.push(i);
                        st.settled += 1;
                    }
                    board.cv.notify_all();
                }
            }
        }
        if let Some(m) = &metrics {
            m.on_exit();
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_wait(d: Duration) {
        let t0 = Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    #[test]
    fn all_tasks_complete_without_speculation() {
        let pool = ThreadPool::new(3);
        let counters = Arc::new(Counters::new());
        let out = run_tasks(
            &pool,
            (0..20u64).collect::<Vec<_>>(),
            Arc::new(|_i, _a, v: Arc<u64>| *v * 2),
            None,
            &counters,
        );
        assert_eq!(out, (0..20u64).map(|v| v * 2).collect::<Vec<_>>());
        assert_eq!(counters.get(names::SPECULATIVE_LAUNCHED), 0);
    }

    #[test]
    fn without_speculation_attempts_own_their_input() {
        // no retained references ⇒ every attempt can consume its input in
        // place, like the serial path moves splits into tasks
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let out = run_tasks(
            &pool,
            vec![vec![1u64, 2], vec![3, 4]],
            Arc::new(|_i, _a, v: Arc<Vec<u64>>| {
                let owned = Arc::try_unwrap(v).expect("attempt must be sole owner");
                owned.into_iter().sum::<u64>()
            }),
            None,
            &counters,
        );
        assert_eq!(out, vec![3, 7]);
    }

    #[test]
    fn straggler_gets_cloned_and_output_is_unchanged() {
        let pool = ThreadPool::new(4);
        let counters = Arc::new(Counters::new());
        let items: Vec<u64> = (0..8).collect();
        let f = Arc::new(|_i: usize, _a: u32, v: Arc<u64>| {
            if *v == 7 {
                busy_wait(Duration::from_millis(150));
            } else {
                busy_wait(Duration::from_millis(2));
            }
            *v + 100
        });
        let out = run_tasks(&pool, items, f, Some(SpecPolicy::default()), &counters);
        assert_eq!(out, (0..8u64).map(|v| v + 100).collect::<Vec<_>>());
        assert!(
            counters.get(names::SPECULATIVE_LAUNCHED) >= 1,
            "the 150ms straggler should have been cloned"
        );
        // whether the clone wins is timing-dependent; only the invariant
        // won <= launched is guaranteed
        assert!(
            counters.get(names::SPECULATIVE_WON) <= counters.get(names::SPECULATIVE_LAUNCHED)
        );
    }

    #[test]
    fn idle_gap_mode_clones_stragglers_and_output_is_unchanged() {
        let pool = ThreadPool::new(4);
        let counters = Arc::new(Counters::new());
        let items: Vec<u64> = (0..8).collect();
        let f = Arc::new(|_i: usize, _a: u32, v: Arc<u64>| {
            if *v == 7 {
                busy_wait(Duration::from_millis(150));
            } else {
                busy_wait(Duration::from_millis(2));
            }
            *v + 100
        });
        let policy = SpecPolicy::default().with_mode(SpecMode::IdleGap);
        let out = run_tasks(&pool, items, f, Some(policy), &counters);
        assert_eq!(out, (0..8u64).map(|v| v + 100).collect::<Vec<_>>());
        assert!(
            counters.get(names::SPECULATIVE_LAUNCHED) >= 1,
            "the 150ms straggler should have been cloned onto a projected-idle lane"
        );
        assert!(
            counters.get(names::SPECULATIVE_WON) <= counters.get(names::SPECULATIVE_LAUNCHED)
        );
    }

    #[test]
    fn wave_metrics_quiesce_after_the_wave() {
        use crate::metrics::registry::MetricsSpec;
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let spec = MetricsSpec::new();
        let jm = spec.job_metrics("wave");
        let out = run_tasks_ft(
            &pool,
            (0..12u64).collect::<Vec<_>>(),
            Arc::new(|_i, _a, v: Arc<u64>| *v * 2),
            WaveOptions {
                metrics: Some(jm.wave()),
                ..WaveOptions::default()
            },
            &counters,
        );
        assert_eq!(out.results.len(), 12);
        pool.join();
        assert_eq!(jm.queued.get(), 0, "queued gauge must balance to zero");
        assert_eq!(jm.running.get(), 0, "running gauge must balance to zero");
        assert_eq!(jm.retried.get(), 0);
    }

    #[test]
    #[should_panic(expected = "task attempt(s) panicked")]
    fn attempt_panic_fails_the_wave() {
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let _ = run_tasks(
            &pool,
            vec![0u64, 1],
            Arc::new(|_i, _a, v: Arc<u64>| {
                if *v == 1 {
                    panic!("boom");
                }
                *v
            }),
            None,
            &counters,
        );
    }

    #[test]
    fn empty_wave_is_fine() {
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let out: Vec<u64> = run_tasks(
            &pool,
            Vec::new(),
            Arc::new(|_i, _a, v: Arc<u64>| *v),
            None,
            &counters,
        );
        assert!(out.is_empty());
    }

    /// A first-attempt panic within the retry budget is invisible to the
    /// caller: the resubmitted attempt produces the same result the
    /// clean run would have.
    #[test]
    fn retry_recovers_a_panicked_attempt() {
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let attempts = Arc::new(AtomicU32::new(0));
        let a = Arc::clone(&attempts);
        let out = run_tasks_ft(
            &pool,
            (0..6u64).collect::<Vec<_>>(),
            Arc::new(move |_i, _a, v: Arc<u64>| {
                if *v == 3 && a.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("injected");
                }
                *v * 10
            }),
            WaveOptions {
                max_retries: 2,
                ..WaveOptions::default()
            },
            &counters,
        );
        let vals: Vec<u64> = out.results.into_iter().map(Option::unwrap).collect();
        assert_eq!(vals, (0..6u64).map(|v| v * 10).collect::<Vec<_>>());
        assert!(out.failed.is_empty());
        assert_eq!(out.retries, 1);
        assert_eq!(counters.get(names::TASK_RETRIES), 1);
        assert_eq!(counters.get(names::TASKS_FAILED), 0);
    }

    /// Exhausting the budget still fails the wave loudly by default.
    #[test]
    #[should_panic(expected = "task attempt(s) panicked")]
    fn exhausted_retries_fail_fast_by_default() {
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let _ = run_tasks_ft(
            &pool,
            vec![0u64, 1],
            Arc::new(|_i, _a, v: Arc<u64>| {
                if *v == 1 {
                    panic!("always");
                }
                *v
            }),
            WaveOptions {
                max_retries: 2,
                ..WaveOptions::default()
            },
            &counters,
        );
    }

    /// With `allow_failure` the wave completes and reports the failed
    /// index instead of panicking — the dead-letter substrate.
    #[test]
    fn allow_failure_reports_failed_tasks() {
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let out = run_tasks_ft(
            &pool,
            (0..4u64).collect::<Vec<_>>(),
            Arc::new(|_i, _a, v: Arc<u64>| {
                if *v == 2 {
                    panic!("always");
                }
                *v + 1
            }),
            WaveOptions {
                max_retries: 1,
                allow_failure: true,
                ..WaveOptions::default()
            },
            &counters,
        );
        assert_eq!(out.failed, vec![2]);
        assert_eq!(out.results[2], None);
        assert_eq!(out.results[0], Some(1));
        assert_eq!(out.results[3], Some(4));
        assert_eq!(out.retries, 1, "budget of 1 consumed before failing");
        assert_eq!(counters.get(names::TASKS_FAILED), 1);
    }

    /// Retries compose with speculation: the wave stays correct and no
    /// task settles twice (every result slot is filled exactly once).
    #[test]
    fn retry_composes_with_speculation() {
        let pool = ThreadPool::new(4);
        let counters = Arc::new(Counters::new());
        let attempts = Arc::new(AtomicU32::new(0));
        let a = Arc::clone(&attempts);
        let out = run_tasks_ft(
            &pool,
            (0..8u64).collect::<Vec<_>>(),
            Arc::new(move |_i, _a, v: Arc<u64>| {
                if *v == 1 && a.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("injected");
                }
                if *v == 7 {
                    busy_wait(Duration::from_millis(120));
                } else {
                    busy_wait(Duration::from_millis(2));
                }
                *v + 100
            }),
            WaveOptions {
                spec: Some(SpecPolicy::default()),
                max_retries: 2,
                ..WaveOptions::default()
            },
            &counters,
        );
        let vals: Vec<u64> = out.results.into_iter().map(Option::unwrap).collect();
        assert_eq!(vals, (0..8u64).map(|v| v + 100).collect::<Vec<_>>());
        assert_eq!(counters.get(names::TASK_RETRIES), 1);
        assert!(
            counters.get(names::SPECULATIVE_WON) <= counters.get(names::SPECULATIVE_LAUNCHED)
        );
    }

    /// The winning attempt invokes the commit hook exactly once per task.
    #[test]
    fn on_win_fires_once_per_task() {
        let pool = ThreadPool::new(4);
        let counters = Arc::new(Counters::new());
        let fired = Arc::new(Mutex::new(Vec::new()));
        let f2 = Arc::clone(&fired);
        let out = run_tasks_ft(
            &pool,
            (0..10u64).collect::<Vec<_>>(),
            Arc::new(|_i, _a, v: Arc<u64>| *v),
            WaveOptions {
                on_win: Some(Arc::new(move |i, t: &u64| {
                    f2.lock().unwrap().push((i, *t));
                })),
                ..WaveOptions::default()
            },
            &counters,
        );
        assert!(out.failed.is_empty());
        let mut hits = fired.lock().unwrap().clone();
        hits.sort_unstable();
        assert_eq!(hits, (0..10usize).map(|i| (i, i as u64)).collect::<Vec<_>>());
    }
}
