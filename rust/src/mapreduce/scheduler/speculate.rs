//! Speculative task-attempt execution on a shared slot pool.
//!
//! One *task* may run as several *attempts*: the primary attempt, plus at
//! most one speculative clone launched by the straggler detector.  All
//! attempts of all concurrently running jobs contend for the same pool
//! slots; first-completion-wins is decided by
//! [`OnceSlots::try_put`](crate::util::threadpool::OnceSlots::try_put) —
//! exactly one attempt's EMPTY→WRITING transition succeeds, and the
//! loser's result is dropped without ever becoming observable.  Because
//! attempts execute a pure function of the task input, speculation can
//! change *when* a result is produced but never *what* it is.
//!
//! The straggler rule mirrors Hadoop's: a running task whose elapsed time
//! exceeds `slowdown ×` the running median of completed task durations
//! (and at least `min_secs`) is cloned — but only onto an *idle* slot, so
//! speculation never delays a primary attempt that is still queued.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::mapreduce::counters::{names, Counters};
use crate::util::threadpool::{OnceSlots, ThreadPool};

/// Straggler-detection knobs (Hadoop's speculative-execution analogue).
#[derive(Debug, Clone)]
pub struct SpecPolicy {
    /// A running task becomes a straggler when its elapsed time exceeds
    /// `slowdown ×` the running median of completed task durations.
    pub slowdown: f64,
    /// Never speculate before a task has run at least this long (Hadoop
    /// waits 60 s; our in-process tasks take milliseconds, so the default
    /// is small).
    pub min_secs: f64,
    /// How often the job driver re-scans running tasks for stragglers.
    pub poll: Duration,
}

impl Default for SpecPolicy {
    fn default() -> Self {
        Self {
            slowdown: 1.5,
            min_secs: 0.02,
            poll: Duration::from_millis(1),
        }
    }
}

struct BoardState {
    /// Tasks whose winner is decided.
    winners: usize,
    /// Winning-attempt durations, in completion order (median source).
    durations: Vec<f64>,
    panics: usize,
}

/// Per-wave bookkeeping shared between the job driver and its attempts.
struct Board {
    epoch: Instant,
    /// Micros since `epoch` (+1 so 0 means "still queued") when the
    /// primary attempt started executing.
    started_us: Vec<AtomicU64>,
    /// A speculative clone has been launched for this task.
    cloned: Vec<AtomicBool>,
    /// The task's outcome is decided (winner stored, or attempt panicked).
    decided: Vec<AtomicBool>,
    state: Mutex<BoardState>,
    cv: Condvar,
}

impl Board {
    fn new(n: usize) -> Self {
        Self {
            epoch: Instant::now(),
            started_us: (0..n).map(|_| AtomicU64::new(0)).collect(),
            cloned: (0..n).map(|_| AtomicBool::new(false)).collect(),
            decided: (0..n).map(|_| AtomicBool::new(false)).collect(),
            state: Mutex::new(BoardState {
                winners: 0,
                durations: Vec::new(),
                panics: 0,
            }),
            cv: Condvar::new(),
        }
    }
}

/// Run one wave of tasks on `pool`, optionally cloning stragglers onto
/// idle slots.  Returns results in task order.  Panics if any attempt
/// panicked (matching `run_owned`'s contract).
///
/// Each attempt receives its input behind an `Arc`.  Without speculation
/// the attempt holds the *only* reference, so the task body can
/// `Arc::try_unwrap` and consume the input in place — no copy, and each
/// input is freed as its task finishes, exactly like the serial path.
/// With speculation on, a second reference per task is retained so a
/// straggler clone can re-run from the same input; only then does the
/// task body fall back to a deep clone.
pub(crate) fn run_tasks<I, T, F>(
    pool: &ThreadPool,
    items: Vec<I>,
    f: Arc<F>,
    spec: Option<SpecPolicy>,
    counters: &Arc<Counters>,
) -> Vec<T>
where
    I: Send + Sync + 'static,
    T: Send + 'static,
    F: Fn(usize, Arc<I>) -> T + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let attempt_inputs: Vec<Arc<I>> = items.into_iter().map(Arc::new).collect();
    let retained: Option<Vec<Arc<I>>> = spec.as_ref().map(|_| attempt_inputs.clone());
    let results = Arc::new(OnceSlots::<T>::empty(n));
    let board = Arc::new(Board::new(n));
    for (i, input) in attempt_inputs.into_iter().enumerate() {
        submit_attempt(
            pool,
            i,
            false,
            input,
            Arc::clone(&f),
            Arc::clone(&results),
            Arc::clone(&board),
            Arc::clone(counters),
        );
    }

    let mut st = board.state.lock().unwrap();
    loop {
        if st.winners >= n {
            break;
        }
        match &spec {
            None => st = board.cv.wait(st).unwrap(),
            Some(policy) => {
                let (guard, _) = board.cv.wait_timeout(st, policy.poll).unwrap();
                st = guard;
                if st.winners >= n {
                    break;
                }
                if st.durations.is_empty() {
                    continue; // no completed task yet: no median baseline
                }
                let mut ds = st.durations.clone();
                drop(st);
                ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let median = ds[ds.len() / 2];
                let threshold = policy.min_secs.max(policy.slowdown * median);
                let now_us = board.epoch.elapsed().as_micros() as u64 + 1;
                for i in 0..n {
                    if board.decided[i].load(Ordering::Acquire)
                        || board.cloned[i].load(Ordering::Acquire)
                    {
                        continue;
                    }
                    let s = board.started_us[i].load(Ordering::Acquire);
                    if s == 0 {
                        continue; // still queued: a clone would not start sooner
                    }
                    let elapsed = now_us.saturating_sub(s) as f64 / 1e6;
                    if elapsed < threshold {
                        continue;
                    }
                    if pool.in_flight() >= pool.size() {
                        break; // no idle slot: never delay primary attempts
                    }
                    if board.cloned[i].swap(true, Ordering::AcqRel) {
                        continue;
                    }
                    counters.inc(names::SPECULATIVE_LAUNCHED);
                    let inputs = retained.as_ref().expect("inputs retained when speculating");
                    submit_attempt(
                        pool,
                        i,
                        true,
                        Arc::clone(&inputs[i]),
                        Arc::clone(&f),
                        Arc::clone(&results),
                        Arc::clone(&board),
                        Arc::clone(counters),
                    );
                }
                st = board.state.lock().unwrap();
            }
        }
    }
    let panics = st.panics;
    drop(st);
    assert_eq!(panics, 0, "{panics} task attempt(s) panicked");
    // Losing attempts may still be running; `take` transitions each slot
    // FULL→TAKEN, after which a late loser's `try_put` simply fails.
    (0..n).map(|i| results.take(i)).collect()
}

#[allow(clippy::too_many_arguments)]
fn submit_attempt<I, T, F>(
    pool: &ThreadPool,
    i: usize,
    speculative: bool,
    input: Arc<I>,
    f: Arc<F>,
    results: Arc<OnceSlots<T>>,
    board: Arc<Board>,
    counters: Arc<Counters>,
) where
    I: Send + Sync + 'static,
    T: Send + 'static,
    F: Fn(usize, Arc<I>) -> T + Send + Sync + 'static,
{
    pool.execute(move || {
        if board.decided[i].load(Ordering::Acquire) {
            return; // winner finished while this attempt was queued
        }
        if !speculative {
            board.started_us[i].store(
                board.epoch.elapsed().as_micros() as u64 + 1,
                Ordering::Release,
            );
        }
        let t0 = Instant::now();
        match catch_unwind(AssertUnwindSafe(|| f(i, input))) {
            Ok(t) => {
                if results.try_put(i, t) {
                    board.decided[i].store(true, Ordering::Release);
                    if speculative {
                        counters.inc(names::SPECULATIVE_WON);
                    }
                    let mut st = board.state.lock().unwrap();
                    st.winners += 1;
                    st.durations.push(t0.elapsed().as_secs_f64());
                    board.cv.notify_all();
                }
                // a losing attempt's result is dropped right here
            }
            Err(_) => {
                // mark decided so the driver unblocks, then report via the
                // panic count — the wave fails loudly, like `run_owned`
                let first = !board.decided[i].swap(true, Ordering::AcqRel);
                let mut st = board.state.lock().unwrap();
                st.panics += 1;
                if first {
                    st.winners += 1;
                }
                board.cv.notify_all();
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_wait(d: Duration) {
        let t0 = Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    #[test]
    fn all_tasks_complete_without_speculation() {
        let pool = ThreadPool::new(3);
        let counters = Arc::new(Counters::new());
        let out = run_tasks(
            &pool,
            (0..20u64).collect::<Vec<_>>(),
            Arc::new(|_i, v: Arc<u64>| *v * 2),
            None,
            &counters,
        );
        assert_eq!(out, (0..20u64).map(|v| v * 2).collect::<Vec<_>>());
        assert_eq!(counters.get(names::SPECULATIVE_LAUNCHED), 0);
    }

    #[test]
    fn without_speculation_attempts_own_their_input() {
        // no retained references ⇒ every attempt can consume its input in
        // place, like the serial path moves splits into tasks
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let out = run_tasks(
            &pool,
            vec![vec![1u64, 2], vec![3, 4]],
            Arc::new(|_i, v: Arc<Vec<u64>>| {
                let owned = Arc::try_unwrap(v).expect("attempt must be sole owner");
                owned.into_iter().sum::<u64>()
            }),
            None,
            &counters,
        );
        assert_eq!(out, vec![3, 7]);
    }

    #[test]
    fn straggler_gets_cloned_and_output_is_unchanged() {
        let pool = ThreadPool::new(4);
        let counters = Arc::new(Counters::new());
        let items: Vec<u64> = (0..8).collect();
        let f = Arc::new(|_i: usize, v: Arc<u64>| {
            if *v == 7 {
                busy_wait(Duration::from_millis(150));
            } else {
                busy_wait(Duration::from_millis(2));
            }
            *v + 100
        });
        let out = run_tasks(&pool, items, f, Some(SpecPolicy::default()), &counters);
        assert_eq!(out, (0..8u64).map(|v| v + 100).collect::<Vec<_>>());
        assert!(
            counters.get(names::SPECULATIVE_LAUNCHED) >= 1,
            "the 150ms straggler should have been cloned"
        );
        // whether the clone wins is timing-dependent; only the invariant
        // won <= launched is guaranteed
        assert!(
            counters.get(names::SPECULATIVE_WON) <= counters.get(names::SPECULATIVE_LAUNCHED)
        );
    }

    #[test]
    #[should_panic(expected = "task attempt(s) panicked")]
    fn attempt_panic_fails_the_wave() {
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let _ = run_tasks(
            &pool,
            vec![0u64, 1],
            Arc::new(|_i, v: Arc<u64>| {
                if *v == 1 {
                    panic!("boom");
                }
                *v
            }),
            None,
            &counters,
        );
    }

    #[test]
    fn empty_wave_is_fine() {
        let pool = ThreadPool::new(2);
        let counters = Arc::new(Counters::new());
        let out: Vec<u64> = run_tasks(
            &pool,
            Vec::new(),
            Arc::new(|_i, v: Arc<u64>| *v),
            None,
            &counters,
        );
        assert!(out.is_empty());
    }
}
