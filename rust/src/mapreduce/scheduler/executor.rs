//! Executor worker: runs map/reduce task bodies on behalf of the
//! distributed scheduler ([`super::dist`]), communicating only via typed
//! messages over a [`Transport`](super::transport::Transport).
//!
//! An executor owns a [`RunStore`] of the sealed map runs it produced,
//! each registered upstream by *location* — `(executor_id, run_id)` — so
//! reduce tasks on other executors fetch them over the data plane instead
//! of receiving in-memory handles. A dedicated per-executor data-server
//! thread answers [`FetchRequest`]s out of the store, so a control loop
//! blocked on its own fetch can never deadlock a peer's.
//!
//! Reduce tasks accumulate sources as map tasks complete (the push
//! dispatcher's first slice across the message boundary): `LaunchReduce
//! { sealed: false }` opens a pending reduce, `AddSources` streams newly
//! registered locations in (fetched eagerly, overlapping the map wave),
//! and `SealReduce` merges everything in canonical map-task order and
//! runs the reduce body inline. Barrier mode is the degenerate case —
//! `LaunchReduce { sealed: true }` with the full source list.
//!
//! Failure semantics on the message path:
//! - a panicking task body (including injected faults) reports
//!   `TaskFailed`; the scheduler decides retry vs dead-letter,
//! - a fetch that times out is retried with a fresh reply link up to a
//!   budget (`DIST_FETCH_RETRIES`); exhaustion or a `Gone` reply reports
//!   `FetchFailed` so the scheduler can re-run the lost map,
//! - a [`KillPlan`] makes this executor silently disconnect after its
//!   N-th completed map — the scheduler observes the dead link on its
//!   next send and resubmits everything this executor held.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::mapreduce::checkpoint::Manifest;
use crate::mapreduce::counters::{names, Counters};
use crate::mapreduce::engine::{
    exec_map_task, exec_reduce_task, CombineFn, GroupFn, MapTaskOutput, ReduceTaskOutput,
};
use crate::mapreduce::fault::{FaultInjector, TaskPhase};
use crate::mapreduce::memory::{MemoryConsumer, MemoryPool, MemoryReservation};
use crate::mapreduce::sortspill::{next_run_id, Codec, ResolvedSpill, Run};
use crate::mapreduce::trace::{JobTraceCtx, TaskTraceCtx, TraceEvent, TracePhase};
use crate::mapreduce::types::{MapTaskFactory, Partitioner, ReduceTaskFactory, SizeEstimate};

use super::transport::{LinkClass, RxLink, Transport, TxLink};

/// Deterministic executor-loss injection: the named executor disconnects
/// (drops its control link without a word) right after completing its
/// `after_map_tasks`-th map task, leaving its registered runs
/// unreachable. Used by `prop_exec.rs` and the `dist-smoke` CI leg to
/// pin the resubmission path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillPlan {
    /// Which executor dies (the scheduler requires ≥ 2 executors when set).
    pub executor: usize,
    /// How many map tasks it completes first.
    pub after_map_tasks: usize,
}

/// Where a map task's sealed runs for one reduce partition live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct RunLocation {
    pub map_task: usize,
    /// Executor currently holding the runs.
    pub executor: usize,
    /// How many runs are registered for this (map, partition) pair; the
    /// fetcher verifies the reply length against it.
    pub runs: u32,
}

/// Scheduler → executor control frames.
pub(crate) enum ToExecutor<KI, VI> {
    LaunchMap {
        task: usize,
        attempt: u32,
        split: Arc<Vec<(KI, VI)>>,
    },
    /// Open (or restart, on a higher attempt) a reduce task. `sealed`
    /// means the source list is complete and the body runs immediately.
    LaunchReduce {
        task: usize,
        attempt: u32,
        sources: Vec<RunLocation>,
        sealed: bool,
    },
    /// Stream newly registered sources into a pending reduce.
    AddSources { task: usize, sources: Vec<RunLocation> },
    /// The source list is complete; merge and run the reduce body.
    SealReduce { task: usize },
    /// Retract a speculation loser's registered runs.
    DropRuns { task: usize, attempt: u32 },
    /// Liveness probe; a failed send is how the scheduler detects loss.
    Ping,
    Shutdown,
}

/// Executor → scheduler control frames. Task outputs travel with their
/// runs stripped ([`MapTaskOutput::take_runs`]) — only byte/record
/// accounting crosses the control plane; the runs stay in the store.
pub(crate) enum FromExecutor<KT, VT, KO, VO> {
    Registered {
        executor: usize,
    },
    MapDone {
        executor: usize,
        task: usize,
        attempt: u32,
        out: MapTaskOutput<KT, VT>,
        run_counts: Vec<u32>,
        run_ids: Vec<Vec<u64>>,
        counters: Counters,
    },
    ReduceDone {
        executor: usize,
        task: usize,
        attempt: u32,
        out: ReduceTaskOutput<KO, VO>,
        counters: Counters,
        /// When this reduce attempt opened, seconds since job start —
        /// feeds `reduce_first_start_secs`/overlap stats.
        started_secs: f64,
    },
    TaskFailed {
        executor: usize,
        phase: TaskPhase,
        task: usize,
        attempt: u32,
        message: String,
    },
    /// A fetch from `source` failed terminally (peer gone or retries
    /// exhausted); the reduce attempt aborted and needs a relaunch once
    /// the map is re-registered.
    FetchFailed {
        executor: usize,
        task: usize,
        attempt: u32,
        source: RunLocation,
    },
}

/// Data-plane request: "send me map task `map_task`'s runs for reduce
/// partition `partition`". The reply travels over a per-request link so
/// concurrent fetches never interleave.
pub(crate) struct FetchRequest<T> {
    pub map_task: usize,
    pub partition: usize,
    pub reply: TxLink<FetchReply<T>>,
}

pub(crate) enum FetchReply<T> {
    /// The registered runs with their ids, in seal order.
    Runs(Vec<(u64, Run<T>)>),
    /// This executor no longer holds them (lost, retracted, or unknown).
    Gone,
}

/// Sealed map runs held by one executor, keyed by map task, with one
/// id-stamped run list per reduce partition. Shared between the control
/// loop (inserts) and the data-server thread (lookups).
pub(crate) struct RunStore<T> {
    tasks: HashMap<usize, Vec<Vec<(u64, Run<T>)>>>,
    /// Set when this executor "dies" under a [`KillPlan`]: the data
    /// server answers `Gone` from then on, like a crashed peer would.
    lost: bool,
}

impl<T> RunStore<T> {
    fn new() -> Self {
        RunStore { tasks: HashMap::new(), lost: false }
    }

    /// Register a map task's runs, assigning each a process-unique id.
    /// Returns per-partition (run count, run ids) for the registry, plus
    /// the pool bytes of any entry this insert replaced (a speculation
    /// loser's stale registration) so the store's reservation can shrink.
    fn insert(
        &mut self,
        task: usize,
        buckets: Vec<Vec<Run<T>>>,
    ) -> (Vec<u32>, Vec<Vec<u64>>, u64)
    where
        T: SizeEstimate,
    {
        let with_ids: Vec<Vec<(u64, Run<T>)>> = buckets
            .into_iter()
            .map(|runs| runs.into_iter().map(|r| (next_run_id(), r)).collect())
            .collect();
        let counts = with_ids.iter().map(|runs| runs.len() as u32).collect();
        let ids = with_ids
            .iter()
            .map(|runs| runs.iter().map(|(id, _)| *id).collect())
            .collect();
        let replaced = self
            .tasks
            .insert(task, with_ids)
            .map(|old| old.iter().flatten().map(|(_, run)| run.pool_bytes()).sum())
            .unwrap_or(0);
        (counts, ids, replaced)
    }
}

/// Everything one executor worker needs; built by the scheduler, moved
/// into the executor thread.
pub(crate) struct ExecutorSpec<KI, VI, KT, VT, KO, VO>
where
    KT: SizeEstimate,
    VT: SizeEstimate,
    KO: SizeEstimate,
    VO: SizeEstimate,
{
    pub id: usize,
    pub num_reducers: usize,
    pub rx_ctl: RxLink<ToExecutor<KI, VI>>,
    pub tx_out: TxLink<FromExecutor<KT, VT, KO, VO>>,
    pub rx_data: RxLink<FetchRequest<(KT, VT)>>,
    /// Data-plane senders to every executor's run server, by executor id.
    pub peers: Vec<TxLink<FetchRequest<(KT, VT)>>>,
    pub mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
    pub partitioner: Arc<dyn Partitioner<KT>>,
    pub combine_fn: Option<CombineFn<KT, VT>>,
    pub reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    pub grouping: GroupFn<KT>,
    pub spill: Option<ResolvedSpill<(KT, VT)>>,
    pub sort_budget: Option<usize>,
    pub injector: Arc<FaultInjector>,
    pub kill: Option<KillPlan>,
    /// Restore-only checkpoint view: committed map tasks short-circuit to
    /// their manifest files instead of re-executing.
    pub manifest: Option<(Arc<Manifest>, Arc<dyn Codec<(KT, VT)>>)>,
    pub jctx: Option<JobTraceCtx>,
    /// Job start instant — `started_secs` stamps are relative to it.
    pub t0: Instant,
    pub fetch_attempts: u32,
    pub fetch_timeout: Duration,
    /// Shared memory pool: the executor's [`RunStore`] accounts its
    /// resident run bytes here and task bodies reserve through it.
    pub memory: Option<MemoryPool>,
}

/// One reduce task accumulating fetched sources until sealed. The
/// `BTreeMap` keeps map-task-ascending order, which is exactly the
/// canonical `transpose_runs` merge order the serial path uses — that
/// ordering is what keeps dist output byte-identical.
struct PendingReduce<T> {
    attempt: u32,
    started_secs: f64,
    counters: Counters,
    fetched: BTreeMap<usize, Vec<Run<T>>>,
}

fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

/// The executor control loop. Returns when told to `Shutdown`, when the
/// scheduler's control link closes, or when its [`KillPlan`] fires.
pub(crate) fn run_executor<KI, VI, KT, VT, KO, VO, TP>(
    spec: ExecutorSpec<KI, VI, KT, VT, KO, VO>,
    transport: TP,
) where
    KI: Clone + Send + Sync + 'static,
    VI: Clone + Send + Sync + 'static,
    KT: Ord + Clone + Send + Sync + SizeEstimate + 'static,
    VT: Clone + Send + Sync + SizeEstimate + 'static,
    KO: Send + SizeEstimate + 'static,
    VO: Send + SizeEstimate + 'static,
    TP: Transport,
{
    let ExecutorSpec {
        id,
        num_reducers: r,
        rx_ctl,
        tx_out,
        rx_data,
        peers,
        mapper,
        partitioner,
        combine_fn,
        reducer,
        grouping,
        spill,
        sort_budget,
        injector,
        kill,
        manifest,
        jctx,
        t0,
        fetch_attempts,
        fetch_timeout,
        memory,
    } = spec;

    let store: Arc<Mutex<RunStore<(KT, VT)>>> = Arc::new(Mutex::new(RunStore::new()));

    // The store's resident run bytes, accounted against the shared pool.
    // The store cannot shed runs on demand (its relief is `DropRuns`),
    // so it registers non-spillable and a denied grow overdrafts
    // truthfully: counted, traced, and charged anyway.  Only the control
    // loop touches the reservation — reservation ops never run under the
    // store mutex.
    let store_mem: Option<RefCell<MemoryReservation>> = memory
        .as_ref()
        .map(|p| RefCell::new(MemoryConsumer::new("run-store").register(p)));
    let charge_store =
        |bytes: u64, replaced: u64, counters: &Counters, tctx: Option<&TaskTraceCtx>| {
            if let Some(mem) = &store_mem {
                let mut res = mem.borrow_mut();
                if replaced > 0 {
                    res.shrink(replaced);
                }
                if bytes > 0 && !res.try_grow(bytes) {
                    counters.inc(names::POOL_DENIED_GROWS);
                    if let Some(t) = tctx {
                        t.emit(TraceEvent::ReservationDenied { requested: bytes });
                    }
                    res.grow(bytes);
                }
            }
        };

    // Data server: answers peers' fetch requests independently of the
    // control loop, so an executor busy in a task body still serves
    // shuffle data. Exits when the last peer sender is dropped.
    {
        let store = Arc::clone(&store);
        thread::Builder::new()
            .name(format!("snmr-exec{id}-data"))
            .spawn(move || {
                while let Ok(req) = rx_data.recv() {
                    let reply = {
                        let s = store.lock().expect("run store poisoned");
                        if s.lost {
                            FetchReply::Gone
                        } else {
                            match s.tasks.get(&req.map_task) {
                                Some(buckets) if req.partition < buckets.len() => {
                                    FetchReply::Runs(
                                        buckets[req.partition]
                                            .iter()
                                            .map(|(rid, run)| (*rid, run.clone()))
                                            .collect(),
                                    )
                                }
                                _ => FetchReply::Gone,
                            }
                        }
                    };
                    let _ = req.reply.send(reply);
                }
            })
            .expect("spawn executor data server");
    }

    let _ = tx_out.send(FromExecutor::Registered { executor: id });

    // Fetch every not-yet-held source into `p`; on terminal failure
    // reports `FetchFailed` and returns false (caller drops the pending).
    let fetch_sources = |p: &mut PendingReduce<(KT, VT)>,
                         task: usize,
                         sources: &[RunLocation]|
     -> bool {
        for source in sources {
            if p.fetched.contains_key(&source.map_task) {
                continue;
            }
            if source.runs == 0 {
                // Nothing to move; record the source as satisfied.
                p.fetched.insert(source.map_task, Vec::new());
                continue;
            }
            if source.executor == id {
                let runs = {
                    let s = store.lock().expect("run store poisoned");
                    if s.lost {
                        None
                    } else {
                        s.tasks.get(&source.map_task).and_then(|buckets| {
                            buckets
                                .get(task)
                                .map(|rs| rs.iter().map(|(_, run)| run.clone()).collect::<Vec<_>>())
                        })
                    }
                };
                match runs {
                    Some(runs) if runs.len() as u32 == source.runs => {
                        p.counters.inc(names::DIST_LOCAL_FETCHES);
                        p.fetched.insert(source.map_task, runs);
                        continue;
                    }
                    _ => {
                        let _ = tx_out.send(FromExecutor::FetchFailed {
                            executor: id,
                            task,
                            attempt: p.attempt,
                            source: *source,
                        });
                        return false;
                    }
                }
            }
            // Remote: request/reply over the data plane, retrying with a
            // fresh reply link on timeout or a torn (dropped-frame) link.
            let mut attempts_left = fetch_attempts.max(1);
            let fetched = loop {
                let (reply_tx, reply_rx) =
                    transport.link::<FetchReply<(KT, VT)>>(LinkClass::Data);
                let sent = peers[source.executor]
                    .send(FetchRequest {
                        map_task: source.map_task,
                        partition: task,
                        reply: reply_tx,
                    })
                    .is_ok();
                if sent {
                    match reply_rx.recv_timeout(fetch_timeout) {
                        Ok(Some(FetchReply::Runs(runs)))
                            if runs.len() as u32 == source.runs =>
                        {
                            break Some(runs.into_iter().map(|(_, run)| run).collect::<Vec<_>>());
                        }
                        Ok(Some(_)) => break None, // Gone or short reply: the peer lost the runs
                        Ok(None) | Err(_) => {}    // timeout / torn link: retry below
                    }
                } else {
                    break None; // peer's data server is gone
                }
                attempts_left -= 1;
                if attempts_left == 0 {
                    break None;
                }
                p.counters.inc(names::DIST_FETCH_RETRIES);
            };
            match fetched {
                Some(runs) => {
                    p.counters.inc(names::DIST_REMOTE_FETCHES);
                    if let Some(j) = &jctx {
                        j.task(TracePhase::Reduce, task, p.attempt).emit(TraceEvent::RunFetched {
                            executor: source.executor as u64,
                            records: runs.iter().map(|run| run.len() as u64).sum(),
                        });
                    }
                    p.fetched.insert(source.map_task, runs);
                }
                None => {
                    let _ = tx_out.send(FromExecutor::FetchFailed {
                        executor: id,
                        task,
                        attempt: p.attempt,
                        source: *source,
                    });
                    return false;
                }
            }
        }
        true
    };

    // Merge the fetched sources in map-task order and run the reduce body.
    let finish_reduce = |task: usize, p: PendingReduce<(KT, VT)>| {
        let PendingReduce { attempt, started_secs, counters, fetched } = p;
        let runs: Vec<Run<(KT, VT)>> = fetched.into_values().flatten().collect();
        let tctx = jctx.as_ref().map(|j| j.task(TracePhase::Reduce, task, attempt));
        if let Some(t) = &tctx {
            t.emit(TraceEvent::AttemptStarted);
        }
        let result = catch_unwind(AssertUnwindSafe(|| {
            injector.fire_attempt(TaskPhase::Reduce, task, attempt, tctx.as_ref());
            exec_reduce_task(
                runs,
                reducer.as_ref(),
                grouping.as_ref(),
                &counters,
                tctx.as_ref(),
                memory.as_ref(),
            )
        }));
        match result {
            Ok(out) => {
                if let Some(t) = &tctx {
                    t.emit(TraceEvent::AttemptFinished);
                }
                let _ = tx_out.send(FromExecutor::ReduceDone {
                    executor: id,
                    task,
                    attempt,
                    out,
                    counters,
                    started_secs,
                });
            }
            Err(payload) => {
                let message = panic_text(payload);
                if let Some(t) = &tctx {
                    t.emit(TraceEvent::AttemptPanicked { message: message.clone() });
                }
                let _ = tx_out.send(FromExecutor::TaskFailed {
                    executor: id,
                    phase: TaskPhase::Reduce,
                    task,
                    attempt,
                    message,
                });
            }
        }
    };

    let mut maps_done = 0usize;
    let mut pending: HashMap<usize, PendingReduce<(KT, VT)>> = HashMap::new();

    loop {
        let msg = match rx_ctl.recv() {
            Ok(m) => m,
            Err(_) => return, // scheduler gone
        };
        match msg {
            ToExecutor::Ping => {}
            ToExecutor::Shutdown => return,
            ToExecutor::DropRuns { task, attempt } => {
                let removed = store.lock().expect("run store poisoned").tasks.remove(&task);
                if let (Some(mem), Some(buckets)) = (&store_mem, &removed) {
                    let bytes: u64 =
                        buckets.iter().flatten().map(|(_, run)| run.pool_bytes()).sum();
                    mem.borrow_mut().shrink(bytes);
                }
                if let (Some(j), Some(buckets)) = (&jctx, removed) {
                    for (partition, runs) in buckets.iter().enumerate() {
                        if !runs.is_empty() {
                            j.task(TracePhase::Map, task, attempt)
                                .emit(TraceEvent::RunRetracted { partition });
                        }
                    }
                }
            }
            ToExecutor::LaunchMap { task, attempt, split } => {
                let counters = Counters::new();
                let mut restored = None;
                if let Some((man, codec)) = &manifest {
                    restored = man.restore_map(task, r, codec);
                }
                let completed = if let Some(mut out) = restored {
                    counters.inc(names::TASKS_RESUMED);
                    if let Some(j) = &jctx {
                        j.task(TracePhase::Map, task, attempt).emit(TraceEvent::CheckpointRestore);
                    }
                    let runs = out.take_runs();
                    let bytes: u64 = runs.iter().flatten().map(Run::pool_bytes).sum();
                    let (run_counts, run_ids, replaced) =
                        store.lock().expect("run store poisoned").insert(task, runs);
                    let tctx = jctx.as_ref().map(|j| j.task(TracePhase::Map, task, attempt));
                    charge_store(bytes, replaced, &counters, tctx.as_ref());
                    let _ = tx_out.send(FromExecutor::MapDone {
                        executor: id,
                        task,
                        attempt,
                        out,
                        run_counts,
                        run_ids,
                        counters,
                    });
                    true
                } else {
                    let tctx = jctx.as_ref().map(|j| j.task(TracePhase::Map, task, attempt));
                    if let Some(t) = &tctx {
                        t.emit(TraceEvent::AttemptStarted);
                    }
                    let split_data = (*split).clone();
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        injector.fire_attempt(TaskPhase::Map, task, attempt, tctx.as_ref());
                        exec_map_task(
                            split_data,
                            r,
                            sort_budget,
                            spill.as_ref(),
                            mapper.as_ref(),
                            partitioner.as_ref(),
                            combine_fn.as_ref(),
                            &counters,
                            None,
                            tctx.as_ref(),
                            memory.as_ref(),
                        )
                    }));
                    match result {
                        Ok(mut out) => {
                            if let Some(t) = &tctx {
                                t.emit(TraceEvent::AttemptFinished);
                            }
                            let runs = out.take_runs();
                            let bytes: u64 =
                                runs.iter().flatten().map(Run::pool_bytes).sum();
                            let (run_counts, run_ids, replaced) = store
                                .lock()
                                .expect("run store poisoned")
                                .insert(task, runs);
                            charge_store(bytes, replaced, &counters, tctx.as_ref());
                            let _ = tx_out.send(FromExecutor::MapDone {
                                executor: id,
                                task,
                                attempt,
                                out,
                                run_counts,
                                run_ids,
                                counters,
                            });
                            true
                        }
                        Err(payload) => {
                            let message = panic_text(payload);
                            if let Some(t) = &tctx {
                                t.emit(TraceEvent::AttemptPanicked { message: message.clone() });
                            }
                            let _ = tx_out.send(FromExecutor::TaskFailed {
                                executor: id,
                                phase: TaskPhase::Map,
                                task,
                                attempt,
                                message,
                            });
                            false
                        }
                    }
                };
                if completed {
                    maps_done += 1;
                    if let Some(k) = kill {
                        if k.executor == id && maps_done >= k.after_map_tasks {
                            // Die: registered runs become unreachable and
                            // the dropped control link is the scheduler's
                            // loss signal.
                            store.lock().expect("run store poisoned").lost = true;
                            return;
                        }
                    }
                }
            }
            ToExecutor::LaunchReduce { task, attempt, sources, sealed } => {
                // A relaunch (higher attempt) replaces any stale pending.
                pending.remove(&task);
                let mut p = PendingReduce {
                    attempt,
                    started_secs: t0.elapsed().as_secs_f64(),
                    counters: Counters::new(),
                    fetched: BTreeMap::new(),
                };
                if !fetch_sources(&mut p, task, &sources) {
                    continue;
                }
                if sealed {
                    finish_reduce(task, p);
                } else {
                    pending.insert(task, p);
                }
            }
            ToExecutor::AddSources { task, sources } => {
                if let Some(mut p) = pending.remove(&task) {
                    if fetch_sources(&mut p, task, &sources) {
                        pending.insert(task, p);
                    }
                }
            }
            ToExecutor::SealReduce { task } => {
                if let Some(p) = pending.remove(&task) {
                    finish_reduce(task, p);
                }
            }
        }
    }
}
