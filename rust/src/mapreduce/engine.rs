//! The job driver: split → map (+sort/combine/partition) → shuffle →
//! reduce, as a streaming pipeline.
//!
//! Faithful to the Hadoop execution model at the semantics level the
//! paper's algorithms require (see module docs on [`super`]), instrumented
//! with the per-task wall-clock timings and byte counts the cluster
//! simulator ([`super::sim`]) consumes.
//!
//! ## Intermediate data path
//!
//! Map tasks partition and sort their output into per-reducer *runs*
//! (through the bounded [`RunSorter`] when a sort budget is configured,
//! one stable sort per bucket otherwise), optionally pre-reduced by a
//! map-side [`Combiner`].  The driver's shuffle step only *transposes*
//! run ownership — reducer `j` receives every map task's bucket-`j` runs,
//! in map-task order — without touching a single record.  Each reduce
//! task then drives its own lazy k-way [`MergeIter`] over those runs, so
//! the merged stream is never materialized and the k-way merges of all
//! reducers run in parallel on the worker pool instead of serially on the
//! driver.  Task inputs and outputs travel through atomic
//! [`OnceSlots`](crate::util::threadpool::OnceSlots) (via [`run_owned`]),
//! so workers never contend on a shared lock for the handoff.

use std::cell::RefCell;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Instant;

use super::combiner::{combine_sorted_bucket, Combiner};
use super::config::JobConfig;
use super::counters::{names, Counters};
use super::memory::{MemoryConsumer, MemoryPool, MemoryReservation};
use super::push::PushAttempt;
use super::shuffle::MergeIter;
use super::sortspill::{ResolvedSpill, Run, RunRecords, RunSorter, SPILL_READ_CHUNK};
use super::splits::even_splits;
use super::trace::{TaskTraceCtx, TraceEvent, TracePhase};
use super::types::{
    Emitter, MapTaskFactory, Partitioner, ReduceTaskFactory, SizeEstimate, ValuesIter,
};
use crate::metrics::histogram::Histogram;
use crate::util::threadpool::run_owned;

/// Grouping comparator: `true` if two (adjacent, sort-ordered) keys belong
/// to the same reduce *group* (Hadoop's value-grouping comparator).
pub type GroupFn<KT> = Arc<dyn Fn(&KT, &KT) -> bool + Send + Sync>;

/// Type-erased map-side combine step: folds one sorted run in place,
/// returning `(records_in, records_out)`.  Built by
/// [`run_job_with_combiner`] so the `Clone` bound the fold needs stays off
/// the combiner-less [`run_job`] path.  Also built by the concurrent
/// [`scheduler`](super::scheduler), which shares the task bodies below.
pub(crate) type CombineFn<K, V> =
    Arc<dyn Fn(&mut Vec<(K, V)>, &Counters) -> (u64, u64) + Send + Sync>;

/// Per-job measured statistics (feed the simulator and the reports).
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    /// Wall time of each map task, in seconds, indexed by task id.
    pub map_task_secs: Vec<f64>,
    /// Wall time of each reduce task, in seconds, indexed by partition.
    /// Includes that reducer's k-way merge, which streams inside the task.
    pub reduce_task_secs: Vec<f64>,
    /// Intermediate bytes routed to each reduce partition (post-combine
    /// when a combiner is registered): the size estimate on the in-memory
    /// path, the on-disk (possibly compressed) run-file bytes when
    /// [`JobConfig::spill`] is set.
    pub shuffle_bytes_per_reducer: Vec<u64>,
    /// Pre-compression estimate of the total intermediate bytes
    /// (`SHUFFLE_BYTES_RAW`); equals the `shuffle_bytes_per_reducer` sum
    /// on the in-memory path.
    pub shuffle_bytes_raw: u64,
    /// Bytes written to spill run files (0 on the in-memory path).
    pub spill_bytes_written: u64,
    /// True when intermediate runs were spilled DEFLATE-compressed — the
    /// signal [`JobProfile`](crate::mapreduce::sim::JobProfile) uses to
    /// charge (de)compression CPU in the simulator.
    pub intermediate_compressed: bool,
    /// Wall time of the whole map phase (tasks + sort), reduce phase
    /// (merge + reduce), and the driver's shuffle transpose, as executed
    /// on the real worker pool.
    pub map_phase_secs: f64,
    pub shuffle_phase_secs: f64,
    pub reduce_phase_secs: f64,
    pub total_secs: f64,
    /// Records emitted by map / reduce.
    pub map_output_records: u64,
    pub reduce_output_records: u64,
    /// Records emitted by each reduce task, indexed by partition.  In SN
    /// blocking mode every window comparison emits one pair, so this is
    /// the per-reduce-task *pair count* — the reduce-side data-skew signal
    /// the `sn::loadbalance` strategies exist to flatten
    /// (`max / (total / tasks)` is the skew ratio they report).
    pub reduce_task_output_records: Vec<u64>,
    /// When the job's first reduce task started executing (stamped on
    /// the reduce slot itself), in seconds after job start.  On the
    /// barrier paths this is the reduce-wave start (strictly after every
    /// map task); with the push-based shuffle ([`JobConfig::push`] / the
    /// scheduler's [`PushMode`](crate::mapreduce::scheduler::PushMode))
    /// a reduce task is submitted at its first mailbox arrival, so on a
    /// multi-wave map phase with a free reduce slot this strictly
    /// precedes the last map-task completion.
    pub reduce_first_start_secs: f64,
    /// When the last map task of the job was decided, in seconds after
    /// job start.
    pub map_wave_done_secs: f64,
    /// How long reduce execution overlapped the job's own map wave:
    /// `map_wave_done_secs − reduce_first_start_secs`, clamped at 0.
    /// Always 0 on the barrier paths — a positive value is the direct
    /// evidence the push shuffle removed the map→reduce barrier.
    pub overlap_secs: f64,
    /// Per-task runtime distribution over the map wave, in microseconds
    /// (log2-bucketed; same samples as [`JobStats::map_task_secs`]).
    pub map_task_us_hist: Histogram,
    /// Per-task runtime distribution over the reduce wave, in
    /// microseconds.
    pub reduce_task_us_hist: Histogram,
    /// Distribution of intermediate bytes per reduce partition (same
    /// samples as [`JobStats::shuffle_bytes_per_reducer`]).
    pub shuffle_bytes_hist: Histogram,
    /// Distribution of output records per reduce task — the reduce-side
    /// skew signal in histogram form.
    pub reduce_records_hist: Histogram,
    /// Task attempts resubmitted after a panic (`TASK_RETRIES`).
    pub task_retries: u64,
    /// Tasks whose every attempt panicked (`TASKS_FAILED`).
    pub tasks_failed: u64,
    /// Tasks that exhausted their retry budget under
    /// [`JobConfig::dead_letter`] — the job's dead-letter queue.  Always
    /// empty on [`JobOutcome::Ok`] jobs.
    pub dead_letters: Vec<DeadLetter>,
}

/// How a finished job finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum JobOutcome {
    /// Every task committed.
    #[default]
    Ok,
    /// One or more tasks were dead-lettered
    /// ([`JobConfig::dead_letter`]): the output is partial — complete
    /// except for the records of the [`JobStats::dead_letters`] entries.
    Degraded,
}

/// The input-split descriptor of a task that exhausted its retries (see
/// [`JobStats::dead_letters`]): enough to identify and re-drive the lost
/// work from the caller's copy of the input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeadLetter {
    pub phase: super::fault::TaskPhase,
    /// Map-task index (= input-split index) or reduce partition.
    pub task: usize,
    /// Input records the lost task owned: the split length for a map
    /// task, the committed input-run count for a reduce partition.
    pub records: u64,
}

/// Everything a finished job returns.
pub struct JobResult<KO, VO> {
    /// Reduce outputs, one `Vec` per reduce partition, in partition order
    /// ("the output partitions can easily be merged to a combined result").
    pub outputs: Vec<Vec<(KO, VO)>>,
    pub counters: Arc<Counters>,
    pub stats: JobStats,
    /// [`JobOutcome::Ok`] unless dead-lettering degraded the job.
    pub outcome: JobOutcome,
}

impl<KO, VO> JobResult<KO, VO> {
    /// Concatenate all partitions in order (the final merge step).
    pub fn merged_output(self) -> Vec<(KO, VO)> {
        self.outputs.into_iter().flatten().collect()
    }
}

/// Key-order comparator for intermediate pairs (the map-side sort order).
fn key_cmp<K: Ord, V>(a: &(K, V), b: &(K, V)) -> std::cmp::Ordering {
    a.0.cmp(&b.0)
}

/// One task's window on the process-wide [`MemoryPool`]: a single
/// reservation covering every intermediate byte the task currently pins
/// (sorter buffers plus sealed-but-unrouted runs), sized by the same
/// [`SizeEstimate`] unit the shuffle accounting uses.
///
/// Charges are *truthful*: a denied [`MemoryReservation::try_grow`] is
/// counted and traced, then taken anyway via the unconditional grow —
/// the bytes exist whether or not the pool likes it, and relief comes
/// from sealing runs at the next drain point (see
/// [`seal_on_pressure`]), not from under-reporting residency.
pub(crate) struct TaskMemory {
    res: MemoryReservation,
    /// Whether pressure has an answer: sealed runs leave the task
    /// through a spill file or the push shuffle.  Barrier-mode
    /// in-memory tasks retain their runs to the end regardless, so
    /// sealing early would shed nothing — they overdraft instead.
    elastic: bool,
    /// A grow was denied since the last [`Self::pressured`] check.
    denied: bool,
}

impl TaskMemory {
    fn new(pool: &MemoryPool, name: &str, elastic: bool) -> Self {
        Self {
            res: MemoryConsumer::new(name).with_can_spill(elastic).register(pool),
            elastic,
            denied: false,
        }
    }

    /// Charge `bytes` against the pool, recording (and overdrafting
    /// past) a denial.
    fn charge(&mut self, bytes: u64, counters: &Counters, trace: Option<&TaskTraceCtx>) {
        if bytes == 0 {
            return;
        }
        if !self.res.try_grow(bytes) {
            counters.inc(names::POOL_DENIED_GROWS);
            if let Some(t) = trace {
                t.emit(TraceEvent::ReservationDenied { requested: bytes });
            }
            self.denied = true;
            self.res.grow(bytes);
        }
    }

    /// Return `bytes` to the pool (a run left the task).
    fn release(&mut self, bytes: u64) {
        self.res.shrink(bytes);
    }

    /// True when the task should seal its buffered records now: a grow
    /// was denied since the last check, or the pool's fair-spill policy
    /// picked this consumer as its victim.  Always false for inelastic
    /// tasks — sealing would free nothing.
    fn pressured(&mut self) -> bool {
        let denied = std::mem::take(&mut self.denied);
        let asked = self.res.take_spill_request();
        (denied || asked) && self.elastic
    }
}

/// Drain every pair buffered in `out` into the per-partition sorters;
/// returns the number of records drained.  With `mem` set, the drained
/// bytes are charged against the task's pool reservation first — the
/// caller answers any resulting pressure via [`seal_on_pressure`].
fn drain_emitter<KT, VT, C>(
    out: &mut Emitter<KT, VT>,
    partitioner: &dyn Partitioner<KT>,
    r: usize,
    sorters: &mut [RunSorter<(KT, VT), C>],
    mem: Option<&RefCell<TaskMemory>>,
    counters: &Counters,
    trace: Option<&TaskTraceCtx>,
) -> u64
where
    KT: SizeEstimate,
    VT: SizeEstimate,
    C: Fn(&(KT, VT), &(KT, VT)) -> std::cmp::Ordering,
{
    let pairs = out.take_pairs();
    let n = pairs.len() as u64;
    if let Some(m) = mem {
        let bytes: u64 = pairs
            .iter()
            .map(|(k, v)| (k.size_bytes() + v.size_bytes()) as u64)
            .sum();
        m.borrow_mut().charge(bytes, counters, trace);
    }
    for (k, v) in pairs {
        let p = partitioner.partition(&k, r);
        assert!(p < r, "partitioner returned {p} for r={r}");
        sorters[p].push((k, v));
    }
    n
}

/// Answer pool pressure at a drain point: seal every partially-filled
/// sorter buffer early and route the sealed runs immediately, so their
/// bytes leave the task (to disk or the push mailboxes) and return to
/// the pool.  A no-op without pressure — run boundaries then fall only
/// at the usual sort-budget seals, which is what keeps the pool-off and
/// unlimited-pool paths byte-identical.
fn seal_on_pressure<KT, VT, C>(
    mem: Option<&RefCell<TaskMemory>>,
    sorters: &mut [RunSorter<(KT, VT), C>],
    router: &mut RunRouter<'_, KT, VT>,
    counters: &Counters,
) where
    KT: SizeEstimate,
    VT: SizeEstimate,
    C: Fn(&(KT, VT), &(KT, VT)) -> std::cmp::Ordering,
{
    let Some(m) = mem else { return };
    if !m.borrow_mut().pressured() {
        return;
    }
    counters.inc(names::POOL_SPILL_REQUESTS);
    for sorter in sorters.iter_mut() {
        if sorter.buffered_len() > 0 {
            sorter.seal_now();
        }
    }
    router.drain_sealed(sorters, counters);
}

// ---------------------------------------------------------------------------
// Task bodies, shared by the serial driver below and the concurrent
// `scheduler` module — both paths execute byte-identical task code, which
// is what makes "scheduler output == serial output" a structural property
// rather than something each job has to re-establish.
// ---------------------------------------------------------------------------

/// Everything one map task hands to the shuffle, plus its measurements.
pub(crate) struct MapTaskOutput<KT, VT> {
    /// Sorted runs per reduce partition — in-memory or codec-serialized
    /// run files ([`Run`]): one run per bucket without a sort budget, one
    /// per sealed chunk with one.
    pub bucket_runs: Vec<Vec<Run<(KT, VT)>>>,
    /// Post-combine intermediate bytes per reduce partition, as the
    /// shuffle charges them: the size estimate in memory, the on-disk
    /// (possibly compressed) run-file bytes when spilled.
    pub bucket_bytes: Vec<u64>,
    /// Pre-compression size estimate per reduce partition.
    pub bucket_raw_bytes: Vec<u64>,
    pub secs: f64,
    pub records: u64,
    pub bytes: u64,
    pub spilled: u64,
    pub spill_runs: u64,
    /// Run files written / bytes written to disk (0 without a spill spec).
    pub spill_file_runs: u64,
    pub spill_file_bytes: u64,
    pub combine_in: u64,
    pub combine_out: u64,
}

impl<KT, VT> MapTaskOutput<KT, VT> {
    /// The output of a task that produced nothing — the placeholder a
    /// dead-lettered map task leaves so the shuffle transpose and stats
    /// vectors stay index-aligned.
    pub(crate) fn empty(r: usize) -> Self {
        Self {
            bucket_runs: (0..r).map(|_| Vec::new()).collect(),
            bucket_bytes: vec![0; r],
            bucket_raw_bytes: vec![0; r],
            secs: 0.0,
            records: 0,
            bytes: 0,
            spilled: 0,
            spill_runs: 0,
            spill_file_runs: 0,
            spill_file_bytes: 0,
            combine_in: 0,
            combine_out: 0,
        }
    }

    /// Strip the runs out, leaving structurally empty buckets; every
    /// accounting field (byte sums, timings, combine counts) stays
    /// intact.  The distributed path parks the runs in the executor's
    /// run store and ships only the accounting over the control plane —
    /// downstream [`transpose_runs`]/`record_map_phase` see the same
    /// byte sums either way.
    pub(crate) fn take_runs(&mut self) -> Vec<Vec<Run<(KT, VT)>>> {
        let r = self.bucket_runs.len();
        std::mem::replace(&mut self.bucket_runs, (0..r).map(|_| Vec::new()).collect())
    }
}

/// Routes each sealed map-side run through combine → accounting → spill
/// serialization, then either hands it to the push-based shuffle the
/// moment it exists or retains it for the driver's barrier transpose.
/// One code path for both modes — which is what keeps their byte and
/// record counters identical.
struct RunRouter<'a, KT, VT>
where
    KT: SizeEstimate,
    VT: SizeEstimate,
{
    spill: Option<&'a ResolvedSpill<(KT, VT)>>,
    combine_fn: Option<&'a CombineFn<KT, VT>>,
    push: Option<&'a PushAttempt<(KT, VT)>>,
    trace: Option<&'a TaskTraceCtx>,
    mem: Option<&'a RefCell<TaskMemory>>,
    bucket_runs: Vec<Vec<Run<(KT, VT)>>>,
    bucket_bytes: Vec<u64>,
    bucket_raw_bytes: Vec<u64>,
    spilled: u64,
    spill_runs: u64,
    spill_file_runs: u64,
    spill_file_bytes: u64,
    combine_in: u64,
    combine_out: u64,
}

impl<'a, KT, VT> RunRouter<'a, KT, VT>
where
    KT: SizeEstimate,
    VT: SizeEstimate,
{
    fn new(
        r: usize,
        spill: Option<&'a ResolvedSpill<(KT, VT)>>,
        combine_fn: Option<&'a CombineFn<KT, VT>>,
        push: Option<&'a PushAttempt<(KT, VT)>>,
        trace: Option<&'a TaskTraceCtx>,
        mem: Option<&'a RefCell<TaskMemory>>,
    ) -> Self {
        Self {
            spill,
            combine_fn,
            push,
            trace,
            mem,
            bucket_runs: (0..r).map(|_| Vec::new()).collect(),
            bucket_bytes: vec![0; r],
            bucket_raw_bytes: vec![0; r],
            spilled: 0,
            spill_runs: 0,
            spill_file_runs: 0,
            spill_file_bytes: 0,
            combine_in: 0,
            combine_out: 0,
        }
    }

    /// Route every run the sorters have sealed so far (mid-task, so a
    /// push-mode map task ships spills while it is still mapping).
    fn drain_sealed<C>(&mut self, sorters: &mut [RunSorter<(KT, VT), C>], counters: &Counters)
    where
        C: Fn(&(KT, VT), &(KT, VT)) -> std::cmp::Ordering,
    {
        for (b, sorter) in sorters.iter_mut().enumerate() {
            for run in sorter.drain_sealed() {
                self.route(b, run, counters);
            }
        }
    }

    /// Combine, account, optionally serialize, and dispatch one run.
    fn route(&mut self, b: usize, mut run: Vec<(KT, VT)>, counters: &Counters) {
        if run.is_empty() {
            return;
        }
        // bytes this run holds of the task's reservation (charged at
        // drain_emitter, pre-combine) — released below as the run leaves
        // task memory, or shrunk to the post-combine size if retained
        let charged: u64 = match self.mem {
            Some(_) => run
                .iter()
                .map(|(k, v)| (k.size_bytes() + v.size_bytes()) as u64)
                .sum(),
            None => 0,
        };
        self.spill_runs += 1;
        if let Some(cf) = self.combine_fn {
            let (ci, co) = cf(&mut run, counters);
            self.combine_in += ci;
            self.combine_out += co;
        }
        let raw: u64 = run
            .iter()
            .map(|(k, v)| (k.size_bytes() + v.size_bytes()) as u64)
            .sum();
        self.bucket_raw_bytes[b] += raw;
        self.spilled += run.len() as u64;
        if let Some(t) = self.trace {
            t.emit(TraceEvent::RunSealed {
                partition: b,
                records: run.len() as u64,
            });
        }
        let sealed = match self.spill {
            None => {
                self.bucket_bytes[b] += raw;
                Run::Mem(run)
            }
            Some(sp) => {
                let rf = sp
                    .write_run(&run)
                    .unwrap_or_else(|e| panic!("spill map run: {e:#}"));
                self.spill_file_runs += 1;
                self.spill_file_bytes += rf.file_bytes();
                self.bucket_bytes[b] += rf.file_bytes();
                if let Some(t) = self.trace {
                    t.emit(TraceEvent::SpillWritten {
                        partition: b,
                        records: rf.records(),
                        file_bytes: rf.file_bytes(),
                    });
                }
                Run::Spilled(rf)
            }
        };
        if let Some(m) = self.mem {
            // pushed runs are re-charged under the mailbox reservation
            // (see push::ShuffleService); spilled runs cost ~0 resident;
            // retained Mem runs keep their (post-combine) resident cost
            let keep = match (&sealed, self.push) {
                (Run::Mem(_), None) => sealed.pool_bytes(),
                _ => 0,
            };
            m.borrow_mut().release(charged.saturating_sub(keep));
        }
        match self.push {
            Some(attempt) => attempt.push(b, sealed),
            None => self.bucket_runs[b].push(sealed),
        }
    }

    fn into_output(self, t0: Instant, records: u64, bytes: u64) -> MapTaskOutput<KT, VT> {
        MapTaskOutput {
            bucket_runs: self.bucket_runs,
            bucket_bytes: self.bucket_bytes,
            bucket_raw_bytes: self.bucket_raw_bytes,
            secs: t0.elapsed().as_secs_f64(),
            records,
            bytes,
            spilled: self.spilled,
            spill_runs: self.spill_runs,
            spill_file_runs: self.spill_file_runs,
            spill_file_bytes: self.spill_file_bytes,
            combine_in: self.combine_in,
            combine_out: self.combine_out,
        }
    }
}

/// Execute one map task over one owned split: `configure` → `map`* →
/// `close`, draining emitted records into per-partition [`RunSorter`]s.
/// Every sealed run is routed — combined by the optional combiner,
/// serialized to disk when `spill` is set — *at seal time*: with a
/// `push` attempt the run leaves the task the moment it exists
/// (mid-task under a sort budget), otherwise the sealed runs are
/// returned for the barrier shuffle's transpose.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exec_map_task<KI, VI, KT, VT>(
    split: Vec<(KI, VI)>,
    r: usize,
    sort_budget: Option<usize>,
    spill: Option<&ResolvedSpill<(KT, VT)>>,
    mapper: &dyn MapTaskFactory<KI, VI, KT, VT>,
    partitioner: &dyn Partitioner<KT>,
    combine_fn: Option<&CombineFn<KT, VT>>,
    counters: &Counters,
    push: Option<&PushAttempt<(KT, VT)>>,
    trace: Option<&TaskTraceCtx>,
    pool: Option<&MemoryPool>,
) -> MapTaskOutput<KT, VT>
where
    KT: Ord + SizeEstimate,
    VT: SizeEstimate,
{
    let t0 = Instant::now();
    let budget = sort_budget.unwrap_or(usize::MAX);
    // a map task can shed memory under pressure only when sealed runs
    // actually leave it — through a spill file or the push shuffle
    let elastic = spill.is_some() || push.is_some();
    let tmem = pool.map(|p| RefCell::new(TaskMemory::new(p, "map-task", elastic)));
    let mem = tmem.as_ref();
    let mut sorters: Vec<_> = (0..r)
        .map(|_| RunSorter::new(budget, key_cmp::<KT, VT>))
        .collect();
    let mut router = RunRouter::new(r, spill, combine_fn, push, trace, mem);
    let mut task = mapper.create_task();
    let mut out = Emitter::new();
    let mut records: u64 = 0;
    task.configure(&mut out, counters);
    if out.len() >= budget {
        records += drain_emitter(&mut out, partitioner, r, &mut sorters, mem, counters, trace);
        seal_on_pressure(mem, &mut sorters, &mut router, counters);
        router.drain_sealed(&mut sorters, counters);
    }
    for (k, v) in split {
        task.map(k, v, &mut out, counters);
        if out.len() >= budget {
            records += drain_emitter(&mut out, partitioner, r, &mut sorters, mem, counters, trace);
            seal_on_pressure(mem, &mut sorters, &mut router, counters);
            router.drain_sealed(&mut sorters, counters);
        }
    }
    task.close(&mut out, counters);
    records += drain_emitter(&mut out, partitioner, r, &mut sorters, mem, counters, trace);
    let bytes = out.bytes();
    for (b, sorter) in sorters.into_iter().enumerate() {
        for run in sorter.into_runs() {
            router.route(b, run, counters);
        }
    }
    router.into_output(t0, records, bytes)
}

/// One reduce task's output plus its measurements.
pub(crate) struct ReduceTaskOutput<KO, VO> {
    pub output: Vec<(KO, VO)>,
    pub secs: f64,
    pub groups: u64,
    pub in_records: u64,
}

impl<KO, VO> ReduceTaskOutput<KO, VO> {
    /// The placeholder output of a dead-lettered reduce partition.
    pub(crate) fn empty() -> Self {
        Self {
            output: Vec::new(),
            secs: 0.0,
            groups: 0,
            in_records: 0,
        }
    }
}

/// Execute one reduce task: lazily k-way-merge `runs` — in-memory and
/// spilled run files stream identically through [`Run::into_records`] —
/// and walk grouping-comparator groups straight off the heap, buffering
/// only the current group's values.
pub(crate) fn exec_reduce_task<KT, VT, KO, VO>(
    runs: Vec<Run<(KT, VT)>>,
    reducer: &dyn ReduceTaskFactory<KT, VT, KO, VO>,
    grouping: &(dyn Fn(&KT, &KT) -> bool + Send + Sync),
    counters: &Counters,
    trace: Option<&TaskTraceCtx>,
    pool: Option<&MemoryPool>,
) -> ReduceTaskOutput<KO, VO>
where
    KT: Ord + SizeEstimate,
    VT: SizeEstimate,
    KO: SizeEstimate,
    VO: SizeEstimate,
{
    let t0 = Instant::now();
    // Reserve the merge's working set up front: in-memory runs at their
    // resident size, spilled runs at their bounded streaming window
    // ([`SPILL_READ_CHUNK`] per run — the k-way merge holds one window
    // per source, never a whole file).  The merge cannot shed memory
    // mid-stream, so a denial is counted and overdrafted rather than
    // parked — admission control (scheduler-side) is what keeps jobs
    // whose floors can't fit from reaching this point.
    let _tmem = pool.map(|p| {
        let bytes: u64 = runs
            .iter()
            .map(|run| match run {
                Run::Mem(_) => run.pool_bytes(),
                Run::Spilled(_) => SPILL_READ_CHUNK as u64,
            })
            .sum();
        let mut res = MemoryConsumer::new("reduce-task").register(p);
        if bytes > 0 && !res.try_grow(bytes) {
            counters.inc(names::POOL_DENIED_GROWS);
            if let Some(t) = trace {
                t.emit(TraceEvent::ReservationDenied { requested: bytes });
            }
            res.grow(bytes);
        }
        res
    });
    if let Some(t) = trace {
        for run in &runs {
            if let Run::Spilled(rf) = run {
                t.emit(TraceEvent::SpillRead {
                    records: rf.records(),
                    file_bytes: rf.file_bytes(),
                });
            }
        }
    }
    let sources: Vec<RunRecords<(KT, VT)>> = runs.into_iter().map(Run::into_records).collect();
    let mut merge = MergeIter::from_iters(sources);
    let in_records = merge.len() as u64;
    let mut task = reducer.create_task();
    let mut out = Emitter::new();
    task.configure(&mut out, counters);
    let consumed = AtomicU64::new(0);
    let mut groups = 0u64;
    let mut group_vals: Vec<VT> = Vec::new();
    let mut next = merge.next();
    // walk groups of consecutive keys equal under the grouping fn; `next`
    // parks the first record of the following group
    while let Some((gkey, gval)) = next.take() {
        group_vals.clear();
        group_vals.push(gval);
        for (k, v) in merge.by_ref() {
            if grouping(&gkey, &k) {
                group_vals.push(v);
            } else {
                next = Some((k, v));
                break;
            }
        }
        groups += 1;
        // Hadoop hands the *first* key of the group to reduce.
        let it = ValuesIter::new(&group_vals, &consumed);
        task.reduce(&gkey, it, &mut out, counters);
    }
    task.close(&mut out, counters);
    ReduceTaskOutput {
        output: out.into_pairs(),
        secs: t0.elapsed().as_secs_f64(),
        groups,
        in_records,
    }
}

/// Divide `input` into `m` contiguous splits (fewer for tiny inputs).
pub(crate) fn split_input<KI, VI>(input: Vec<(KI, VI)>, m: usize) -> Vec<Vec<(KI, VI)>> {
    let ranges = even_splits(input.len(), m);
    let mut rest = input;
    // carve from the back so we can use split_off without copying
    let mut carved: Vec<Vec<(KI, VI)>> = Vec::with_capacity(ranges.len());
    for (start, _) in ranges.iter().rev() {
        carved.push(rest.split_off(*start));
    }
    carved.reverse();
    carved
}

/// The shuffle transpose: reducer `j` receives every map task's bucket-`j`
/// runs, appended in map-task order (the merge's stability contract).  No
/// record is touched — spilled runs move as file handles.  Returns
/// `(per_reducer_runs, shuffle_bytes, shuffle_bytes_raw)`.
#[allow(clippy::type_complexity)]
pub(crate) fn transpose_runs<KT, VT>(
    map_outputs: Vec<MapTaskOutput<KT, VT>>,
    r: usize,
) -> (Vec<Vec<Run<(KT, VT)>>>, Vec<u64>, Vec<u64>) {
    let mut per_reducer_runs: Vec<Vec<Run<(KT, VT)>>> = (0..r).map(|_| Vec::new()).collect();
    let mut shuffle_bytes = vec![0u64; r];
    let mut shuffle_bytes_raw = vec![0u64; r];
    for mo in map_outputs {
        let MapTaskOutput {
            bucket_runs,
            bucket_bytes,
            bucket_raw_bytes,
            ..
        } = mo;
        for (j, ((runs, b), raw)) in bucket_runs
            .into_iter()
            .zip(bucket_bytes)
            .zip(bucket_raw_bytes)
            .enumerate()
        {
            shuffle_bytes[j] += b;
            shuffle_bytes_raw[j] += raw;
            per_reducer_runs[j].extend(runs);
        }
    }
    (per_reducer_runs, shuffle_bytes, shuffle_bytes_raw)
}

/// Fold a finished map wave's measurements into the job counters; returns
/// the total map output records.
pub(crate) fn record_map_wave<KT, VT>(
    counters: &Counters,
    outs: &[MapTaskOutput<KT, VT>],
    has_combiner: bool,
) -> u64 {
    let map_records: u64 = outs.iter().map(|o| o.records).sum();
    let map_bytes: u64 = outs.iter().map(|o| o.bytes).sum();
    counters.add(names::MAP_OUTPUT_RECORDS, map_records);
    counters.add(names::MAP_OUTPUT_BYTES, map_bytes);
    counters.add(names::SPILLED_RECORDS, outs.iter().map(|o| o.spilled).sum());
    counters.add(
        names::MAP_SPILL_RUNS,
        outs.iter().map(|o| o.spill_runs).sum(),
    );
    let file_runs: u64 = outs.iter().map(|o| o.spill_file_runs).sum();
    if file_runs > 0 {
        counters.add(names::SPILLED_RUNS, file_runs);
        counters.add(
            names::SPILL_BYTES_WRITTEN,
            outs.iter().map(|o| o.spill_file_bytes).sum(),
        );
    }
    if has_combiner {
        counters.add(
            names::COMBINE_INPUT_RECORDS,
            outs.iter().map(|o| o.combine_in).sum(),
        );
        counters.add(
            names::COMBINE_OUTPUT_RECORDS,
            outs.iter().map(|o| o.combine_out).sum(),
        );
    }
    map_records
}

/// Fold a finished reduce wave's measurements into the job counters;
/// returns the total reduce output records.
pub(crate) fn record_reduce_wave<KO, VO>(
    counters: &Counters,
    outs: &[ReduceTaskOutput<KO, VO>],
) -> u64 {
    counters.add(names::REDUCE_GROUPS, outs.iter().map(|o| o.groups).sum());
    counters.add(
        names::REDUCE_INPUT_RECORDS,
        outs.iter().map(|o| o.in_records).sum(),
    );
    let red_records: u64 = outs.iter().map(|o| o.output.len() as u64).sum();
    counters.add(names::REDUCE_OUTPUT_RECORDS, red_records);
    red_records
}

/// Run one MapReduce job over an in-memory input.
///
/// `input` is a list of `(key, value)` records; it is divided into
/// `config.num_map_tasks` contiguous splits.  Execution uses
/// `config.workers` threads for the map wave and again for the reduce wave
/// (Hadoop's slot model; map and reduce waves do not overlap — the paper's
/// Hadoop 0.20 has no shuffle/compute overlap either for the final wave,
/// and this keeps per-phase accounting clean).
pub fn run_job<KI, VI, KT, VT, KO, VO>(
    config: &JobConfig,
    input: Vec<(KI, VI)>,
    mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
    partitioner: Arc<dyn Partitioner<KT>>,
    grouping: GroupFn<KT>,
    reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
) -> JobResult<KO, VO>
where
    KI: Send + 'static,
    VI: Send + 'static,
    KT: Ord + Send + SizeEstimate + 'static,
    VT: Send + SizeEstimate + 'static,
    KO: Send + SizeEstimate + 'static,
    VO: Send + SizeEstimate + 'static,
{
    run_job_inner(config, input, mapper, partitioner, grouping, reducer, None)
}

/// As [`run_job`], with a map-side combiner (Hadoop's
/// `setCombinerClass`): each sorted run is pre-reduced before the shuffle,
/// shrinking `SHUFFLE_BYTES` for associative aggregations such as the
/// key-histogram jobs the Manual partitioner is built from.  The reduce
/// outputs are unchanged whenever the combiner is associative and
/// key-preserving (Hadoop's contract).
pub fn run_job_with_combiner<KI, VI, KT, VT, KO, VO>(
    config: &JobConfig,
    input: Vec<(KI, VI)>,
    mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
    partitioner: Arc<dyn Partitioner<KT>>,
    grouping: GroupFn<KT>,
    reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    combiner: Arc<dyn Combiner<KT, VT>>,
) -> JobResult<KO, VO>
where
    KI: Send + 'static,
    VI: Send + 'static,
    KT: Ord + Clone + Send + SizeEstimate + 'static,
    VT: Send + SizeEstimate + 'static,
    KO: Send + SizeEstimate + 'static,
    VO: Send + SizeEstimate + 'static,
{
    let combine_fn: CombineFn<KT, VT> = Arc::new(move |run: &mut Vec<(KT, VT)>, c: &Counters| {
        combine_sorted_bucket(run, combiner.as_ref(), c)
    });
    run_job_inner(
        config,
        input,
        mapper,
        partitioner,
        grouping,
        reducer,
        Some(combine_fn),
    )
}

fn run_job_inner<KI, VI, KT, VT, KO, VO>(
    config: &JobConfig,
    input: Vec<(KI, VI)>,
    mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
    partitioner: Arc<dyn Partitioner<KT>>,
    grouping: GroupFn<KT>,
    reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
    combine_fn: Option<CombineFn<KT, VT>>,
) -> JobResult<KO, VO>
where
    KI: Send + 'static,
    VI: Send + 'static,
    KT: Ord + Send + SizeEstimate + 'static,
    VT: Send + SizeEstimate + 'static,
    KO: Send + SizeEstimate + 'static,
    VO: Send + SizeEstimate + 'static,
{
    let counters = Arc::new(Counters::new());
    let workers = config.workers;
    let r = config.num_reduce_tasks;
    let sort_budget = config.sort_buffer_records;
    // resolve the type-erased spill codec once per job (panics on a codec
    // built for different record types — a wiring bug, not a data error)
    let spill: Option<ResolvedSpill<(KT, VT)>> = config.spill.as_ref().map(|s| s.resolve());
    let has_combiner = combine_fn.is_some();
    // The serial driver is the fail-fast reference path: an injected
    // panic fails the job (via `run_owned`'s panic accounting) — retry,
    // dead-lettering, and checkpointing live on the scheduler.
    let injector = super::fault::FaultInjector::from_plan(config.faults.clone());
    // One trace context per job: stamps `JobStarted` and anchors every
    // record's `at_secs` to this job's start.
    let jctx = config.trace.as_ref().map(|t| t.job_ctx(&config.name));
    // the serial driver accounts task memory under the job's pool, if
    // any — there is no scheduler here to admit jobs, so tasks charge
    // (and overdraft) directly
    let pool = config.memory.clone();

    // Each map task: configure → map* → close; emitted records drain into
    // per-partition RunSorters (Hadoop's map-side "sort & spill": every
    // sealed chunk is one sorted run), then the combiner pre-reduces each
    // run before it is handed to the shuffle.
    let map_wave = {
        let mapper = Arc::clone(&mapper);
        let partitioner = Arc::clone(&partitioner);
        let counters = Arc::clone(&counters);
        let injector = Arc::clone(&injector);
        let jctx = jctx.clone();
        let pool = pool.clone();
        move |splits: Vec<Vec<(KI, VI)>>| {
            let pool = pool.clone();
            run_owned(workers, splits, move |i, split: Vec<(KI, VI)>| {
                // the serial path runs exactly one attempt per task
                let tctx = jctx.as_ref().map(|j| j.task(TracePhase::Map, i, 0));
                if let Some(t) = &tctx {
                    t.emit(TraceEvent::AttemptStarted);
                }
                injector.fire_traced(super::fault::TaskPhase::Map, i, tctx.as_ref());
                let out = exec_map_task(
                    split,
                    r,
                    sort_budget,
                    spill.as_ref(),
                    mapper.as_ref(),
                    partitioner.as_ref(),
                    combine_fn.as_ref(),
                    &counters,
                    None,
                    tctx.as_ref(),
                    pool.as_ref(),
                );
                if let Some(t) = &tctx {
                    t.emit(TraceEvent::AttemptFinished);
                    t.emit(TraceEvent::AttemptWon);
                }
                out
            })
        }
    };
    // Each reduce task lazily k-way-merges its runs and walks groups
    // straight off the heap; only the current group's values are buffered
    // (they must form a contiguous `&[VT]` for the forward-cursor
    // iterator).
    let reduce_wave = {
        let reducer = Arc::clone(&reducer);
        let grouping = Arc::clone(&grouping);
        let counters = Arc::clone(&counters);
        let injector = Arc::clone(&injector);
        let jctx = jctx.clone();
        let pool = pool.clone();
        move |per_reducer_runs: Vec<Vec<Run<(KT, VT)>>>| {
            let pool = pool.clone();
            run_owned(
                workers,
                per_reducer_runs,
                move |j, runs: Vec<Run<(KT, VT)>>| {
                    let tctx = jctx.as_ref().map(|jc| jc.task(TracePhase::Reduce, j, 0));
                    if let Some(t) = &tctx {
                        t.emit(TraceEvent::AttemptStarted);
                    }
                    injector.fire_traced(super::fault::TaskPhase::Reduce, j, tctx.as_ref());
                    let out = exec_reduce_task(
                        runs,
                        reducer.as_ref(),
                        grouping.as_ref(),
                        &counters,
                        tctx.as_ref(),
                        pool.as_ref(),
                    );
                    if let Some(t) = &tctx {
                        t.emit(TraceEvent::AttemptFinished);
                        t.emit(TraceEvent::AttemptWon);
                    }
                    out
                },
            )
        }
    };
    super::driver::drive_barrier_job(
        config,
        input,
        &counters,
        has_combiner,
        map_wave,
        reduce_wave,
        jctx,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::combiner::FnCombiner;
    use crate::mapreduce::types::{FnMapTask, FnReduceTask, HashPartitioner, MapTask};

    /// Word-count — the Figure 1 example of the paper.
    #[test]
    fn word_count_like_figure_1() {
        let docs = vec![
            ((), "b c".to_string()),
            ((), "a d".to_string()),
            ((), "b d".to_string()),
            ((), "c d".to_string()),
        ];
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), doc: String, out: &mut Emitter<String, u64>, _c: &Counters| {
                for w in doc.split_whitespace() {
                    out.emit(w.to_string(), 1);
                }
            },
        ));
        // range partition: a-c → 0, d-z → 1 (like the figure's a–m / n–z)
        struct Range;
        impl Partitioner<String> for Range {
            fn partition(&self, key: &String, _r: usize) -> usize {
                usize::from(key.as_str() >= "d")
            }
        }
        let reducer = Arc::new(FnReduceTask::new(
            |k: &String, vals: ValuesIter<'_, u64>, out: &mut Emitter<String, u64>, _c: &Counters| {
                out.emit(k.clone(), vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("wc").with_tasks(2, 2).with_workers(2);
        let res = run_job(
            &cfg,
            docs,
            mapper,
            Arc::new(Range),
            Arc::new(|a: &String, b: &String| a == b),
            reducer,
        );
        assert_eq!(
            res.outputs[0],
            vec![("a".to_string(), 1), ("b".to_string(), 2), ("c".to_string(), 2)]
        );
        assert_eq!(res.outputs[1], vec![("d".to_string(), 3)]);
        assert_eq!(res.counters.get(names::MAP_INPUT_RECORDS), 4);
        assert_eq!(res.counters.get(names::MAP_OUTPUT_RECORDS), 8);
        assert_eq!(res.counters.get(names::REDUCE_GROUPS), 4);
    }

    /// Reduce input must be sorted by key even with multiple map tasks and
    /// a hash partitioner.
    #[test]
    fn reduce_input_sorted_and_partition_disjoint() {
        let input: Vec<((), u64)> = (0..1000u64).rev().map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(v % 97, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.count() as u64);
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(4, 3).with_workers(3);
        let res = run_job(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        // each key appears in exactly one partition, keys sorted within
        let mut seen = std::collections::BTreeSet::new();
        for part in &res.outputs {
            let keys: Vec<u64> = part.iter().map(|(k, _)| *k).collect();
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            assert_eq!(keys, sorted);
            for k in keys {
                assert!(seen.insert(k), "key {k} in two partitions");
            }
        }
        assert_eq!(seen.len(), 97);
        let total: u64 = res
            .outputs
            .iter()
            .flatten()
            .map(|(_, count)| *count)
            .sum();
        assert_eq!(total, 1000);
    }

    /// configure/close lifecycle runs once per task; per-task state works.
    #[test]
    fn map_task_lifecycle_hooks() {
        struct Stateful {
            seen: u64,
        }
        impl MapTask<(), u64, u64, u64> for Stateful {
            fn configure(&mut self, out: &mut Emitter<u64, u64>, _c: &Counters) {
                out.emit(7777, 0); // marker from configure
            }
            fn map(&mut self, _k: (), v: u64, _out: &mut Emitter<u64, u64>, _c: &Counters) {
                self.seen += v;
            }
            fn close(&mut self, out: &mut Emitter<u64, u64>, _c: &Counters) {
                out.emit(8888, self.seen); // flush in close (RepSN pattern)
            }
        }
        struct F;
        impl MapTaskFactory<(), u64, u64, u64> for F {
            fn create_task(&self) -> Box<dyn MapTask<(), u64, u64, u64> + Send> {
                Box::new(Stateful { seen: 0 })
            }
        }
        let input: Vec<((), u64)> = (1..=10).map(|i| ((), i)).collect();
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(2, 1).with_workers(1);
        let res = run_job(
            &cfg,
            input,
            Arc::new(F),
            Arc::new(HashPartitioner::new(|_: &u64| 0)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        let out = res.merged_output();
        // two tasks → two configure markers and two close flushes summing 55
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, 7777);
        assert_eq!(out[1], (8888, 55));
    }

    /// Grouping comparator groups distinct sort keys into one reduce call.
    #[test]
    fn grouping_comparator_prefix_grouping() {
        // keys (group, seq) sorted lexicographically; group by .0 only
        let input: Vec<((), (u32, u32))> =
            vec![((), (1, 3)), ((), (1, 1)), ((), (2, 2)), ((), (1, 2))];
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: (u32, u32), out: &mut Emitter<(u32, u32), u32>, _c: &Counters| {
                out.emit(v, v.1);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &(u32, u32),
             vals: ValuesIter<'_, u32>,
             out: &mut Emitter<u32, Vec<u32>>,
             _c: &Counters| {
                out.emit(k.0, vals.copied().collect());
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(2, 1).with_workers(1);
        let res = run_job(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|_: &(u32, u32)| 0)),
            Arc::new(|a: &(u32, u32), b: &(u32, u32)| a.0 == b.0),
            reducer,
        );
        let out = res.merged_output();
        // group 1 gets values in *sorted key order* 1,2,3; group 2 gets [2]
        assert_eq!(out, vec![(1, vec![1, 2, 3]), (2, vec![2])]);
    }

    #[test]
    fn stats_are_populated() {
        let input: Vec<((), u64)> = (0..100).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| out.emit(v, v),
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, _v: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, 0)
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(4, 2).with_workers(2);
        let res = run_job(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        assert_eq!(res.stats.map_task_secs.len(), 4);
        assert_eq!(res.stats.reduce_task_secs.len(), 2);
        assert_eq!(res.stats.shuffle_bytes_per_reducer.len(), 2);
        assert!(res.stats.total_secs > 0.0);
        assert_eq!(res.stats.map_output_records, 100);
    }

    /// The streaming merge keeps values of equal keys in map-task order
    /// (the stability contract the old materializing merge guaranteed).
    #[test]
    fn values_of_equal_keys_arrive_in_map_task_order() {
        // 4 records, 2 splits → task 0 maps [10, 11], task 1 maps [12, 13]
        let input: Vec<((), u64)> = (10..14).map(|v| ((), v)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(0, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, Vec<u64>>, _c: &Counters| {
                out.emit(*k, vals.copied().collect());
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(2, 1).with_workers(2);
        let res = run_job(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|_: &u64| 0)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        assert_eq!(res.merged_output(), vec![(0, vec![10, 11, 12, 13])]);
    }

    fn histogram_fixtures() -> (
        Vec<((), u64)>,
        Arc<FnMapTask<impl Fn((), u64, &mut Emitter<u64, u64>, &Counters)>>,
        Arc<FnReduceTask<impl Fn(&u64, ValuesIter<'_, u64>, &mut Emitter<u64, u64>, &Counters)>>,
    ) {
        let input: Vec<((), u64)> = (0..600u64).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(v % 5, 1); // 5 hot keys — classic combiner material
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        (input, mapper, reducer)
    }

    /// The combiner shrinks shuffle bytes without changing reduce output.
    #[test]
    fn combiner_preserves_output_and_shrinks_shuffle() {
        let cfg = JobConfig::named("hist").with_tasks(4, 2).with_workers(2);
        let (input, mapper, reducer) = histogram_fixtures();
        let plain = run_job(
            &cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer.clone(),
        );
        let combined = run_job_with_combiner(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
            Arc::new(FnCombiner::new(|_k: &u64, vals: Vec<u64>, _c: &Counters| {
                vec![vals.into_iter().sum()]
            })),
        );
        assert_eq!(plain.outputs, combined.outputs);
        let sb_plain = plain.counters.get(names::SHUFFLE_BYTES);
        let sb_comb = combined.counters.get(names::SHUFFLE_BYTES);
        assert!(
            sb_comb * 10 < sb_plain,
            "combiner should shrink shuffle: {sb_comb} vs {sb_plain}"
        );
        assert_eq!(combined.counters.get(names::COMBINE_INPUT_RECORDS), 600);
        // 4 tasks × ≤5 keys each
        assert!(combined.counters.get(names::COMBINE_OUTPUT_RECORDS) <= 20);
        assert_eq!(plain.counters.get(names::COMBINE_INPUT_RECORDS), 0);
        // reduce still sees the combined records
        assert_eq!(
            combined.counters.get(names::REDUCE_INPUT_RECORDS),
            combined.counters.get(names::COMBINE_OUTPUT_RECORDS)
        );
    }

    /// A tight sort budget produces many sealed runs but identical output.
    #[test]
    fn sort_budget_spill_is_output_equivalent() {
        let (input, mapper, reducer) = histogram_fixtures();
        let base_cfg = JobConfig::named("spill").with_tasks(4, 3).with_workers(2);
        let spill_cfg = base_cfg.clone().with_sort_buffer(Some(7));
        let plain = run_job(
            &base_cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer.clone(),
        );
        let spilled = run_job(
            &spill_cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        assert_eq!(plain.outputs, spilled.outputs);
        // without a budget: ≤ one run per (task, bucket); with a tight one
        // the sealed-chunk runs must outnumber that
        let base_runs = plain.counters.get(names::MAP_SPILL_RUNS);
        let spill_runs = spilled.counters.get(names::MAP_SPILL_RUNS);
        assert!(base_runs <= 4 * 3);
        assert!(
            spill_runs > base_runs,
            "expected chunked spill runs: {spill_runs} vs {base_runs}"
        );
        assert_eq!(spilled.counters.get(names::SPILLED_RECORDS), 600);
    }

    /// The disk-backed data path: identical outputs, honest spill
    /// counters, and `SHUFFLE_BYTES` reporting on-disk volume.
    #[test]
    fn disk_backed_runs_are_output_equivalent() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_fixtures();
        let dir = TempSpillDir::new("engine-disk").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let base_cfg = JobConfig::named("disk")
            .with_tasks(4, 3)
            .with_workers(2)
            .with_sort_buffer(Some(16));
        let disk_cfg = base_cfg
            .clone()
            .with_spill(Some(SpillSpec::new(dir.path(), codec)));
        let mem = run_job(
            &base_cfg,
            input.clone(),
            mapper.clone(),
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer.clone(),
        );
        let disk = run_job(
            &disk_cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        assert_eq!(mem.outputs, disk.outputs);
        // every sealed run became a run file
        assert_eq!(
            disk.counters.get(names::SPILLED_RUNS),
            disk.counters.get(names::MAP_SPILL_RUNS)
        );
        assert!(disk.counters.get(names::SPILL_BYTES_WRITTEN) > 0);
        // the raw estimate matches the in-memory accounting; the charged
        // shuffle volume is the on-disk bytes
        assert_eq!(
            disk.counters.get(names::SHUFFLE_BYTES_RAW),
            mem.counters.get(names::SHUFFLE_BYTES)
        );
        assert_eq!(
            disk.counters.get(names::SHUFFLE_BYTES),
            disk.counters.get(names::SPILL_BYTES_WRITTEN)
        );
        assert!(disk.stats.intermediate_compressed);
        assert_eq!(disk.stats.spill_bytes_written, disk.counters.get(names::SPILL_BYTES_WRITTEN));
        // in-memory jobs report raw == charged
        assert_eq!(
            mem.counters.get(names::SHUFFLE_BYTES_RAW),
            mem.counters.get(names::SHUFFLE_BYTES)
        );
        assert_eq!(mem.counters.get(names::SPILLED_RUNS), 0);
        assert!(!mem.stats.intermediate_compressed);
    }

    /// A combiner composes with the disk-backed path: runs are combined
    /// *before* serialization, so spilled bytes reflect combined records.
    #[test]
    fn combiner_runs_before_spill_serialization() {
        use crate::mapreduce::sortspill::{Codec, KeyValueCodec, SpillSpec, TempSpillDir, U64Codec};
        let (input, mapper, reducer) = histogram_fixtures();
        let dir = TempSpillDir::new("engine-comb").unwrap();
        let codec: Arc<dyn Codec<(u64, u64)>> = Arc::new(KeyValueCodec::new(U64Codec, U64Codec));
        let cfg = JobConfig::named("disk-comb")
            .with_tasks(4, 2)
            .with_workers(2)
            .with_spill(Some(SpillSpec::new(dir.path(), codec).with_compress(false)));
        let combined = run_job_with_combiner(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
            Arc::new(FnCombiner::new(|_k: &u64, vals: Vec<u64>, _c: &Counters| {
                vec![vals.into_iter().sum()]
            })),
        );
        // 4 tasks × ≤5 distinct keys, 16 encoded bytes per record + 9-byte
        // run-file header: far below the 600-record uncombined volume
        let combined_records = combined.counters.get(names::COMBINE_OUTPUT_RECORDS);
        assert!(combined_records <= 20);
        assert_eq!(
            combined.counters.get(names::SHUFFLE_BYTES_RAW),
            combined_records * 16
        );
        assert!(!combined.stats.intermediate_compressed, "compression off");
        let total: u64 = combined
            .outputs
            .iter()
            .flatten()
            .map(|(_, c)| *c)
            .sum();
        assert_eq!(total, 600);
    }
}
