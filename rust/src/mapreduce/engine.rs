//! The job driver: split → map (+sort/partition) → shuffle → reduce.
//!
//! Faithful to the Hadoop execution model at the semantics level the
//! paper's algorithms require (see module docs on [`super`]), instrumented
//! with the per-task wall-clock timings and byte counts the cluster
//! simulator ([`super::sim`]) consumes.

use std::sync::atomic::AtomicU64;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::config::JobConfig;
use super::counters::{names, Counters};
use super::shuffle::merge_sorted_runs;
use super::splits::even_splits;
use super::types::{
    Emitter, MapTaskFactory, Partitioner, ReduceTaskFactory, SizeEstimate, ValuesIter,
};
use crate::util::threadpool::run_indexed;

/// Grouping comparator: `true` if two (adjacent, sort-ordered) keys belong
/// to the same reduce *group* (Hadoop's value-grouping comparator).
pub type GroupFn<KT> = Arc<dyn Fn(&KT, &KT) -> bool + Send + Sync>;

/// Per-job measured statistics (feed the simulator and the reports).
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    /// Wall time of each map task, in seconds, indexed by task id.
    pub map_task_secs: Vec<f64>,
    /// Wall time of each reduce task, in seconds, indexed by partition.
    pub reduce_task_secs: Vec<f64>,
    /// Estimated intermediate bytes routed to each reduce partition.
    pub shuffle_bytes_per_reducer: Vec<u64>,
    /// Wall time of the whole map phase (tasks + sort), reduce phase, and
    /// shuffle merge, as executed on the real worker pool.
    pub map_phase_secs: f64,
    pub shuffle_phase_secs: f64,
    pub reduce_phase_secs: f64,
    pub total_secs: f64,
    /// Records emitted by map / reduce.
    pub map_output_records: u64,
    pub reduce_output_records: u64,
}

/// Everything a finished job returns.
pub struct JobResult<KO, VO> {
    /// Reduce outputs, one `Vec` per reduce partition, in partition order
    /// ("the output partitions can easily be merged to a combined result").
    pub outputs: Vec<Vec<(KO, VO)>>,
    pub counters: Arc<Counters>,
    pub stats: JobStats,
}

impl<KO, VO> JobResult<KO, VO> {
    /// Concatenate all partitions in order (the final merge step).
    pub fn merged_output(self) -> Vec<(KO, VO)> {
        self.outputs.into_iter().flatten().collect()
    }
}

/// Run one MapReduce job over an in-memory input.
///
/// `input` is a list of `(key, value)` records; it is divided into
/// `config.num_map_tasks` contiguous splits.  Execution uses
/// `config.workers` threads for the map wave and again for the reduce wave
/// (Hadoop's slot model; map and reduce waves do not overlap — the paper's
/// Hadoop 0.20 has no shuffle/compute overlap either for the final wave,
/// and this keeps per-phase accounting clean).
pub fn run_job<KI, VI, KT, VT, KO, VO>(
    config: &JobConfig,
    input: Vec<(KI, VI)>,
    mapper: Arc<dyn MapTaskFactory<KI, VI, KT, VT>>,
    partitioner: Arc<dyn Partitioner<KT>>,
    grouping: GroupFn<KT>,
    reducer: Arc<dyn ReduceTaskFactory<KT, VT, KO, VO>>,
) -> JobResult<KO, VO>
where
    KI: Send + 'static,
    VI: Send + 'static,
    KT: Ord + Send + SizeEstimate + 'static,
    VT: Send + SizeEstimate + 'static,
    KO: Send + SizeEstimate + 'static,
    VO: Send + SizeEstimate + 'static,
{
    let t_start = Instant::now();
    let counters = Arc::new(Counters::new());
    let m = config.num_map_tasks;
    let r = config.num_reduce_tasks;

    // ---- split ------------------------------------------------------------
    let n_input = input.len();
    counters.add(names::MAP_INPUT_RECORDS, n_input as u64);
    let ranges = even_splits(n_input, m);
    let mut splits: Vec<Option<Vec<(KI, VI)>>> = Vec::with_capacity(ranges.len());
    {
        let mut rest = input;
        // carve from the back so we can use split_off without copying
        let mut carved: Vec<Vec<(KI, VI)>> = Vec::with_capacity(ranges.len());
        for (start, _) in ranges.iter().rev() {
            carved.push(rest.split_off(*start));
        }
        carved.reverse();
        for c in carved {
            splits.push(Some(c));
        }
    }
    let actual_m = splits.len(); // may be < m for tiny inputs

    // ---- map phase ---------------------------------------------------------
    // Each map task: configure → map* → close, then partition + sort each
    // bucket (Hadoop sorts at spill time, map-side).
    let t_map = Instant::now();
    let splits = Arc::new(Mutex::new(splits));
    struct MapOut<KT, VT> {
        buckets: Vec<Vec<(KT, VT)>>,
        secs: f64,
        records: u64,
        bytes: u64,
    }
    let map_outputs: Vec<MapOut<KT, VT>> = {
        let splits = Arc::clone(&splits);
        let mapper = Arc::clone(&mapper);
        let partitioner = Arc::clone(&partitioner);
        let counters = Arc::clone(&counters);
        run_indexed(config.workers, actual_m, move |i| {
            let t0 = Instant::now();
            let split = splits.lock().unwrap()[i].take().expect("split taken once");
            let mut task = mapper.create_task();
            let mut out = Emitter::new();
            task.configure(&mut out, &counters);
            for (k, v) in split {
                task.map(k, v, &mut out, &counters);
            }
            task.close(&mut out, &counters);
            let records = out.len() as u64;
            let bytes = out.bytes();
            // partition + sort (the map-side "sort & spill")
            let mut buckets: Vec<Vec<(KT, VT)>> = (0..r).map(|_| Vec::new()).collect();
            for (k, v) in out.into_pairs() {
                let p = partitioner.partition(&k, r);
                assert!(p < r, "partitioner returned {p} for r={r}");
                buckets[p].push((k, v));
            }
            for b in &mut buckets {
                b.sort_by(|a, b| a.0.cmp(&b.0));
            }
            MapOut {
                buckets,
                secs: t0.elapsed().as_secs_f64(),
                records,
                bytes,
            }
        })
    };
    let map_phase_secs = t_map.elapsed().as_secs_f64();

    let mut stats = JobStats {
        map_task_secs: map_outputs.iter().map(|o| o.secs).collect(),
        map_phase_secs,
        ..Default::default()
    };
    let map_records: u64 = map_outputs.iter().map(|o| o.records).sum();
    let map_bytes: u64 = map_outputs.iter().map(|o| o.bytes).sum();
    counters.add(names::MAP_OUTPUT_RECORDS, map_records);
    counters.add(names::MAP_OUTPUT_BYTES, map_bytes);
    counters.add(names::SPILLED_RECORDS, map_records);
    stats.map_output_records = map_records;

    // ---- shuffle -----------------------------------------------------------
    // Transpose buckets: reducer j receives map task i's bucket j.
    let t_shuffle = Instant::now();
    let mut per_reducer_runs: Vec<Vec<Vec<(KT, VT)>>> = (0..r).map(|_| Vec::new()).collect();
    let mut shuffle_bytes = vec![0u64; r];
    for mo in map_outputs {
        for (j, bucket) in mo.buckets.into_iter().enumerate() {
            let b: u64 = bucket
                .iter()
                .map(|(k, v)| (k.size_bytes() + v.size_bytes()) as u64)
                .sum();
            shuffle_bytes[j] += b;
            per_reducer_runs[j].push(bucket);
        }
    }
    counters.add(names::SHUFFLE_BYTES, shuffle_bytes.iter().sum());
    stats.shuffle_bytes_per_reducer = shuffle_bytes;
    // merge runs into one sorted stream per reducer
    let merged: Vec<Vec<(KT, VT)>> = per_reducer_runs
        .into_iter()
        .map(merge_sorted_runs)
        .collect();
    stats.shuffle_phase_secs = t_shuffle.elapsed().as_secs_f64();

    // ---- reduce phase --------------------------------------------------
    let t_reduce = Instant::now();
    struct RedOut<KO, VO> {
        output: Vec<(KO, VO)>,
        secs: f64,
        groups: u64,
        in_records: u64,
    }
    let merged = Arc::new(Mutex::new(
        merged.into_iter().map(Some).collect::<Vec<_>>(),
    ));
    let red_outputs: Vec<RedOut<KO, VO>> = {
        let merged = Arc::clone(&merged);
        let reducer = Arc::clone(&reducer);
        let grouping = Arc::clone(&grouping);
        let counters = Arc::clone(&counters);
        run_indexed(config.workers, r, move |j| {
            let t0 = Instant::now();
            let run = merged.lock().unwrap()[j].take().expect("run taken once");
            let in_records = run.len() as u64;
            // Unzip into parallel key/value vectors so each group's values
            // form a contiguous `&[VT]` for the forward-cursor iterator.
            let mut keys: Vec<KT> = Vec::with_capacity(run.len());
            let mut values: Vec<VT> = Vec::with_capacity(run.len());
            for (k, v) in run {
                keys.push(k);
                values.push(v);
            }
            let mut task = reducer.create_task();
            let mut out = Emitter::new();
            task.configure(&mut out, &counters);
            let consumed = AtomicU64::new(0);
            let mut groups = 0u64;
            // walk groups of consecutive keys equal under the grouping fn
            let mut start = 0;
            while start < keys.len() {
                let mut end = start + 1;
                while end < keys.len() && grouping(&keys[start], &keys[end]) {
                    end += 1;
                }
                groups += 1;
                // Hadoop hands the *first* key of the group to reduce.
                let it = ValuesIter::new(&values[start..end], &consumed);
                task.reduce(&keys[start], it, &mut out, &counters);
                start = end;
            }
            task.close(&mut out, &counters);
            RedOut {
                output: out.into_pairs(),
                secs: t0.elapsed().as_secs_f64(),
                groups,
                in_records,
            }
        })
    };
    stats.reduce_phase_secs = t_reduce.elapsed().as_secs_f64();
    stats.reduce_task_secs = red_outputs.iter().map(|o| o.secs).collect();
    let groups: u64 = red_outputs.iter().map(|o| o.groups).sum();
    let red_in: u64 = red_outputs.iter().map(|o| o.in_records).sum();
    counters.add(names::REDUCE_GROUPS, groups);
    counters.add(names::REDUCE_INPUT_RECORDS, red_in);
    let outputs: Vec<Vec<(KO, VO)>> = red_outputs.into_iter().map(|o| o.output).collect();
    let red_records: u64 = outputs.iter().map(|o| o.len() as u64).sum();
    counters.add(names::REDUCE_OUTPUT_RECORDS, red_records);
    stats.reduce_output_records = red_records;
    stats.total_secs = t_start.elapsed().as_secs_f64();

    JobResult {
        outputs,
        counters,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::types::{FnMapTask, FnReduceTask, HashPartitioner, MapTask};

    /// Word-count — the Figure 1 example of the paper.
    #[test]
    fn word_count_like_figure_1() {
        let docs = vec![
            ((), "b c".to_string()),
            ((), "a d".to_string()),
            ((), "b d".to_string()),
            ((), "c d".to_string()),
        ];
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), doc: String, out: &mut Emitter<String, u64>, _c: &Counters| {
                for w in doc.split_whitespace() {
                    out.emit(w.to_string(), 1);
                }
            },
        ));
        // range partition: a-c → 0, d-z → 1 (like the figure's a–m / n–z)
        struct Range;
        impl Partitioner<String> for Range {
            fn partition(&self, key: &String, _r: usize) -> usize {
                usize::from(key.as_str() >= "d")
            }
        }
        let reducer = Arc::new(FnReduceTask::new(
            |k: &String, vals: ValuesIter<'_, u64>, out: &mut Emitter<String, u64>, _c: &Counters| {
                out.emit(k.clone(), vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("wc").with_tasks(2, 2).with_workers(2);
        let res = run_job(
            &cfg,
            docs,
            mapper,
            Arc::new(Range),
            Arc::new(|a: &String, b: &String| a == b),
            reducer,
        );
        assert_eq!(
            res.outputs[0],
            vec![("a".to_string(), 1), ("b".to_string(), 2), ("c".to_string(), 2)]
        );
        assert_eq!(res.outputs[1], vec![("d".to_string(), 3)]);
        assert_eq!(res.counters.get(names::MAP_INPUT_RECORDS), 4);
        assert_eq!(res.counters.get(names::MAP_OUTPUT_RECORDS), 8);
        assert_eq!(res.counters.get(names::REDUCE_GROUPS), 4);
    }

    /// Reduce input must be sorted by key even with multiple map tasks and
    /// a hash partitioner.
    #[test]
    fn reduce_input_sorted_and_partition_disjoint() {
        let input: Vec<((), u64)> = (0..1000u64).rev().map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(v % 97, v);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.count() as u64);
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(4, 3).with_workers(3);
        let res = run_job(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        // each key appears in exactly one partition, keys sorted within
        let mut seen = std::collections::BTreeSet::new();
        for part in &res.outputs {
            let keys: Vec<u64> = part.iter().map(|(k, _)| *k).collect();
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            assert_eq!(keys, sorted);
            for k in keys {
                assert!(seen.insert(k), "key {k} in two partitions");
            }
        }
        assert_eq!(seen.len(), 97);
        let total: u64 = res
            .outputs
            .iter()
            .flatten()
            .map(|(_, count)| *count)
            .sum();
        assert_eq!(total, 1000);
    }

    /// configure/close lifecycle runs once per task; per-task state works.
    #[test]
    fn map_task_lifecycle_hooks() {
        struct Stateful {
            seen: u64,
        }
        impl MapTask<(), u64, u64, u64> for Stateful {
            fn configure(&mut self, out: &mut Emitter<u64, u64>, _c: &Counters) {
                out.emit(7777, 0); // marker from configure
            }
            fn map(&mut self, _k: (), v: u64, _out: &mut Emitter<u64, u64>, _c: &Counters) {
                self.seen += v;
            }
            fn close(&mut self, out: &mut Emitter<u64, u64>, _c: &Counters) {
                out.emit(8888, self.seen); // flush in close (RepSN pattern)
            }
        }
        struct F;
        impl MapTaskFactory<(), u64, u64, u64> for F {
            fn create_task(&self) -> Box<dyn MapTask<(), u64, u64, u64> + Send> {
                Box::new(Stateful { seen: 0 })
            }
        }
        let input: Vec<((), u64)> = (1..=10).map(|i| ((), i)).collect();
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, vals: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, vals.map(|v| *v).sum());
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(2, 1).with_workers(1);
        let res = run_job(
            &cfg,
            input,
            Arc::new(F),
            Arc::new(HashPartitioner::new(|_: &u64| 0)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        let out = res.merged_output();
        // two tasks → two configure markers and two close flushes summing 55
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, 7777);
        assert_eq!(out[1], (8888, 55));
    }

    /// Grouping comparator groups distinct sort keys into one reduce call.
    #[test]
    fn grouping_comparator_prefix_grouping() {
        // keys (group, seq) sorted lexicographically; group by .0 only
        let input: Vec<((), (u32, u32))> =
            vec![((), (1, 3)), ((), (1, 1)), ((), (2, 2)), ((), (1, 2))];
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: (u32, u32), out: &mut Emitter<(u32, u32), u32>, _c: &Counters| {
                out.emit(v, v.1);
            },
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &(u32, u32),
             vals: ValuesIter<'_, u32>,
             out: &mut Emitter<u32, Vec<u32>>,
             _c: &Counters| {
                out.emit(k.0, vals.copied().collect());
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(2, 1).with_workers(1);
        let res = run_job(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|_: &(u32, u32)| 0)),
            Arc::new(|a: &(u32, u32), b: &(u32, u32)| a.0 == b.0),
            reducer,
        );
        let out = res.merged_output();
        // group 1 gets values in *sorted key order* 1,2,3; group 2 gets [2]
        assert_eq!(out, vec![(1, vec![1, 2, 3]), (2, vec![2])]);
    }

    #[test]
    fn stats_are_populated() {
        let input: Vec<((), u64)> = (0..100).map(|i| ((), i)).collect();
        let mapper = Arc::new(FnMapTask::new(
            |_k: (), v: u64, out: &mut Emitter<u64, u64>, _c: &Counters| out.emit(v, v),
        ));
        let reducer = Arc::new(FnReduceTask::new(
            |k: &u64, _v: ValuesIter<'_, u64>, out: &mut Emitter<u64, u64>, _c: &Counters| {
                out.emit(*k, 0)
            },
        ));
        let cfg = JobConfig::named("t").with_tasks(4, 2).with_workers(2);
        let res = run_job(
            &cfg,
            input,
            mapper,
            Arc::new(HashPartitioner::new(|k: &u64| *k)),
            Arc::new(|a: &u64, b: &u64| a == b),
            reducer,
        );
        assert_eq!(res.stats.map_task_secs.len(), 4);
        assert_eq!(res.stats.reduce_task_secs.len(), 2);
        assert_eq!(res.stats.shuffle_bytes_per_reducer.len(), 2);
        assert!(res.stats.total_secs > 0.0);
        assert_eq!(res.stats.map_output_records, 100);
    }
}
